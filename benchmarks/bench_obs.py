"""Observability overhead (DESIGN.md §14): the disabled path must be noise.

Commits one base + ``N_DERIVATIVES`` finetunes through the pipelined
store (the instrumented hot path: quantize/encode/hash spans inside the
worker pool, pack-fsync at the commit point) under three configurations:

* **stripped**  — ``span``/``propagate`` monkeypatched to no-ops, i.e. an
  uninstrumented build (the baseline an overhead claim must compare to);
* **disabled**  — the shipped default: tracing off, every ``span()`` call
  is one branch returning a cached null context manager;
* **enabled**   — tracing on, every span allocated and buffered.

Reports relative commit-throughput overhead of *disabled* and *enabled*
vs *stripped*, plus the direct cost of a disabled ``span()`` call in
nanoseconds. Per the §14 contract the numbers are **measured, not
asserted** — single-digit-percent wall-clock noise on a busy CI box
would make an assertion flaky, so the trajectory lives in
``BENCH_PR8.json`` where PRs diff it instead.

Run directly: ``PYTHONPATH=src:. python -m benchmarks.bench_obs``
"""

from __future__ import annotations

import contextlib
import tempfile
import time
from typing import Dict

from benchmarks.pools import base_model, finetune
from repro.obs import reset_trace, span, tracing
from repro.store import ArtifactStore
from repro.store import artifact_store as _store_mod

N_DERIVATIVES = 16
REPEATS = 5
SPAN_CALLS = 200_000


def _commit_pool(models) -> float:
    """Seconds to commit the whole pool into a fresh pipelined store."""
    with tempfile.TemporaryDirectory() as root:
        store = ArtifactStore(root=root, io_workers=4)
        t0 = time.perf_counter()
        parent = None
        for name, art in models:
            parent = store.commit_artifact(name, art, parent_ref=parent)
        return time.perf_counter() - t0


@contextlib.contextmanager
def _stripped():
    """Uninstrumented build: remove even the disabled-path branch."""
    null = contextlib.nullcontext()
    saved = _store_mod.span, _store_mod.propagate
    _store_mod.span = lambda *a, **kw: null
    _store_mod.propagate = lambda fn: fn
    try:
        yield
    finally:
        _store_mod.span, _store_mod.propagate = saved


def _disabled_span_ns() -> float:
    t0 = time.perf_counter()
    for _ in range(SPAN_CALLS):
        with span("bench.noop", cat="bench"):
            pass
    return (time.perf_counter() - t0) / SPAN_CALLS * 1e9


def main() -> Dict[str, float]:
    base = base_model(seed=0, n_layers=8, d=384)
    models = [("base", base)] + [
        (f"ft{i}", finetune(base, seed=10 + i)) for i in range(N_DERIVATIVES)]

    _commit_pool(models)  # warmup: page cache, JIT'd codecs, pool spin-up

    def run_stripped():
        with _stripped():
            return _commit_pool(models)

    def run_disabled():
        return _commit_pool(models)

    def run_enabled():
        reset_trace()
        with tracing():
            dt = _commit_pool(models)
        reset_trace()
        return dt

    configs = [("stripped", run_stripped), ("disabled", run_disabled),
               ("enabled", run_enabled)]
    best = {name: float("inf") for name, _ in configs}
    # rotate the configuration order each round so slow-start / cache
    # drift never favors one slot; keep the best of each — min is the
    # noise floor (wall-clock variance on a shared box swamps the true
    # sub-0.1% disabled-path cost, hence the analytic bound below)
    for i in range(REPEATS):
        for name, run in configs[i % 3:] + configs[:i % 3]:
            best[name] = min(best[name], run())

    span_ns = _disabled_span_ns()
    # analytic bound: spans actually hit during one traced pool commit ×
    # the measured per-call disabled cost, as a fraction of commit time —
    # immune to the wall-clock noise the A/B rows carry
    reset_trace()
    with tracing():
        _commit_pool(models)
    from repro.obs import export_chrome_trace
    spans_per_commit = sum(1 for e in export_chrome_trace()["traceEvents"]
                           if e.get("ph") == "X")
    reset_trace()
    bound_pct = spans_per_commit * span_ns * 1e-9 / best["disabled"] * 100

    n = len(models)
    row = {
        "n_models": n,
        "commit_stripped_s": round(best["stripped"], 4),
        "commit_disabled_s": round(best["disabled"], 4),
        "commit_enabled_s": round(best["enabled"], 4),
        "disabled_overhead_pct": round(
            (best["disabled"] / best["stripped"] - 1) * 100, 2),
        "enabled_overhead_pct": round(
            (best["enabled"] / best["stripped"] - 1) * 100, 2),
        "disabled_span_ns": round(span_ns, 1),
        "spans_per_commit": spans_per_commit,
        "disabled_overhead_bound_pct": round(bound_pct, 4),
        "models_per_s_disabled": round(n / best["disabled"], 2),
    }
    print(f"{'config':<12} {'commit_s':>9} {'overhead':>9}")
    for cfg in ("stripped", "disabled", "enabled"):
        over = (best[cfg] / best["stripped"] - 1) * 100
        print(f"{cfg:<12} {best[cfg]:>9.4f} {over:>8.2f}%")
    print(f"disabled span() call: {span_ns:.0f} ns; "
          f"{spans_per_commit} spans/commit -> "
          f"{bound_pct:.4f}% analytic bound")
    return row


if __name__ == "__main__":
    main()
