"""Continuous checkpointing overhead (DESIGN.md §15): commit at training speed.

Trains the toy transformer with the step-delta commit engine at several
cadences and measures wall-clock overhead vs the same loop with
checkpointing disabled, plus bytes/step vs a naive full-snapshot baseline
(state nbytes × commits). Exact tier = lossless xdelta chains (bit-identical
resume); lossy tier = int8 error-feedback deltas with exact keyframes.

``--smoke`` (the CI ``ckpt-smoke`` job) runs a reduced matrix and ASSERTS
the §15 contract: every-10-step exact overhead under bound, exact-tier
resume bit-identity, and lossy restore resolving to an exact keyframe.
The full run writes the same rows into ``BENCH_PR9.json`` via
``benchmarks/run.py`` where PRs diff the trajectory.

Run directly: ``PYTHONPATH=src:. python -m benchmarks.bench_checkpoint``
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.models.config import ModelConfig

# heavier step than the unit-test toy: overhead percentages are relative,
# so the step must do real compute for the ratio to mean anything
CFG = ModelConfig(name="ckpt-bench", family="dense", n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=32, dtype="float32", attn_chunk=64, remat="none")
BATCH, SEQ = 8, 512
WARMUP = 3

# CI boxes are noisy and share cores; the local trajectory file records the
# measured numbers, the smoke assertion uses the contract bound from the
# issue (every-10-step exact < 10%) with headroom for scheduler jitter.
SMOKE_EXACT10_BOUND_PCT = 10.0


def _dir_bytes(root: str) -> int:
    """Stored object bytes: loose objects + packfiles (not the indexes)."""
    total = 0
    for sub in ("objects", "packs"):
        for dirpath, _, files in os.walk(os.path.join(root, sub)):
            for f in files:
                if f.endswith(".json"):
                    continue
                total += os.path.getsize(os.path.join(dirpath, f))
    return total


def _state_nbytes(state) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree_util.tree_leaves(state))


def _run(directory: Optional[str], steps: int, *, commit_every: int = 1,
         lossy: bool = False):
    """One measured training run; returns (seconds/step, trainer)."""
    from repro.train import Trainer
    tr = Trainer(CFG, batch=BATCH, seq=SEQ, checkpoint_dir=directory,
                 seed=0, commit_every=commit_every, lossy_tier=lossy)
    tr.run(WARMUP)
    t0 = time.perf_counter()
    tr.run(steps)
    if tr.ckpt is not None:
        tr.ckpt.wait()
    dt = (time.perf_counter() - t0) / steps
    return dt, tr


def _config_row(tag: str, tier: str, cadence: int,
                steps: int) -> Dict[str, Any]:
    # adjacent baseline: wall-clock on a shared box drifts by more than the
    # overheads being measured, so each row compares against a no-checkpoint
    # run taken right next to it, not one global baseline
    base_s, _ = _run(None, min(steps, 15))
    with tempfile.TemporaryDirectory() as d:
        dt, tr = _run(d, steps, commit_every=cadence, lossy=tier == "lossy")
        n_commits = len(tr.ckpt._steps())
        obj_bytes = _dir_bytes(d)
        snap_bytes = _state_nbytes(tr.state)
        row = {
            "config": tag, "tier": tier, "commit_every": cadence,
            "steps": steps, "step_s": round(dt, 5),
            "base_step_s": round(base_s, 5),
            "overhead_pct": round((dt - base_s) / base_s * 100, 2),
            "commits": n_commits,
            "bytes_per_step": int(obj_bytes / steps),
            "bytes_per_commit": int(obj_bytes / max(n_commits, 1)),
            "full_snapshot_bytes_per_commit": snap_bytes,
            "bytes_vs_full_snapshot": round(
                obj_bytes / max(n_commits, 1) / snap_bytes, 4),
        }
        _check_restore(tr, tier)
        return row


def _check_restore(tr, tier: str) -> None:
    """Functional contract, asserted on every run (cheap next to the loop):
    exact tier resumes bit-identical; lossy tier resolves to a verified
    exact keyframe by default."""
    ckpt = tr.ckpt
    latest = ckpt.latest_step()
    restored, step = ckpt.restore(template=tr.state, verify=True)
    if tier == "exact":
        assert step == latest
        for a, b in zip(jax.tree_util.tree_leaves(tr.state),
                        jax.tree_util.tree_leaves(restored)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), \
                "exact-tier resume must be bit-identical"
    else:
        node = ckpt.lineage.nodes[ckpt._node_name(step)]
        md = ckpt.store.get_manifest(node.artifact_ref).get("metadata") or {}
        assert not md.get("lossy"), \
            "default lossy restore must resolve to an exact keyframe"
        # the lossy intermediates are reachable on request and finite
        flat, s2 = ckpt.restore(step=latest, allow_lossy=True)
        assert s2 == latest
        assert all(np.isfinite(np.asarray(v, np.float64)).all()
                   for v in flat.values())


def main(smoke: bool = False) -> Dict[str, Any]:
    rows = []
    matrix = ([("exact@10", "exact", 10, 30), ("lossy@1", "lossy", 1, 12)]
              if smoke else
              [("exact@1", "exact", 1, 15),
               ("exact@10", "exact", 10, 30),
               ("exact@100", "exact", 100, 100),
               ("lossy@1", "lossy", 1, 15),
               ("lossy@10", "lossy", 10, 30)])
    for tag, tier, cadence, n in matrix:
        row = _config_row(tag, tier, cadence, n)
        rows.append(row)
        print(f"  {tag:10s} step={row['step_s']*1e3:7.1f}ms "
              f"(base {row['base_step_s']*1e3:.1f}ms) "
              f"overhead={row['overhead_pct']:6.2f}% "
              f"bytes/step={row['bytes_per_step']:>9,} "
              f"vs-full-snapshot={row['bytes_vs_full_snapshot']:.3f}x")
    result = {"base_step_s": rows[0]["base_step_s"], "batch": BATCH,
              "seq": SEQ, "state_bytes": None, "rows": rows}
    exact10 = next(r for r in rows if r["config"] == "exact@10")
    result["state_bytes"] = exact10["full_snapshot_bytes_per_commit"]
    if smoke:
        assert exact10["overhead_pct"] < SMOKE_EXACT10_BOUND_PCT, (
            f"every-10-step exact overhead {exact10['overhead_pct']}% "
            f"exceeds the §15 bound {SMOKE_EXACT10_BOUND_PCT}%")
        print("ckpt-smoke OK: overhead bound, exact bit-identity, "
              "lossy->keyframe restore")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced matrix + contract assertions (CI)")
    args = ap.parse_args()
    out = main(smoke=args.smoke)
    print(f"base step {out['base_step_s']*1e3:.1f}ms, "
          f"state {out['state_bytes']:,} bytes")
