"""Diagnostics engine benchmark (paper §4 test-reuse; DESIGN.md §9.1).

Builds a BERT-style lineage pool (G1' families: roots + finetuned
derivatives, committed through the delta-compressed store), registers one
metric probe per model family, then measures:

  cold    first sweep — every (test, model) pair executes, results land in
          the content-addressed ledger
  warm    second sweep through a FRESH runner — everything answers from the
          persisted ledger: asserts a >0 cache-hit ratio and ZERO tensor
          materializations
  scoped  a head-scoped probe across versions whose head is frozen — the
          scoped content key collapses them to one ledger entry

Usage: PYTHONPATH=src:. python -m benchmarks.bench_diag [--smoke]
"""

from __future__ import annotations

import shutil
import sys
import tempfile
import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.pools import base_model, finetune, reinit_head
from repro.core import LineageGraph
from repro.diag import DiagnosticsRunner
from repro.store import ArtifactStore

FAMILIES: Dict[str, Tuple[int, int]] = {"bert": (10, 128), "roberta": (20, 128),
                                        "albert": (30, 96), "distil": (40, 64)}


def probe_mean_activation(model) -> float:
    """Deterministic accuracy stand-in: probe-input mean activation."""
    first = sorted(model.params)[0]
    d = np.asarray(model.params[first]).shape[0]
    x = np.ones((2, d), np.float32)
    for name in model.graph.topo_order():
        w = model.params.get(f"{name}/w")
        if w is None:
            continue
        x = np.tanh(x @ np.asarray(w))
    return float(np.mean(x) * 100)


def probe_head_norm(model) -> float:
    return float(np.linalg.norm(np.asarray(model.params["head/w"])))


def build_pool(root_dir: str, n_children: int = 2, d_scale: float = 1.0,
               n_versions: int = 1) -> LineageGraph:
    """G1'-style pool: unrelated family roots, finetuned children, and
    head-frozen versions of each child (exercises scoped memoization)."""
    g = LineageGraph(path=root_dir, store=ArtifactStore(root=root_dir))
    for fam, (seed, d) in FAMILIES.items():
        d = max(8, int(d * d_scale))
        root = base_model(seed=seed, d=d, prefix=f"{fam}_", model_type=fam)
        g.add_node(root, fam)
        for i in range(n_children):
            child = finetune(reinit_head(root, seed=seed + i),
                             seed=seed + 50 + i, scale=1e-4, density=0.15)
            name = f"{fam}-task{i}"
            g.add_node(child, name)
            g.add_edge(fam, name)
            prev = name
            for v in range(n_versions):
                # Trunk-only finetune with the head restored bit-exactly
                # from the STORED parent (the delta-reconstructed truth) —
                # the zero head-delta round-trips exactly, so all versions
                # share one stored head and the scoped probe memoizes.
                vname = f"{name}@v{v + 2}"
                stored = g.store.load_artifact(
                    g.nodes[prev].artifact_ref, lazy=False)
                vm = finetune(stored, seed=seed + 90 + v, density=0.1)
                vm = vm.replace_params(
                    {"head/w": stored.params["head/w"]})
                g.add_node(vm, vname)
                g.add_version_edge(prev, vname)
                prev = vname
    return g


def register_probes(g: LineageGraph) -> None:
    for fam in FAMILIES:
        g.register_test_function(probe_mean_activation, f"{fam}/activation",
                                 mt=fam)
        g.register_test_function(probe_head_norm, f"{fam}/head_norm", mt=fam,
                                 scope="head")


def main(smoke: bool = False) -> Dict:
    root_dir = tempfile.mkdtemp(prefix="mgit-bench-diag-")
    try:
        d_scale = 0.25 if smoke else 1.0
        g = build_pool(root_dir, n_children=1 if smoke else 2,
                       d_scale=d_scale, n_versions=1 if smoke else 2)
        register_probes(g)
        store = g.store

        # -- cold: everything executes, eager baseline for comparison --------
        store.reset_io_stats()
        t0 = time.perf_counter()
        cold = DiagnosticsRunner(g).run()
        cold_s = time.perf_counter() - t0
        cold_materialized = store.io_stats["tensors_materialized"]

        # -- warm: fresh runner, same store — pure ledger reads ---------------
        store.reset_io_stats()
        t0 = time.perf_counter()
        warm = DiagnosticsRunner(g).run()
        warm_s = time.perf_counter() - t0
        warm_materialized = store.io_stats["tensors_materialized"]

        assert warm.cache_hit_ratio > 0, "second pass must hit the ledger"
        assert warm.executed == 0, "unchanged models must not re-execute"
        assert warm_materialized == 0, \
            f"warm pass materialized {warm_materialized} tensors"
        assert cold.values() == warm.values(), "memoized values must agree"

        # -- scoped: head-frozen versions share the head-probe entry ----------
        # Count distinct executions of the scoped probe vs nodes it covers.
        scoped_runs = sum(
            1 for res in cold.results.values() for r in res.values()
            if r.test.endswith("head_norm") and not r.cached)
        scoped_nodes = sum(
            1 for res in cold.results.values() for r in res.values()
            if r.test.endswith("head_norm"))
        row = {
            "n_models": len(g.nodes),
            "n_pairs": cold.total,
            "cold_s": cold_s,
            "warm_s": warm_s,
            "speedup": cold_s / max(warm_s, 1e-9),
            "cache_hit_ratio": warm.cache_hit_ratio,
            "cold_materialized": cold_materialized,
            "warm_materialized": warm_materialized,
            "scoped_probe_nodes": scoped_nodes,
            "scoped_probe_executions": scoped_runs,
            "scoped_skips": scoped_nodes - scoped_runs,
        }
        assert row["scoped_skips"] > 0, \
            "head-frozen versions must reuse the scoped ledger entry"

        print(f"diag runner: {row['n_models']} models, {row['n_pairs']} "
              f"(test,model) pairs")
        print(f"  cold  {cold_s*1e3:8.1f} ms  "
              f"({cold_materialized} tensors materialized)")
        print(f"  warm  {warm_s*1e3:8.1f} ms  (0 tensors materialized, "
              f"hit ratio {row['cache_hit_ratio']:.0%}) -> "
              f"{row['speedup']:.1f}x")
        print(f"  scoped head probe: {scoped_runs}/{scoped_nodes} executions "
              f"({row['scoped_skips']} re-runs skipped via bit-identical "
              f"submodule)")
        return row
    finally:
        shutil.rmtree(root_dir, ignore_errors=True)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
