"""Hub throughput: HTTP transport vs LocalTransport on one pool (DESIGN.md §11).

Boots a real hub daemon on a loopback ephemeral port, then runs the same
collaboration session twice — once through ``LocalTransport`` (directory
peer) and once through ``HttpTransport`` — reporting wall time, bytes and
dedup per step plus the wire invariants:

* push/clone over HTTP is **bit-identical** to the LocalTransport round
  trip (same lineage etag, same object key set, same stored params);
* an unchanged re-push transfers zero objects over either transport;
* both receiving repos pass fsck with exact refcounts.

Run directly (CI hub-smoke job):
``PYTHONPATH=src:. python -m benchmarks.bench_hub`` — exits non-zero if an
invariant fails.
"""

from __future__ import annotations

import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.pools import g2_adaptation
from repro.core import LineageGraph
from repro.core.auto import auto_insert
from repro.hub import HubApp, start_in_thread
from repro.remote import (HttpTransport, LocalTransport, RemoteState,
                          lineage_etag, pull, push)
from repro.store import ArtifactStore


def _seed(path: str, pool) -> LineageGraph:
    g = LineageGraph(path=path,
                     store=ArtifactStore(root=path, t_thr=float("inf")))
    for name, artifact in pool:
        auto_insert(g, artifact, name)
    return g


def _row(transport: str, step: str, report, elapsed: float) -> Dict:
    return {"transport": transport, "step": step,
            "objects_total": report.objects_total,
            "objects_transferred": report.objects_transferred,
            "bytes_transferred": report.bytes_transferred,
            "dedup_ratio": round(report.dedup_ratio, 4),
            "seconds": round(elapsed, 4)}


def _session(name: str, g: LineageGraph, transport, state: RemoteState,
             dst_dir: str) -> List[Dict]:
    rows = []
    for step in ("initial push", "unchanged re-push"):
        t0 = time.perf_counter()
        rep = push(g, transport, state=state)
        rows.append(_row(name, step, rep, time.perf_counter() - t0))
    g2 = LineageGraph(path=dst_dir, store=ArtifactStore(root=dst_dir))
    t0 = time.perf_counter()
    rep = pull(g2, transport, state=RemoteState(dst_dir, "origin"))
    rows.append(_row(name, "fresh pull (clone)", rep,
                     time.perf_counter() - t0))
    assert rows[1]["objects_transferred"] == 0, \
        f"{name}: unchanged re-push must transfer zero objects"
    for node_name in g.nodes:
        a = g.store.load_artifact(g.nodes[node_name].artifact_ref)
        b = g2.store.load_artifact(g2.nodes[node_name].artifact_ref)
        for k in a.params:
            np.testing.assert_array_equal(np.asarray(a.params[k]),
                                          np.asarray(b.params[k]))
    assert g2.store.fsck([n.artifact_ref for n in g2.nodes.values()
                          if n.artifact_ref])["ok"], f"{name}: clone fsck"
    return rows


def run(scale: int = 1) -> List[Dict]:
    pool, _, _ = g2_adaptation(scale=scale)
    rows: List[Dict] = []
    with tempfile.TemporaryDirectory() as tmp:
        g = _seed(f"{tmp}/src", pool)

        rows += _session("local", g, LocalTransport(f"{tmp}/local-remote"),
                         RemoteState(g.path, "local"), f"{tmp}/local-clone")

        app = HubApp(f"{tmp}/hub-remote")
        server, _ = start_in_thread(app)
        try:
            transport = HttpTransport(server.url)
            rows += _session("http", g, transport,
                             RemoteState(g.path, "hub"), f"{tmp}/http-clone")
            # wire invariant: both remotes ended in the same state
            local_doc = LocalTransport(f"{tmp}/local-remote").fetch_lineage()
            hub_doc, _ = app.lineage()
            assert lineage_etag(hub_doc) == lineage_etag(local_doc), \
                "HTTP push produced a different lineage document"
            local_keys = sorted(
                ArtifactStore(root=f"{tmp}/local-remote").cas.keys())
            assert sorted(app.store.cas.keys()) == local_keys, \
                "HTTP push produced a different object set"
            assert app.fsck()["ok"], "hub-side fsck failed"
            rows.append({"transport": "http", "step": "server stats",
                         **{k: v for k, v in transport.server_stats().items()
                            if k in ("requests", "bytes_in", "bytes_out",
                                     "objects_received", "objects_served")}})
        finally:
            server.shutdown()
            server.server_close()
    return rows


def main() -> List[Dict]:
    rows = run()
    header = (f"{'transport':<9} {'step':<20} {'objects':>12} "
              f"{'bytes':>12} {'dedup':>7} {'s':>8}")
    print(header)
    print("-" * len(header))
    for r in rows:
        if "objects_total" not in r:
            print(f"{r['transport']:<9} {r['step']:<20} "
                  + ", ".join(f"{k}={v}" for k, v in r.items()
                              if k not in ("transport", "step")))
            continue
        objs = f"{r['objects_transferred']}/{r['objects_total']}"
        print(f"{r['transport']:<9} {r['step']:<20} {objs:>12} "
              f"{r['bytes_transferred']:>12} {r['dedup_ratio']:>7.2%} "
              f"{r['seconds']:>8.3f}")
    print("http == local bit-identity: OK; zero-object re-push: OK; "
          "fsck: OK")
    return rows


if __name__ == "__main__":
    main()
