"""Benchmark harness entry: one function per paper table/figure.

Prints a ``name,us_per_call,derived`` CSV summary line per benchmark plus
each benchmark's own table, and writes the machine-readable perf
trajectory CI and future PRs diff against: ``BENCH_PR4.json`` (commit
throughput, warm/cold checkout latency, dedup ratio) and
``BENCH_PR6.json`` (chunk-level dedup, streaming RSS, ranged pull) and
``BENCH_PR7.json`` (serving resident density, hot-swap latency) and
``BENCH_PR8.json`` (observability overhead: disabled-path commit cost) and
``BENCH_PR9.json`` (continuous checkpointing: overhead per cadence/tier,
bytes/step vs full snapshots) and
``BENCH_PR10.json`` (hub under load: live-traffic GC reclaim, replica
reads, saturation throughput and 503 shed rate).
Usage: PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import json
import sys
import time


def _csv(name: str, us: float, derived: str) -> None:
    print(f"CSV,{name},{us:.1f},{derived}")


def main() -> None:
    sys.path.insert(0, ".")
    from benchmarks import (bench_autoconstruct, bench_compression,
                            bench_functionality, bench_insertion,
                            bench_kernels)

    print("=" * 72)
    print("Table 4 — compression ratio / accuracy delta / runtime")
    print("=" * 72)
    t0 = time.perf_counter()
    rows = bench_compression.main()
    lzma_rows = [r for r in rows if r["technique"] == "MGit (LZMA + Hash)"]
    best = max(lzma_rows, key=lambda r: r["ratio"])
    _csv("table4_compression", (time.perf_counter() - t0) * 1e6 / max(len(rows), 1),
         f"best_ratio={best['ratio']:.2f}@{best['graph']}")
    pipe = next(r for r in rows if r["technique"] == "pipeline")
    _csv("pipeline", pipe["pip_commit_s"] * 1e6 / pipe["n_nodes"],
         f"commit_x={pipe['commit_speedup']:.2f},"
         f"checkout_x={pipe['checkout_speedup']:.2f},"
         f"models_per_s={pipe['commit_models_per_s']:.1f}")
    with open("BENCH_PR4.json", "w") as f:
        json.dump({
            "pool": {"n_nodes": pipe["n_nodes"], "d": pipe["d"]},
            "commit": {
                "serial_s": pipe["seq_commit_s"],
                "pipelined_s": pipe["pip_commit_s"],
                "speedup": pipe["commit_speedup"],
                "models_per_s": pipe["commit_models_per_s"],
            },
            "checkout": {
                "warm_serial_s": pipe["seq_warm_checkout_s"],
                "warm_batched_s": pipe["pip_warm_checkout_s"],
                "warm_speedup": pipe["checkout_speedup"],
                "cold_serial_s": pipe["seq_cold_checkout_s"],
                "cold_batched_s": pipe["pip_cold_checkout_s"],
                "cold_speedup": pipe["cold_checkout_speedup"],
            },
            "dedup_ratio": {"serial": pipe["seq_ratio"],
                            "pipelined": pipe["pip_ratio"]},
            "fold": {"depth5_chain_hops": pipe["fold_chain_hops"],
                     "dequants": 1},
        }, f, indent=1)
    print("wrote BENCH_PR4.json")

    print("=" * 72)
    print("Figure 3 — auto-insertion scaling")
    print("=" * 72)
    t0 = time.perf_counter()
    rows = bench_insertion.main()
    _csv("fig3_insertion", rows[-1]["avg_insert_s"] * 1e6,
         f"n={rows[-1]['n_models']}")

    print("=" * 72)
    print("§6.1 — automated graph construction accuracy")
    print("=" * 72)
    t0 = time.perf_counter()
    rows = bench_autoconstruct.main()
    g1 = [r for r in rows if r["graph"] == "G1"]
    _csv("g1_autoconstruct", (time.perf_counter() - t0) * 1e6,
         f"paper={g1[0]['accuracy']:.3f},improved={g1[-1]['accuracy']:.3f}")

    print("=" * 72)
    print("§6.4 — bisect + update cascade")
    print("=" * 72)
    rows = bench_functionality.main()
    _csv("bisect", rows[0]["bisect_s"] * 1e6,
         f"probe_speedup={rows[0]['probe_speedup']:.1f}x")
    _csv("cascade", rows[1]["cascade_s"] * 1e6,
         f"models={rows[1]['created']}")
    _csv("test_sweep", rows[2]["memo_warm_s"] * 1e6,
         f"warm_speedup={rows[2]['warm_speedup']:.1f}x,"
         f"hit_ratio={rows[2]['cache_hit_ratio']:.2f}")

    print("=" * 72)
    print("§4 diagnostics — memoized runner ledger (cache hits, 0-IO warm sweep)")
    print("=" * 72)
    from benchmarks import bench_diag
    row = bench_diag.main(smoke=True)
    _csv("diag_runner", row["warm_s"] * 1e6,
         f"hit_ratio={row['cache_hit_ratio']:.2f},"
         f"speedup={row['speedup']:.1f}x,"
         f"scoped_skips={row['scoped_skips']}")

    print("=" * 72)
    print("§5 collaboration — sync negotiation dedup (objects moved vs total)")
    print("=" * 72)
    from benchmarks import bench_sync
    rows = bench_sync.main()
    incr = next(r for r in rows if r["step"] == "incremental push")
    _csv("sync_dedup", incr["seconds"] * 1e6,
         f"dedup={incr['dedup_ratio']:.2%},"
         f"moved={incr['objects_transferred']}/{incr['objects_total']}")

    print("=" * 72)
    print("§11 hub — HTTP transport vs LocalTransport (bit-identity + wire cost)")
    print("=" * 72)
    from benchmarks import bench_hub
    rows = bench_hub.main()
    http_push = next(r for r in rows if r["transport"] == "http"
                     and r["step"] == "initial push")
    local_push = next(r for r in rows if r["transport"] == "local"
                      and r["step"] == "initial push")
    _csv("hub_http_push", http_push["seconds"] * 1e6,
         f"http_over_local={http_push['seconds']/max(local_push['seconds'], 1e-9):.2f}x,"
         f"bytes={http_push['bytes_transferred']}")

    print("=" * 72)
    print("§12 chunk layer — dedup ratio, streaming RSS, parallel ranged pull")
    print("=" * 72)
    from benchmarks import bench_chunks
    dedup, rss, pull = bench_chunks.main()
    _csv("chunk_dedup", dedup["edit_commit_s"] * 1e6,
         f"added_frac={dedup['added_frac']:.2%},"
         f"chunks={dedup['chunks']}")
    _csv("chunk_rss", rss["commit_s"] * 1e6,
         f"chunked_mb={rss['chunked_rss_delta_mb']},"
         f"dense_mb={rss['dense_rss_delta_mb']},"
         f"budget_mb={rss['rss_budget_mb']}")
    _csv("chunk_pull", pull["parallel_s"] * 1e6,
         f"speedup={pull['speedup']:.2f}x,"
         f"parallel_mb_per_s={pull['parallel_mb_per_s']}")
    with open("BENCH_PR6.json", "w") as f:
        json.dump({
            "edit_dedup": {
                "tensor_mb": dedup["tensor_mb"],
                "added_bytes": dedup["added_bytes"],
                "added_frac": dedup["added_frac"],
                "chunks": dedup["chunks"],
            },
            "streaming_rss": {
                "tensor_mb": rss["tensor_mb"],
                "window_mb": rss["window_mb"],
                "budget_mb": rss["rss_budget_mb"],
                "chunked_delta_mb": rss["chunked_rss_delta_mb"],
                "dense_delta_mb": rss["dense_rss_delta_mb"],
                "commit_mb_per_s": rss["commit_mb_per_s"],
            },
            "ranged_pull": {
                "payload_mb": pull["payload_mb"],
                "rtt_ms": pull["rtt_ms"],
                "link_mb_per_s": pull["link_mb_per_s"],
                "single_s": pull["single_s"],
                "parallel_s": pull["parallel_s"],
                "speedup": pull["speedup"],
            },
        }, f, indent=1)
    print("wrote BENCH_PR6.json")

    print("=" * 72)
    print("§13 lineage-native serving — resident density + hot swap")
    print("=" * 72)
    from benchmarks import bench_serve
    serve = bench_serve.main()
    _csv("serve_density", serve["build_s"] * 1e6 / serve["n_models"],
         f"density_x={serve['density_x']:.2f},"
         f"models_per_gb={serve['models_per_gb_pool']}")
    _csv("serve_swap", serve["swap_mean_s"] * 1e6,
         f"naive_load_us={serve['naive_load_s']*1e6:.1f},"
         f"inflight_errors={serve['inflight_errors']}")
    with open("BENCH_PR7.json", "w") as f:
        json.dump({
            "resident_density": {
                "n_models": serve["n_models"],
                "model_mb": serve["model_mb"],
                "resident_mb": serve["resident_mb"],
                "naive_mb": serve["naive_mb"],
                "density_x": serve["density_x"],
                "models_per_gb_pool": serve["models_per_gb_pool"],
                "models_per_gb_naive": serve["models_per_gb_naive"],
            },
            "hot_swap": {
                "swaps": serve["swaps"],
                "swap_mean_s": serve["swap_mean_s"],
                "swap_max_s": serve["swap_max_s"],
                "naive_load_s": serve["naive_load_s"],
                "inflight_errors": serve["inflight_errors"],
            },
        }, f, indent=1)
    print("wrote BENCH_PR7.json")

    print("=" * 72)
    print("§14 observability — disabled-path overhead on commit throughput")
    print("=" * 72)
    from benchmarks import bench_obs
    obs = bench_obs.main()
    _csv("obs_overhead", obs["commit_disabled_s"] * 1e6 / obs["n_models"],
         f"disabled_pct={obs['disabled_overhead_pct']:.2f},"
         f"bound_pct={obs['disabled_overhead_bound_pct']:.4f},"
         f"span_ns={obs['disabled_span_ns']:.0f}")
    with open("BENCH_PR8.json", "w") as f:
        json.dump({
            "commit_overhead": {
                "n_models": obs["n_models"],
                "stripped_s": obs["commit_stripped_s"],
                "disabled_s": obs["commit_disabled_s"],
                "enabled_s": obs["commit_enabled_s"],
                "disabled_overhead_pct": obs["disabled_overhead_pct"],
                "enabled_overhead_pct": obs["enabled_overhead_pct"],
                "models_per_s_disabled": obs["models_per_s_disabled"],
            },
            "disabled_path": {
                "span_call_ns": obs["disabled_span_ns"],
                "spans_per_commit": obs["spans_per_commit"],
                "overhead_bound_pct": obs["disabled_overhead_bound_pct"],
            },
        }, f, indent=1)
    print("wrote BENCH_PR8.json")

    print("=" * 72)
    print("Storage kernels — CPU wall-time + TPU roofline bound")
    print("=" * 72)
    rows = bench_kernels.main()
    _csv("kernels", rows[0]["cpu_s"] * 1e6,
         f"tpu_bound_us={rows[0]['tpu_roofline_s']*1e6:.1f}")

    print("=" * 72)
    print("§15 continuous checkpointing — commit at training speed")
    print("=" * 72)
    from benchmarks import bench_checkpoint
    ck = bench_checkpoint.main()
    e10 = next(r for r in ck["rows"] if r["config"] == "exact@10")
    l1 = next(r for r in ck["rows"] if r["config"] == "lossy@1")
    _csv("ckpt_overhead", ck["base_step_s"] * 1e6,
         f"exact10_pct={e10['overhead_pct']:.2f},"
         f"lossy1_pct={l1['overhead_pct']:.2f},"
         f"exact10_bytes_ratio={e10['bytes_vs_full_snapshot']:.3f}")
    with open("BENCH_PR9.json", "w") as f:
        json.dump(ck, f, indent=1)
    print("wrote BENCH_PR9.json")

    print("=" * 72)
    print("§16 hub under production load — GC live, replicas, saturation")
    print("=" * 72)
    from benchmarks import bench_hub_load
    hub = bench_hub_load.run(smoke=True)
    _csv("hub_load", hub["push_p50_s"] * 1e6,
         f"ok={hub['ok']},"
         f"reclaimed={hub['gc']['bytes_reclaimed']}"
         f"/floor={hub['gc']['reclaim_floor_bytes']},"
         f"sat_ok_per_s={hub['saturation']['ok_per_s']},"
         f"shed_503={hub['overload']['shed_503']}")
    with open("BENCH_PR10.json", "w") as f:
        json.dump(hub, f, indent=1)
    print("wrote BENCH_PR10.json")

    print("=" * 72)
    print("Roofline (from dry-run artifact, single-pod) — see EXPERIMENTS.md")
    print("=" * 72)
    try:
        from benchmarks import bench_roofline
        table = bench_roofline.main()
        ok = [r for r in table if r["status"] == "ok"]
        if ok:
            avg = sum(r["roofline_frac"] for r in ok) / len(ok)
            _csv("roofline", 0.0, f"cells={len(ok)},avg_compute_frac={avg:.3f}")
    except FileNotFoundError:
        print("experiments/dryrun.json missing — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")


if __name__ == "__main__":
    main()
