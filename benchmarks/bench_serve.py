"""Serving density + hot-swap latency (DESIGN.md §13).

Builds one base with ``N_DERIVATIVES`` single-layer adapters (the sparse
finetune regime the paper's pools model), then measures:

* **resident density** — models/GB with the :class:`ModelPool` (one shared
  base + per-view private deltas) vs naive residency (N independent full
  copies). Invariant: the pool fits **>= 3x** more models per GB.
* **hot-swap latency** — endpoint swap on a warm pool (pointer move) vs a
  naive full checkout of the incoming model. Invariant: swap is faster.
* **zero-drop** — a predict hammer runs through every swap; any failed
  in-flight request fails the benchmark.

Run directly (CI serve-smoke job):
``PYTHONPATH=src:. python -m benchmarks.bench_serve`` — exits non-zero if
an invariant fails.
"""

from __future__ import annotations

import tempfile
import threading
import time
from typing import Dict

import numpy as np

from benchmarks.pools import base_model
from repro.serve import ModelPool, Router
from repro.store import ArtifactStore

N_DERIVATIVES = 12
N_SWAPS = 40


def _adapter(parent, key: str, seed: int, scale=1e-3):
    """One-layer perturbation: the maximally-shareable derivative."""
    rng = np.random.default_rng(seed)
    v = parent.params[key]
    return parent.replace_params(
        {key: (v + rng.normal(scale=scale, size=v.shape)).astype(v.dtype)})


def _build_repo(root: str):
    store = ArtifactStore(root=root)
    base = base_model(seed=0)
    base_ref = store.commit_artifact("base", base)
    keys = [k for k in base.params if k != "head/w"]
    refs = [store.commit_artifact(
        f"ft{i}", _adapter(base, keys[i % len(keys)], seed=100 + i),
        parent_ref=base_ref)
        for i in range(N_DERIVATIVES)]
    return store, base, refs


def _node_payload(ref: str) -> Dict:
    return {"nodes": [{"name": "m", "artifact_ref": ref, "parents": [],
                       "children": [], "version_parents": [],
                       "version_children": [], "metadata": {}}]}


def main() -> Dict:
    with tempfile.TemporaryDirectory() as root:
        store, base, refs = _build_repo(root)
        model_bytes = base.nbytes()

        # -- resident density: pool vs N full copies -----------------------
        pool = ModelPool(store, max_resident=N_DERIVATIVES + 1)
        t0 = time.perf_counter()
        for r in refs:
            pool.get(r)
        build_s = time.perf_counter() - t0
        resident_bytes = pool.base_bytes + pool.private_bytes()
        naive_bytes = model_bytes * N_DERIVATIVES
        density_x = naive_bytes / resident_bytes

        # -- naive load cost: one cold full checkout -----------------------
        store.cache.clear()
        store.fold_cache.clear()
        t0 = time.perf_counter()
        store.materialize_artifact(refs[0])
        naive_load_s = time.perf_counter() - t0

        # -- hot swap on a warm pool, with an in-flight hammer -------------
        router = Router(pool, ["prod=node:m"])
        router.refresh(_node_payload(refs[0]))
        errors, stop = [], threading.Event()

        def hammer():
            while not stop.is_set():
                try:
                    router.predict("prod")
                except Exception as exc:  # noqa: BLE001 — a drop = failure
                    errors.append(exc)
                    return

        worker = threading.Thread(target=hammer)
        worker.start()
        swap_s = []
        for i in range(N_SWAPS):
            t0 = time.perf_counter()
            report = router.refresh(_node_payload(refs[(i + 1) % len(refs)]))
            swap_s.append(time.perf_counter() - t0)
            assert report["prod"]["status"] == "swapped"
        stop.set()
        worker.join(timeout=10)
        swap_mean_s = sum(swap_s) / len(swap_s)

        row = {
            "n_models": N_DERIVATIVES,
            "model_mb": round(model_bytes / 2**20, 3),
            "resident_mb": round(resident_bytes / 2**20, 3),
            "naive_mb": round(naive_bytes / 2**20, 3),
            "density_x": round(density_x, 2),
            "models_per_gb_pool": round(N_DERIVATIVES
                                        / (resident_bytes / 2**30), 1),
            "models_per_gb_naive": round(N_DERIVATIVES
                                         / (naive_bytes / 2**30), 1),
            "build_s": round(build_s, 4),
            "naive_load_s": round(naive_load_s, 6),
            "swap_mean_s": round(swap_mean_s, 6),
            "swap_max_s": round(max(swap_s), 6),
            "swaps": N_SWAPS,
            "inflight_errors": len(errors),
            "params_aliased": pool.stats()["params_aliased"],
        }
        print(f"{'metric':<22}{'value':>14}")
        for k, v in row.items():
            print(f"{k:<22}{v:>14}")

        assert not errors, f"in-flight requests dropped during swap: {errors[0]}"
        assert density_x >= 3.0, \
            f"pool density {density_x:.2f}x < 3x naive residency"
        assert swap_mean_s < naive_load_s, \
            f"warm swap {swap_mean_s:.6f}s not faster than naive " \
            f"load {naive_load_s:.6f}s"
        return row


if __name__ == "__main__":
    main()
