"""Hub under production load: mixed traffic with GC + compaction live,
read-replica fan-out, and worker-pool saturation (DESIGN.md §16).

Phases, all against one multi-tenant :class:`HubService` on loopback:

1. **mixed workload** — writer threads push finetune chains into several
   tenants while reader threads pull them back and verify **bit identity**;
   a maintenance thread runs orphan GC + pack compaction the whole time; a
   read replica mirrors the primary and a ``ReplicaSetTransport`` client
   fans its reads across it. Per-op p50/p99 latencies are reported.
2. **GC under live traffic** — a scratch tenant with strictly private
   payload is deleted mid-traffic; maintenance cycles must reclaim at
   least those private bytes without a single bit-identity failure.
3. **saturation** — a thread storm against a deliberately small worker
   pool; reports sustained 200-throughput and the shed (503) rate, and
   requires zero 500s.

Exit is non-zero if any invariant fails: bit-identity, fsck-clean primary
AND replica, reclaim floor, zero 500s. Writes ``BENCH_PR10.json``.

Usage: ``PYTHONPATH=src:. python -m benchmarks.bench_hub_load [--smoke]``
"""

from __future__ import annotations

import argparse
import http.client
import json
import statistics
import sys
import tempfile
import threading
import time
from typing import Dict, List
from urllib.parse import urlsplit

import numpy as np

from benchmarks.pools import base_model, finetune
from repro.core import LineageGraph
from repro.hub import HubService, start_in_thread
from repro.hub.replica import ReplicaHub, ReplicaSetTransport
from repro.remote import HttpTransport, RemoteState, pull, push
from repro.store import ArtifactStore

TENANTS = ("alpha", "beta", "gamma", "delta")


def _repo(path: str) -> LineageGraph:
    return LineageGraph(path=path, store=ArtifactStore(root=path))


def _pct(samples: List[float], q: float) -> float:
    if not samples:
        return 0.0
    s = sorted(samples)
    return s[min(len(s) - 1, int(q * len(s)))]


def _verify_pull(src: LineageGraph, dst: LineageGraph, names) -> int:
    bad = 0
    for name in names:
        a = src.store.load_artifact(src.nodes[name].artifact_ref)
        b = dst.store.load_artifact(dst.nodes[name].artifact_ref)
        for k in a.params:
            if not np.array_equal(np.asarray(a.params[k]),
                                  np.asarray(b.params[k])):
                bad += 1
    return bad


def run(smoke: bool = False) -> Dict:
    writers = 4 if smoke else 8
    chain = 2 if smoke else 4
    d = 64 if smoke else 128
    storm_threads = 24 if smoke else 64
    storm_s = 2.0 if smoke else 6.0

    out: Dict = {"mode": "smoke" if smoke else "full"}
    errors: List[str] = []
    lat: Dict[str, List[float]] = {"push": [], "pull": []}
    lat_lock = threading.Lock()
    bit_failures = [0]

    with tempfile.TemporaryDirectory() as tmp:
        service = HubService(f"{tmp}/hub")
        server, _ = start_in_thread(service, max_workers=16, queue_depth=64)
        replica = ReplicaHub(f"{tmp}/replica", server.url)
        rserver, _ = start_in_thread(replica.service)

        stop = threading.Event()
        maint_stats = {"gc_runs": 0, "reclaimed": 0, "compactions": 0}

        def maintenance():
            while not stop.is_set():
                try:
                    rep = service.run_gc()
                    maint_stats["gc_runs"] += 1
                    maint_stats["reclaimed"] += rep["reclaimed_bytes"]
                    if service.compact()["ran"]:
                        maint_stats["compactions"] += 1
                    replica.sync_once()
                except Exception as exc:  # noqa: BLE001 - recorded, not fatal
                    errors.append(f"maintenance: {exc}")
                stop.wait(0.1)

        def writer(i: int) -> None:
            tenant = TENANTS[i % len(TENANTS)]
            try:
                g = _repo(f"{tmp}/w{i}")
                art = base_model(seed=i, n_layers=3, d=d)
                g.add_node(art, f"w{i}@v1")
                for v in range(2, chain + 2):
                    art = finetune(art, seed=100 * i + v)
                    g.add_node(art, f"w{i}@v{v}")
                t = HttpTransport(f"{server.url}/r/{tenant}",
                                  retries=6, backoff=0.05)
                t0 = time.perf_counter()
                push(g, t, state=RemoteState(g.path, "origin"))
                with lat_lock:
                    lat["push"].append(time.perf_counter() - t0)
                # read back through the replica set (stale -> primary)
                rs = ReplicaSetTransport(
                    HttpTransport(f"{server.url}/r/{tenant}",
                                  retries=6, backoff=0.05),
                    [HttpTransport(f"{rserver.url}/r/{tenant}",
                                   retries=2, backoff=0.05)])
                g2 = _repo(f"{tmp}/r{i}")
                t0 = time.perf_counter()
                pull(g2, rs)
                with lat_lock:
                    lat["pull"].append(time.perf_counter() - t0)
                bad = _verify_pull(
                    g, g2, [f"w{i}@v{v}" for v in range(1, chain + 2)])
                with lat_lock:
                    bit_failures[0] += bad
                    out["replica_reads"] = (out.get("replica_reads", 0)
                                            + rs.replica_reads)
                    out["replica_fallbacks"] = (out.get("replica_fallbacks", 0)
                                                + rs.fallbacks)
            except Exception as exc:  # noqa: BLE001
                errors.append(f"writer {i}: {exc}")

        # -- phase 1+2: mixed workload with maintenance + replica live -------
        maint = threading.Thread(target=maintenance, daemon=True)
        maint.start()

        # scratch tenant whose private bytes must be reclaimed once deleted
        gs = _repo(f"{tmp}/scratch")
        gs.add_node(base_model(seed=991, n_layers=3, d=d, prefix="S"),
                    "scratch@v1")
        push(gs, HttpTransport(f"{server.url}/r/scratch",
                               retries=6, backoff=0.05),
             state=RemoteState(gs.path, "origin"))
        cas = service.store.cas
        scratch_keys = set(
            service.store.expected_refcounts(
                service.repo("scratch").roots()))
        shared = set()
        for name in service.repo_names():
            if name != "scratch":
                shared |= set(service.store.expected_refcounts(
                    service.repo(name).roots()))
        private = scratch_keys - shared
        private_bytes = sum(cas.size(k) for k in private if cas.has(k))

        t_phase = time.perf_counter()
        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(writers)]
        for t in threads:
            t.start()
        service.delete_repo("scratch")          # GC target, mid-traffic
        for t in threads:
            t.join()
        mixed_s = time.perf_counter() - t_phase

        # the deleted tenant must be reclaimed BY THE LIVE maintenance loop,
        # while worker traffic is (or was just) in flight — that is the §16
        # acceptance; the post-stop cycle only mops up writer debris
        deadline = time.time() + (10 if smoke else 30)
        while (maint_stats["reclaimed"] < private_bytes
               and time.time() < deadline):
            time.sleep(0.1)
        out["reclaimed_live_bytes"] = maint_stats["reclaimed"]
        stop.set()
        maint.join(10)
        rep = service.run_gc(grace=0, confirm_cycles=1)
        maint_stats["reclaimed"] += rep["reclaimed_bytes"]
        replica.sync_once()                     # converge the mirror

        # -- phase 3: saturation storm ---------------------------------------
        host = urlsplit(server.url)
        codes: Dict[int, int] = {}
        codes_lock = threading.Lock()
        t_storm = time.perf_counter()

        def storm():
            end = t_storm + storm_s
            while time.perf_counter() < end:
                try:
                    conn = http.client.HTTPConnection(host.hostname,
                                                      host.port, timeout=10)
                    conn.request("GET", "/api/ping")
                    resp = conn.getresponse()
                    resp.read()
                    with codes_lock:
                        codes[resp.status] = codes.get(resp.status, 0) + 1
                    conn.close()
                except OSError:
                    with codes_lock:
                        codes[-1] = codes.get(-1, 0) + 1

        storm_pool = [threading.Thread(target=storm)
                      for _ in range(storm_threads)]
        for t in storm_pool:
            t.start()
        for t in storm_pool:
            t.join()
        storm_elapsed = time.perf_counter() - t_storm

        # -- phase 4: forced overload against a deliberately tiny pool -------
        # same service, second listener: 4 slots + simulated 20ms RTT, so a
        # 24-thread storm MUST shed — proves 503 + Retry-After under
        # saturation rather than unbounded queueing
        small, _ = start_in_thread(service, max_workers=2, queue_depth=2)
        small.delay_s = 0.02
        shost = urlsplit(small.url)
        shed_codes: Dict[int, int] = {}

        def shed_storm():
            end = time.perf_counter() + 1.0
            while time.perf_counter() < end:
                try:
                    conn = http.client.HTTPConnection(shost.hostname,
                                                      shost.port, timeout=10)
                    conn.request("GET", "/api/ping")
                    resp = conn.getresponse()
                    resp.read()
                    with codes_lock:
                        shed_codes[resp.status] = \
                            shed_codes.get(resp.status, 0) + 1
                    conn.close()
                except OSError:
                    with codes_lock:
                        shed_codes[-1] = shed_codes.get(-1, 0) + 1

        shed_pool = [threading.Thread(target=shed_storm) for _ in range(24)]
        for t in shed_pool:
            t.start()
        for t in shed_pool:
            t.join()
        small.shutdown()
        small.server_close()

        stats = service.default.stats
        fsck_primary = service.fsck()
        fsck_replica = replica.service.fsck()

        out.update({
            "writers": writers,
            "mixed_workload_s": round(mixed_s, 3),
            "push_p50_s": round(_pct(lat["push"], 0.50), 4),
            "push_p99_s": round(_pct(lat["push"], 0.99), 4),
            "pull_p50_s": round(_pct(lat["pull"], 0.50), 4),
            "pull_p99_s": round(_pct(lat["pull"], 0.99), 4),
            "gc": {
                "runs": maint_stats["gc_runs"],
                "bytes_reclaimed": maint_stats["reclaimed"],
                "reclaim_floor_bytes": private_bytes,
                "compactions": maint_stats["compactions"],
            },
            "saturation": {
                "threads": storm_threads,
                "seconds": round(storm_elapsed, 3),
                "ok_per_s": round(codes.get(200, 0) / storm_elapsed, 1),
                "shed_503": codes.get(503, 0),
                "conn_errors": codes.get(-1, 0),
            },
            "overload": {
                "served_200": shed_codes.get(200, 0),
                "shed_503": shed_codes.get(503, 0),
                "conn_errors": shed_codes.get(-1, 0),
            },
            "bit_identity_failures": bit_failures[0],
            "errors_500": stats["errors_500"],
            "sheds_503_total": stats["sheds_503"],
            "fsck_primary_ok": bool(fsck_primary["ok"]),
            "fsck_replica_ok": bool(fsck_replica["ok"]),
            "worker_errors": errors,
        })

        server.shutdown()
        server.server_close()
        rserver.shutdown()
        rserver.server_close()

    ok = (not errors
          and bit_failures[0] == 0
          and out["errors_500"] == 0
          and out["fsck_primary_ok"] and out["fsck_replica_ok"]
          and maint_stats["reclaimed"] >= private_bytes
          and codes.get(200, 0) > 0
          and shed_codes.get(200, 0) > 0
          and shed_codes.get(503, 0) > 0)
    out["ok"] = ok
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (hub-load-smoke job)")
    ap.add_argument("--out", default="BENCH_PR10.json")
    args = ap.parse_args(argv)
    report = run(smoke=args.smoke)
    print(json.dumps(report, indent=1))
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")
    if not report["ok"]:
        print("FAIL: hub load invariants violated", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
