"""Storage-kernel microbenchmarks + analytic TPU roofline for each kernel.

Wall-times here are the CPU oracle path (the production CPU fallback);
the Pallas kernels are validated in interpret mode (tests) and characterized
analytically for TPU v5e: all three kernels are pure HBM-streaming
(arithmetic intensity << 1 FLOP/byte), so the roofline bound is bytes/819GB/s.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

HBM_BW = 819e9

SIZES = [(1 << 20,), (1 << 24,)]  # 4MB, 64MB fp32 tensors


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run() -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    for (n,) in SIZES:
        p2 = jnp.asarray(rng.normal(size=n), jnp.float32)
        p1 = p2 + jnp.asarray(rng.normal(scale=1e-4, size=n) *
                              (rng.random(n) < 0.3), jnp.float32)

        t = _time(lambda a, b: ops.delta_quantize(a, b, backend="ref")[0], p1, p2)
        bytes_moved = n * 4 * 3  # read p1, p2; write q
        rows.append({"kernel": "delta_quantize", "n": n, "cpu_s": t,
                     "tpu_roofline_s": bytes_moved / HBM_BW,
                     "bytes": bytes_moved})

        q, _ = ops.delta_quantize(p1, p2, backend="ref")
        t = _time(lambda a, b: ops.dequant_apply(a, b, backend="ref"), p1, q)
        rows.append({"kernel": "dequant_apply", "n": n, "cpu_s": t,
                     "tpu_roofline_s": bytes_moved / HBM_BW,
                     "bytes": bytes_moved})

        t = _time(lambda a: ops.fingerprint(a, backend="ref"), p1)
        rows.append({"kernel": "fingerprint", "n": n, "cpu_s": t,
                     "tpu_roofline_s": n * 4 / HBM_BW, "bytes": n * 4})

        # fused snapshot (§Perf-C): delta+quantize+fingerprint, int8 out
        t = _time(lambda a, b: ops.snapshot_fused(a, b, backend="ref")[0],
                  p1, p2)
        fused_bytes = n * (4 + 4 + 1)   # read p1+p2, write int8 q
        rows.append({"kernel": "snapshot_fused", "n": n, "cpu_s": t,
                     "tpu_roofline_s": fused_bytes / HBM_BW,
                     "bytes": fused_bytes})
    return rows


def main():
    rows = run()
    print(f"{'kernel':16} {'elems':>9} {'cpu_ms':>9} {'tpu_bound_us':>13} "
          f"{'MB':>7}")
    for r in rows:
        print(f"{r['kernel']:16} {r['n']:9d} {r['cpu_s']*1e3:9.2f} "
              f"{r['tpu_roofline_s']*1e6:13.1f} {r['bytes']/1e6:7.1f}")
    return rows


if __name__ == "__main__":
    main()
