"""Paper Table 4: compression ratio / accuracy delta / per-model runtime for
every (graph x technique) combination.

Techniques:
  MGit (LZMA + Hash)      delta compression with LZMA + content hashing
  MGit (RLE + Hash)       delta compression with RLE + content hashing
  MGit (Hash)             content-based hashing only (lossless)
  Full                    quantize + LZMA of FULL models (no deltas)
  Full w/o quantization   LZMA of raw full models
  MGit (sparse + Hash)    beyond-paper sparse codec
"""

from __future__ import annotations

import lzma
import time
from typing import Dict, List

import numpy as np

from benchmarks.pools import GRAPHS
from repro.core import LineageGraph
from repro.core.lineage import RegisteredTest
from repro.kernels import ops
from repro.kernels.ref import quant_scale
from repro.store import ArtifactStore

from repro.core.artifact import ModelArtifact


def probe_score(model: ModelArtifact) -> float:
    """Deterministic accuracy stand-in: probe activations through the chain."""
    first = next(iter(model.params))
    d = model.params[first].shape[0]
    x = np.linspace(-1, 1, 2 * d, dtype=np.float32).reshape(2, d)
    for name in model.graph.topo_order():
        w = model.params.get(f"{name}/w")
        if w is None or w.shape[0] != x.shape[1]:
            continue
        x = np.tanh(x @ w)
    return float(np.mean(np.abs(x)) * 100)


def _full_codec_baseline(pool, quantize: bool, eps: float = 1e-4):
    """'Full' rows: LZMA over (optionally quantized) full models."""
    raw = comp = 0
    acc_deltas = []
    t0 = time.perf_counter()
    for _, m in pool:
        before = probe_score(m)
        rec_params = {}
        for k, v in m.params.items():
            raw += v.nbytes
            if quantize:
                q = np.floor(v / quant_scale(eps) + 0.5).astype(np.int32)
                comp += len(lzma.compress(q.tobytes(), preset=1))
                rec_params[k] = (q * quant_scale(eps)).astype(v.dtype)
            else:
                comp += len(lzma.compress(np.ascontiguousarray(v).tobytes(),
                                          preset=1))
                rec_params[k] = v
        after = probe_score(m.replace_params(rec_params))
        acc_deltas.append(abs(after - before))
    dt = time.perf_counter() - t0
    return {"ratio": raw / comp, "acc_max": max(acc_deltas),
            "acc_avg": float(np.mean(acc_deltas)),
            "s_per_model": dt / len(pool)}


def _mgit_run(pool, gold, codec: str, delta: bool, tmp=None):
    store = ArtifactStore(root=tmp, codec=codec, t_thr=float("inf"),
                          delta_enabled=delta)
    g = LineageGraph(store=store)
    g.tests.append(RegisteredTest(name="probe", fn=probe_score,
                                  model_type="toy"))
    acc_deltas = []
    t0 = time.perf_counter()
    for name, m in pool:
        parent = gold.get(name)
        if parent is not None and parent in g.nodes:
            g.add_edge(parent, name)
        g.add_node(m, name)
        before = probe_score(m)
        after = probe_score(g.get_model(name))
        acc_deltas.append(abs(after - before))
    dt = time.perf_counter() - t0
    return {"ratio": store.compression_ratio(), "acc_max": max(acc_deltas),
            "acc_avg": float(np.mean(acc_deltas)),
            "s_per_model": dt / len(pool)}


def bench_chain_reconstruction(depth: int = 8, d: int = 256,
                               repeats: int = 20) -> Dict[str, float]:
    """Plan-based lazy engine vs the eager recursive loader on a deep chain.

    Builds a ``depth``-long delta chain, then repeatedly reconstructs the
    chain tip both ways:
      * ``eager``: ``load_artifact_recursive`` — materializes every FULL
        ancestor artifact per load (the pre-plan reference path);
      * ``lazy``: per-parameter plan execution through the byte-budget tensor
        cache (``load_artifact`` + param access).
    Also reports single-parameter access cost: bytes materialized to produce
    ONE tensor from the chain tip, cold, vs the full-model bytes the eager
    path forces.
    """
    import tempfile

    from benchmarks.pools import base_model, finetune

    with tempfile.TemporaryDirectory() as tmp:
        store = ArtifactStore(root=tmp, codec="lzma", t_thr=float("inf"),
                              max_chain_depth=depth)
        model = base_model(seed=0, d=d)
        refs = [store.commit_artifact("v0", model)]
        for v in range(1, depth + 1):
            model = finetune(model, seed=v)
            refs.append(store.commit_artifact(f"v{v}", model,
                                              parent_ref=refs[-1]))
        tip = refs[-1]
        model_bytes = store.load_artifact(tip).nbytes()

        # cold single-param access through the plan engine
        store.cache.clear()
        store.reset_io_stats()
        art = store.load_artifact(tip)
        key = next(iter(art.params))
        art.params[key]
        single_param_bytes = store.io_stats["bytes_materialized"]

        t0 = time.perf_counter()
        for _ in range(repeats):
            eager = store.load_artifact_recursive(tip)
            for k in eager.params:
                np.asarray(eager.params[k])
        t_eager = time.perf_counter() - t0

        store.cache.clear()
        t0 = time.perf_counter()
        for _ in range(repeats):
            lazy = store.load_artifact(tip)
            for k in lazy.params:
                np.asarray(lazy.params[k])
        t_lazy = time.perf_counter() - t0

    return {
        "depth": depth,
        "repeats": repeats,
        "eager_s": t_eager,
        "lazy_s": t_lazy,
        "speedup": t_eager / max(t_lazy, 1e-9),
        "model_bytes": model_bytes,
        # peak-materialization comparison for ONE parameter at the chain tip:
        # the plan engine touches O(tensor x depth); the recursive loader
        # forces O(model x depth)
        "single_param_bytes": single_param_bytes,
        "eager_chain_bytes": model_bytes * (depth + 1),
    }


def run(graphs: List[str] = ("G1", "G2", "G3", "G4", "G5")) -> List[Dict]:
    rows = []
    for gname in graphs:
        pool, gold, gtype = GRAPHS[gname]()
        techniques = {
            "MGit (LZMA + Hash)": lambda: _mgit_run(pool, gold, "lzma", True),
            "MGit (RLE + Hash)": lambda: _mgit_run(pool, gold, "rle", True),
            "MGit (sparse + Hash)": lambda: _mgit_run(pool, gold, "sparse", True),
            "MGit (Hash)": lambda: _mgit_run(pool, gold, "raw", False),
            "Full": lambda: _full_codec_baseline(pool, quantize=True),
            "Full w/o quantization": lambda: _full_codec_baseline(pool, quantize=False),
        }
        if gname == "G5":  # paper reports Hash only for G5
            techniques = {"MGit (Hash)": techniques["MGit (Hash)"],
                          "MGit (LZMA + Hash)": techniques["MGit (LZMA + Hash)"]}
        for tech, fn in techniques.items():
            r = fn()
            rows.append({"graph": gname, "type": gtype, "technique": tech, **r})
    return rows


def main():
    rows = run()
    print(f"{'graph':5} {'technique':24} {'ratio':>7} {'accD_max':>9} "
          f"{'accD_avg':>9} {'s/model':>8}")
    for r in rows:
        print(f"{r['graph']:5} {r['technique']:24} {r['ratio']:7.2f} "
              f"{r['acc_max']:9.4f} {r['acc_avg']:9.4f} {r['s_per_model']:8.2f}")
    chain = bench_chain_reconstruction()
    print(f"\nchain reconstruction (depth={chain['depth']}, "
          f"x{chain['repeats']} repeats):")
    print(f"  eager recursive: {chain['eager_s']:.3f}s   "
          f"lazy plan engine: {chain['lazy_s']:.3f}s   "
          f"speedup: {chain['speedup']:.1f}x")
    print(f"  single-param cold access: {chain['single_param_bytes']:,} bytes "
          f"materialized (tensor x chain) vs {chain['eager_chain_bytes']:,} "
          f"(model x chain) on the eager path")
    return rows + [{"technique": "chain_reconstruction", **chain}]


if __name__ == "__main__":
    main()
