"""Paper Table 4: compression ratio / accuracy delta / per-model runtime for
every (graph x technique) combination.

Techniques:
  MGit (LZMA + Hash)      delta compression with LZMA + content hashing
  MGit (RLE + Hash)       delta compression with RLE + content hashing
  MGit (Hash)             content-based hashing only (lossless)
  Full                    quantize + LZMA of FULL models (no deltas)
  Full w/o quantization   LZMA of raw full models
  MGit (sparse + Hash)    beyond-paper sparse codec
"""

from __future__ import annotations

import lzma
import time
from typing import Dict, List

import numpy as np

from benchmarks.pools import GRAPHS
from repro.core import LineageGraph
from repro.core.lineage import RegisteredTest
from repro.kernels import ops
from repro.kernels.ref import quant_scale
from repro.store import ArtifactStore

from repro.core.artifact import ModelArtifact


def probe_score(model: ModelArtifact) -> float:
    """Deterministic accuracy stand-in: probe activations through the chain."""
    first = next(iter(model.params))
    d = model.params[first].shape[0]
    x = np.linspace(-1, 1, 2 * d, dtype=np.float32).reshape(2, d)
    for name in model.graph.topo_order():
        w = model.params.get(f"{name}/w")
        if w is None or w.shape[0] != x.shape[1]:
            continue
        x = np.tanh(x @ w)
    return float(np.mean(np.abs(x)) * 100)


def _full_codec_baseline(pool, quantize: bool, eps: float = 1e-4):
    """'Full' rows: LZMA over (optionally quantized) full models."""
    raw = comp = 0
    acc_deltas = []
    t0 = time.perf_counter()
    for _, m in pool:
        before = probe_score(m)
        rec_params = {}
        for k, v in m.params.items():
            raw += v.nbytes
            if quantize:
                q = np.floor(v / quant_scale(eps) + 0.5).astype(np.int32)
                comp += len(lzma.compress(q.tobytes(), preset=1))
                rec_params[k] = (q * quant_scale(eps)).astype(v.dtype)
            else:
                comp += len(lzma.compress(np.ascontiguousarray(v).tobytes(),
                                          preset=1))
                rec_params[k] = v
        after = probe_score(m.replace_params(rec_params))
        acc_deltas.append(abs(after - before))
    dt = time.perf_counter() - t0
    return {"ratio": raw / comp, "acc_max": max(acc_deltas),
            "acc_avg": float(np.mean(acc_deltas)),
            "s_per_model": dt / len(pool)}


def _mgit_run(pool, gold, codec: str, delta: bool, tmp=None):
    store = ArtifactStore(root=tmp, codec=codec, t_thr=float("inf"),
                          delta_enabled=delta)
    g = LineageGraph(store=store)
    g.tests.append(RegisteredTest(name="probe", fn=probe_score,
                                  model_type="toy"))
    acc_deltas = []
    t0 = time.perf_counter()
    for name, m in pool:
        parent = gold.get(name)
        if parent is not None and parent in g.nodes:
            g.add_edge(parent, name)
        g.add_node(m, name)
        before = probe_score(m)
        after = probe_score(g.get_model(name))
        acc_deltas.append(abs(after - before))
    dt = time.perf_counter() - t0
    return {"ratio": store.compression_ratio(), "acc_max": max(acc_deltas),
            "acc_avg": float(np.mean(acc_deltas)),
            "s_per_model": dt / len(pool)}


def bench_chain_reconstruction(depth: int = 8, d: int = 256,
                               repeats: int = 20) -> Dict[str, float]:
    """Plan-based lazy engine vs the eager recursive loader on a deep chain.

    Builds a ``depth``-long delta chain, then repeatedly reconstructs the
    chain tip both ways:
      * ``eager``: ``load_artifact_recursive`` — materializes every FULL
        ancestor artifact per load (the pre-plan reference path);
      * ``lazy``: per-parameter plan execution through the byte-budget tensor
        cache (``load_artifact`` + param access).
    Also reports single-parameter access cost: bytes materialized to produce
    ONE tensor from the chain tip, cold, vs the full-model bytes the eager
    path forces.
    """
    import tempfile

    from benchmarks.pools import base_model, finetune

    with tempfile.TemporaryDirectory() as tmp:
        store = ArtifactStore(root=tmp, codec="lzma", t_thr=float("inf"),
                              max_chain_depth=depth)
        model = base_model(seed=0, d=d)
        refs = [store.commit_artifact("v0", model)]
        for v in range(1, depth + 1):
            model = finetune(model, seed=v)
            refs.append(store.commit_artifact(f"v{v}", model,
                                              parent_ref=refs[-1]))
        tip = refs[-1]
        model_bytes = store.load_artifact(tip).nbytes()

        # cold single-param access through the plan engine
        store.cache.clear()
        store.reset_io_stats()
        art = store.load_artifact(tip)
        key = next(iter(art.params))
        art.params[key]
        single_param_bytes = store.io_stats["bytes_materialized"]

        t0 = time.perf_counter()
        for _ in range(repeats):
            eager = store.load_artifact_recursive(tip)
            for k in eager.params:
                np.asarray(eager.params[k])
        t_eager = time.perf_counter() - t0

        store.cache.clear()
        t0 = time.perf_counter()
        for _ in range(repeats):
            lazy = store.load_artifact(tip)
            for k in lazy.params:
                np.asarray(lazy.params[k])
        t_lazy = time.perf_counter() - t0

    return {
        "depth": depth,
        "repeats": repeats,
        "eager_s": t_eager,
        "lazy_s": t_lazy,
        "speedup": t_eager / max(t_lazy, 1e-9),
        "model_bytes": model_bytes,
        # peak-materialization comparison for ONE parameter at the chain tip:
        # the plan engine touches O(tensor x depth); the recursive loader
        # forces O(model x depth)
        "single_param_bytes": single_param_bytes,
        "eager_chain_bytes": model_bytes * (depth + 1),
    }


def _chain_pool(n: int = 20, d: int = 256):
    """n-node finetune chain (the PR-4 throughput pool)."""
    from benchmarks.pools import base_model, finetune
    m = base_model(seed=0, d=d)
    pool = [("v0", m)]
    for i in range(1, n):
        m = finetune(m, seed=i)
        pool.append((f"v{i}", m))
    return pool


def bench_pipeline(n_nodes: int = 20, d: int = 256, reps: int = 3,
                   smoke: bool = False) -> Dict[str, float]:
    """Pipelined/batched engines vs the serial baseline (DESIGN.md §10).

    Commits an ``n_nodes`` finetune chain through both engines and
    re-materializes a deep-chain tip, reporting best-of-``reps`` wall
    times (min is robust to scheduler noise on shared CI boxes). Asserts
    the §10 invariants while it's at it:

    * batched ``materialize_artifact`` is bit-identical to per-param
      ``materialize_param`` on store-loaded values;
    * a depth-5 same-eps chain folds into ONE dequant (``io_stats``);
    * ``fsck`` is clean after pipelined commits + gc.
    """
    import tempfile

    from repro.store import ArtifactStore

    if smoke:
        n_nodes, d, reps = min(n_nodes, 8), min(d, 128), 2
    pool = _chain_pool(n_nodes, d)
    depth_cap = 8
    tip_index = depth_cap  # deepest chain node in the pool
    out: Dict[str, float] = {"n_nodes": n_nodes, "d": d}

    def one_run(pipelined: bool):
        with tempfile.TemporaryDirectory() as tmp:
            store = ArtifactStore(root=tmp, t_thr=float("inf"),
                                  max_chain_depth=depth_cap,
                                  pipelined=pipelined,
                                  fold_enabled=pipelined)
            t0 = time.perf_counter()
            refs = [store.commit_artifact("v0", pool[0][1])]
            for name, m in pool[1:]:
                refs.append(store.commit_artifact(name, m,
                                                  parent_ref=refs[-1]))
            commit_s = time.perf_counter() - t0
            tip = refs[min(tip_index, len(refs) - 1)]

            # warm checkout: OS cache + manifests hot, tensor caches cold
            t0 = time.perf_counter()
            for _ in range(3):
                store.cache.clear()
                store.fold_cache.clear()
                if pipelined:
                    art = store.materialize_artifact(tip)
                else:
                    art = store.load_artifact(tip)
                    for k in art.params:
                        art.params[k]
            warm_s = (time.perf_counter() - t0) / 3
            ratio = store.compression_ratio()

            # cold checkout: a fresh store process (no manifest cache, no
            # tensor/fold caches; OS page cache stays warm)
            store2 = ArtifactStore(root=tmp, t_thr=float("inf"),
                                   max_chain_depth=depth_cap,
                                   pipelined=pipelined,
                                   fold_enabled=pipelined)
            t0 = time.perf_counter()
            if pipelined:
                store2.materialize_artifact(tip)
            else:
                art = store2.load_artifact(tip)
                for k in art.params:
                    art.params[k]
            cold_s = time.perf_counter() - t0

            extras = {}
            if pipelined:
                # invariant: batch == per-param, both store-loaded
                batch = store.materialize_artifact(tip)
                store.cache.clear()
                store.fold_cache.clear()
                for k in batch.params:
                    pp = store.materialize_param(tip, k)
                    assert np.array_equal(np.asarray(batch.params[k]), pp), k
                # invariant: same-eps chain folds to ONE dequant per param
                store.cache.clear()
                store.fold_cache.clear()
                store.reset_io_stats()
                depth5 = refs[min(5, len(refs) - 1)]
                store.materialize_param(depth5, next(iter(batch.params)))
                io = store.io_stats
                assert io["dequant_calls"] == 1, io
                extras["fold_chain_hops"] = io["chain_hops"]
                # invariant: fsck clean after pipelined commit + gc
                store.gc()
                rep = store.fsck(roots=refs)
                assert rep["ok"], {k: rep[k] for k in
                                   ("corrupt", "missing_objects",
                                    "refcount_drift")}
            return commit_s, warm_s, cold_s, ratio, extras

    seq = [one_run(False) for _ in range(reps)]
    pip = [one_run(True) for _ in range(reps)]
    out["seq_commit_s"] = min(r[0] for r in seq)
    out["pip_commit_s"] = min(r[0] for r in pip)
    out["seq_warm_checkout_s"] = min(r[1] for r in seq)
    out["pip_warm_checkout_s"] = min(r[1] for r in pip)
    out["seq_cold_checkout_s"] = min(r[2] for r in seq)
    out["pip_cold_checkout_s"] = min(r[2] for r in pip)
    out["seq_ratio"] = seq[0][3]
    out["pip_ratio"] = pip[0][3]
    out["commit_speedup"] = out["seq_commit_s"] / out["pip_commit_s"]
    out["checkout_speedup"] = (out["seq_warm_checkout_s"]
                               / out["pip_warm_checkout_s"])
    out["cold_checkout_speedup"] = (out["seq_cold_checkout_s"]
                                    / out["pip_cold_checkout_s"])
    out["commit_models_per_s"] = n_nodes / out["pip_commit_s"]
    out.update(pip[0][4])
    return out


def bench_lzma_presets(d: int = 256) -> List[Dict]:
    """Satellite: ratio/speed tradeoff of the configurable LZMA preset."""
    import lzma

    from benchmarks.pools import base_model, finetune
    from repro.store.delta import host_snapshot

    parent = base_model(seed=0, d=d)
    child = finetune(parent, seed=1)
    rows = []
    for preset in (0, 1, 6):
        enc = dec = raw = comp = 0.0
        for k in parent.params:
            q, _, _ = host_snapshot(np.asarray(parent.params[k]),
                                    np.asarray(child.params[k]), 1e-4)
            data = np.ascontiguousarray(q).tobytes()
            t0 = time.perf_counter()
            blob = lzma.compress(data, preset=preset)
            enc += time.perf_counter() - t0
            t0 = time.perf_counter()
            lzma.decompress(blob)
            dec += time.perf_counter() - t0
            raw += len(data)
            comp += len(blob)
        rows.append({"preset": preset, "ratio": raw / comp,
                     "encode_s": enc, "decode_s": dec})
    return rows


def run(graphs: List[str] = ("G1", "G2", "G3", "G4", "G5")) -> List[Dict]:
    rows = []
    for gname in graphs:
        pool, gold, gtype = GRAPHS[gname]()
        techniques = {
            "MGit (LZMA + Hash)": lambda: _mgit_run(pool, gold, "lzma", True),
            "MGit (RLE + Hash)": lambda: _mgit_run(pool, gold, "rle", True),
            "MGit (sparse + Hash)": lambda: _mgit_run(pool, gold, "sparse", True),
            "MGit (Hash)": lambda: _mgit_run(pool, gold, "raw", False),
            "Full": lambda: _full_codec_baseline(pool, quantize=True),
            "Full w/o quantization": lambda: _full_codec_baseline(pool, quantize=False),
        }
        if gname == "G5":  # paper reports Hash only for G5
            techniques = {"MGit (Hash)": techniques["MGit (Hash)"],
                          "MGit (LZMA + Hash)": techniques["MGit (LZMA + Hash)"]}
        for tech, fn in techniques.items():
            r = fn()
            rows.append({"graph": gname, "type": gtype, "technique": tech, **r})
    return rows


def main():
    rows = run()
    print(f"{'graph':5} {'technique':24} {'ratio':>7} {'accD_max':>9} "
          f"{'accD_avg':>9} {'s/model':>8}")
    for r in rows:
        print(f"{r['graph']:5} {r['technique']:24} {r['ratio']:7.2f} "
              f"{r['acc_max']:9.4f} {r['acc_avg']:9.4f} {r['s_per_model']:8.2f}")
    chain = bench_chain_reconstruction()
    print(f"\nchain reconstruction (depth={chain['depth']}, "
          f"x{chain['repeats']} repeats):")
    print(f"  eager recursive: {chain['eager_s']:.3f}s   "
          f"lazy plan engine: {chain['lazy_s']:.3f}s   "
          f"speedup: {chain['speedup']:.1f}x")
    print(f"  single-param cold access: {chain['single_param_bytes']:,} bytes "
          f"materialized (tensor x chain) vs {chain['eager_chain_bytes']:,} "
          f"(model x chain) on the eager path")
    pipe = bench_pipeline()
    print(f"\npipelined commit & batched checkout "
          f"({pipe['n_nodes']}-node pool, d={pipe['d']}):")
    print(f"  commit:   serial {pipe['seq_commit_s']:.2f}s vs pipelined "
          f"{pipe['pip_commit_s']:.2f}s = {pipe['commit_speedup']:.2f}x "
          f"({pipe['commit_models_per_s']:.1f} models/s)")
    print(f"  checkout: serial {pipe['seq_warm_checkout_s']*1000:.1f}ms vs "
          f"batched {pipe['pip_warm_checkout_s']*1000:.1f}ms = "
          f"{pipe['checkout_speedup']:.2f}x (warm, depth-8 tip)")
    print(f"  ratio: {pipe['seq_ratio']:.1f} (serial/preset-1) vs "
          f"{pipe['pip_ratio']:.1f} (pipelined/preset-0); depth-5 chain "
          f"folded {pipe['fold_chain_hops']} hops into 1 dequant")
    presets = bench_lzma_presets()
    print("  lzma presets: " + "  ".join(
        f"p{p['preset']}: ratio {p['ratio']:.1f} enc {p['encode_s']*1000:.0f}ms "
        f"dec {p['decode_s']*1000:.0f}ms" for p in presets))
    return rows + [{"technique": "chain_reconstruction", **chain},
                   {"technique": "pipeline", **pipe}]


def perf_smoke() -> None:
    """CI gate: the batched/pipelined engines must not regress below the
    serial baseline on a small pool (speed targets are asserted loosely —
    shared CI boxes are noisy; the full bench reports exact numbers)."""
    pipe = bench_pipeline(smoke=True)
    print(f"perf-smoke: commit {pipe['commit_speedup']:.2f}x "
          f"warm-checkout {pipe['checkout_speedup']:.2f}x "
          f"cold-checkout {pipe['cold_checkout_speedup']:.2f}x "
          f"(fold: {pipe['fold_chain_hops']} hops -> 1 dequant)")
    assert pipe["commit_speedup"] >= 1.0, pipe
    assert pipe["checkout_speedup"] >= 1.0, pipe
    print("perf-smoke OK: batched >= sequential, fold + fsck invariants hold")


if __name__ == "__main__":
    import sys
    if "--perf-smoke" in sys.argv:
        perf_smoke()
    else:
        main()
