"""Paper §6.1 (G1): automated graph construction accuracy vs the gold graph.

The paper recovers 22/23 HF models correctly; we measure the recovered
fraction on the synthetic HF-style pool (an inferred parent counts as correct
if it is the gold parent or any model of the same root family — the paper
counts family-level placement)."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.pools import GRAPHS
from repro.core import LineageGraph, auto_construct


def _family(name: str) -> str:
    return name.split("-")[0].split("_")[0].split("@")[0]


def run(graphs=("G1", "G2", "G4")) -> List[Dict]:
    rows = []
    for gname in graphs:
        pool, gold, gtype = GRAPHS[gname]()
        for mode, vs in (("paper (hash-only)", False),
                         ("+value tiebreak", True)):
            g = LineageGraph()
            chosen = auto_construct(g, pool, use_value_similarity=vs)
            correct = total = 0
            for name, parent_gold in gold.items():
                total += 1
                parent = chosen[name]
                if parent_gold is None:
                    correct += parent is None
                else:
                    correct += (parent is not None
                                and _family(parent) == _family(parent_gold))
            rows.append({"graph": gname, "mode": mode, "n_models": total,
                         "correct": correct, "accuracy": correct / total})
    return rows


def main():
    rows = run()
    print(f"{'graph':5} {'mode':18} {'n':>4} {'correct':>8} {'accuracy':>9}")
    for r in rows:
        print(f"{r['graph']:5} {r['mode']:18} {r['n_models']:4d} "
              f"{r['correct']:8d} {r['accuracy']:9.3f}")
    return rows


if __name__ == "__main__":
    main()
