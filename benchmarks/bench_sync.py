"""Remote sync dedup report (paper §5; DESIGN.md §8).

Measures what the have/want negotiation saves across a collaboration
session: for each sync step, objects transferred vs. the closure's total
object count (the dedup ratio), wall time, and the round-trip invariant —
a fresh clone must reconstruct a bit-identical lineage graph, and an
unchanged re-push must transfer exactly zero objects.

Run directly (CI smoke job): ``PYTHONPATH=src:. python -m benchmarks.bench_sync``
— exits non-zero if any invariant fails.
"""

from __future__ import annotations

import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.pools import g2_adaptation
from repro.core import LineageGraph
from repro.core.auto import auto_insert
from repro.remote import LocalTransport, RemoteState, clone, pull, push
from repro.store import ArtifactStore


def _row(step: str, report, elapsed: float) -> Dict:
    return {
        "step": step,
        "objects_total": report.objects_total,
        "objects_transferred": report.objects_transferred,
        "bytes_transferred": report.bytes_transferred,
        "dedup_ratio": round(report.dedup_ratio, 4),
        "seconds": round(elapsed, 4),
    }


def run(scale: int = 1) -> List[Dict]:
    pool, _, _ = g2_adaptation(scale=scale)
    rows: List[Dict] = []
    with tempfile.TemporaryDirectory() as tmp:
        src, remote_dir, dst = f"{tmp}/src", f"{tmp}/remote", f"{tmp}/clone"
        store = ArtifactStore(root=src, t_thr=float("inf"))
        g = LineageGraph(path=src, store=store)
        split = max(1, len(pool) - 2)
        for name, artifact in pool[:split]:
            auto_insert(g, artifact, name)

        remote = LocalTransport(remote_dir)
        state = RemoteState(src, "origin")
        for step in ("initial push", "unchanged re-push"):
            t0 = time.perf_counter()
            rep = push(g, remote, state=state)
            rows.append(_row(step, rep, time.perf_counter() - t0))

        # grow the graph, push only the increment
        for name, artifact in pool[split:]:
            auto_insert(g, artifact, name)
        t0 = time.perf_counter()
        rep = push(g, remote, state=state)
        rows.append(_row("incremental push", rep, time.perf_counter() - t0))

        t0 = time.perf_counter()
        rep = clone(remote_dir, dst)
        rows.append(_row("clone", rep, time.perf_counter() - t0))

        # -- invariants (the acceptance criteria) ---------------------------
        assert rows[1]["objects_transferred"] == 0, \
            "unchanged re-push must transfer zero objects"
        assert 0 < rows[2]["objects_transferred"] < rows[2]["objects_total"], \
            "incremental push must transfer only the increment"
        g2 = LineageGraph(path=dst, store=ArtifactStore(root=dst))
        assert sorted(g2.nodes) == sorted(g.nodes), "clone lost nodes"
        for name in g.nodes:
            assert g2.nodes[name].artifact_ref == g.nodes[name].artifact_ref
            a = g.store.load_artifact(g.nodes[name].artifact_ref)
            b = g2.store.load_artifact(g2.nodes[name].artifact_ref)
            for k in a.params:
                np.testing.assert_array_equal(np.asarray(a.params[k]),
                                              np.asarray(b.params[k]))
        t0 = time.perf_counter()
        rep = pull(g2, LocalTransport(remote_dir),
                   state=RemoteState(dst, "origin"))
        rows.append(_row("no-op pull", rep, time.perf_counter() - t0))
        assert rows[-1]["objects_transferred"] == 0, \
            "pull of an already-synced graph must transfer zero objects"
        assert g2.store.fsck(
            [n.artifact_ref for n in g2.nodes.values() if n.artifact_ref]
        )["ok"], "clone fails fsck"
    return rows


def main() -> List[Dict]:
    rows = run()
    header = f"{'step':<18} {'objects':>14} {'bytes':>12} {'dedup':>7} {'s':>8}"
    print(header)
    print("-" * len(header))
    for r in rows:
        objs = f"{r['objects_transferred']}/{r['objects_total']}"
        print(f"{r['step']:<18} {objs:>14} {r['bytes_transferred']:>12} "
              f"{r['dedup_ratio']:>7.2%} {r['seconds']:>8.3f}")
    print("round-trip bit-identical: OK; zero-object re-push: OK")
    return rows


if __name__ == "__main__":
    main()
