"""Chunk layer (DESIGN.md §12): dedup ratio, bounded-RSS streaming, ranged pull.

Three measurements, one per acceptance criterion of the chunk layer:

* **edit dedup** — commit a large tensor, apply a 0.1% localized edit,
  re-commit: the second version must re-store < 5% of the tensor's bytes
  (content-defined chunking keeps every untouched chunk's key);
* **streaming RSS** — commit + file-checkout a tensor larger than the
  configured chunk window through a procedural source (the tensor never
  exists in memory); the process RSS high-water delta must stay under
  2x the window budget. Measured in a fresh subprocess so this process's
  allocation history cannot mask the result;
* **ranged pull** — pull one tensor's chunks from a loopback hub emulating
  a WAN path (per-request RTT, per-connection bandwidth cap): a single
  sequential stream vs chunk-parallel ranged connections.

Run directly (CI chunk-smoke job asserts the same bounds):
``PYTHONPATH=src:. python -m benchmarks.bench_chunks``
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core import LayerGraph, LayerNode, ModelArtifact
from repro.store import ArtifactStore

EDIT_MB = 64                   # edit-dedup tensor size
STREAM_MB = 256                # streaming tensor size (logical)
WINDOW_MB = 32                 # chunk window budget for the RSS run
PULL_MB = 48                   # ranged-pull payload


def _artifact(w: np.ndarray) -> ModelArtifact:
    g = LayerGraph.chain([LayerNode("big", "linear",
                                    params={"w": (w.shape, "float32")})])
    return ModelArtifact(g, {"big/w": w})


def bench_edit_dedup() -> Dict:
    rows = EDIT_MB * 2 ** 20 // (1024 * 4)
    rng = np.random.default_rng(0)
    w = rng.standard_normal((rows, 1024)).astype(np.float32)
    with tempfile.TemporaryDirectory() as tmp:
        store = ArtifactStore(root=tmp)
        t0 = time.perf_counter()
        r1 = store.commit_artifact("m", _artifact(w))
        commit_s = time.perf_counter() - t0
        base_bytes = store.cas.physical_bytes()

        w2 = w.copy()
        n = max(1, w.size // 1000)             # 0.1% localized edit
        w2.reshape(-1)[w.size // 3:w.size // 3 + n] += 0.5
        t0 = time.perf_counter()
        r2 = store.commit_artifact("m", _artifact(w2), parent_ref=r1)
        edit_commit_s = time.perf_counter() - t0
        added = store.cas.physical_bytes() - base_bytes

        t0 = time.perf_counter()
        got = store.materialize_param(r2, "big/w")
        checkout_s = time.perf_counter() - t0
        # delta children reconstruct within the quantization step (eps);
        # bit-identity holds for full commits (checked in streaming_rss)
        assert np.allclose(got, w2, atol=store.eps), "checkout out of eps"
        report = store.fsck([r1, r2])
        assert report["ok"] and not report["chunk_damage"], "fsck failed"
        e = store.get_manifest(r2)["params"]["big/w"]
        return {"step": "edit_dedup", "tensor_mb": EDIT_MB,
                "chunks": len(e["chunks"]),
                "reused": sum(1 for it in e["chunks"]
                              if "c" not in it or store.cas.refcounts.get(
                                  it.get("c", ""), 0) > 1),
                "added_bytes": int(added),
                "added_frac": round(added / w.nbytes, 5),
                "commit_s": round(commit_s, 3),
                "edit_commit_s": round(edit_commit_s, 3),
                "checkout_s": round(checkout_s, 3)}


# Runs in a fresh interpreter per mode: ru_maxrss is a process-lifetime
# high-water mark, so the parent's (or the other mode's) allocation history
# would hide the result. "chunked" streams a FnSource through the chunk
# window; "dense" materializes the same tensor in memory and commits it with
# chunking disabled — the pre-chunk-layer baseline.
_RSS_SCRIPT = r"""
import json, resource, sys, time
import numpy as np
from repro.core import LayerGraph, LayerNode, ModelArtifact
from repro.store import ArtifactStore
from repro.store.chunks import FnSource

mode, stream_mb, window_mb, tmp = (sys.argv[1], int(sys.argv[2]),
                                   int(sys.argv[3]), sys.argv[4])
pat = np.random.default_rng(7).bytes(1 << 20)

def read(off, size):
    parts, p = [], off
    while size > 0:
        i = p % len(pat)
        n = min(size, len(pat) - i)
        # mix the MiB index in so consecutive blocks differ (defeats
        # trivial whole-stream dedup while staying allocation-free)
        blk = bytearray(pat[i:i + n])
        blk[0] = (p >> 20) & 0xFF
        parts.append(bytes(blk))
        p += n
        size -= n
    return b"".join(parts)

rows = stream_mb * (1 << 20) // 4096
shape = (rows, 1024)
g = LayerGraph.chain([LayerNode("big", "linear",
                                params={"w": (shape, "float32")})])
if mode == "chunked":
    store = ArtifactStore(root=tmp, chunk_mode="fixed",
                          chunk_window_bytes=window_mb * (1 << 20))
    value = FnSource(read, shape, "float32")
else:
    store = ArtifactStore(root=tmp, chunk_threshold=0)  # chunking off
    value = np.frombuffer(read(0, rows * 4096),
                          dtype=np.float32).reshape(shape)
art = ModelArtifact(g, {"big/w": value})

base_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
t0 = time.perf_counter()
ref = store.commit_artifact("m", art)
commit_s = time.perf_counter() - t0
t0 = time.perf_counter()
digest = store.materialize_param_to_file(ref, "big/w", tmp + "/w.bin")
checkout_s = time.perf_counter() - t0
entry = store.get_manifest(ref)["params"]["big/w"]
assert digest == entry["hash"], "streamed checkout not bit-identical"
peak_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
print(json.dumps({"delta_mb": (peak_kb - base_kb) / 1024.0,
                  "commit_s": commit_s, "checkout_s": checkout_s,
                  "chunks": len(entry.get("chunks", []))}))
"""


def _rss_run(mode: str) -> Dict:
    with tempfile.TemporaryDirectory() as tmp:
        env = dict(os.environ)
        env["PYTHONPATH"] = "src:" + env.get("PYTHONPATH", "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run(
            [sys.executable, "-c", _RSS_SCRIPT, mode, str(STREAM_MB),
             str(WINDOW_MB), tmp],
            env=env, capture_output=True, text=True, check=True)
        return json.loads(out.stdout.strip().splitlines()[-1])


def bench_streaming_rss() -> Dict:
    chunked = _rss_run("chunked")
    dense = _rss_run("dense")
    return {"step": "streaming_rss", "tensor_mb": STREAM_MB,
            "window_mb": WINDOW_MB, "rss_budget_mb": 2 * WINDOW_MB,
            "chunked_rss_delta_mb": round(chunked["delta_mb"], 1),
            "dense_rss_delta_mb": round(dense["delta_mb"], 1),
            "chunks": chunked["chunks"],
            "commit_s": round(chunked["commit_s"], 3),
            "checkout_s": round(chunked["checkout_s"], 3),
            "commit_mb_per_s": round(
                STREAM_MB / max(chunked["commit_s"], 1e-9), 1),
            "within_budget": chunked["delta_mb"] < 2 * WINDOW_MB}


PULL_CHUNK_MB = 1              # chunk object size on the hub
PULL_RTT_MS = 5                # simulated per-request RTT
PULL_BPS = 100 * 2 ** 20       # simulated per-connection bandwidth cap
PULL_WORKERS = 8


def bench_ranged_pull() -> Dict:
    """Chunk-parallel ranged pull vs single-stream pull of one tensor.

    The hub emulates a WAN path (per-request RTT + per-connection
    bandwidth cap via ``HubServer.delay_s`` / ``throttle_bps``) because a
    raw loopback socket has neither property, and parallelism only pays
    where they exist. ``single`` is one mget stream over one connection
    (the strongest sequential baseline); ``parallel`` fans the tensor's
    chunks across ranged connections the way ``fetch_param_shard`` does.
    Unthrottled loopback numbers ride along for calibration.
    """
    from concurrent.futures import ThreadPoolExecutor
    from repro.hub import HubApp, start_in_thread
    from repro.remote.http import HttpTransport
    rng = np.random.default_rng(1)
    n_chunks = PULL_MB // PULL_CHUNK_MB
    with tempfile.TemporaryDirectory() as tmp:
        app = HubApp(os.path.join(tmp, "hub"))
        chunks = {app.store.cas.put_bytes(rng.bytes(PULL_CHUNK_MB * 2 ** 20)):
                  PULL_CHUNK_MB * 2 ** 20 for _ in range(n_chunks)}
        keys = list(chunks)
        server, _ = start_in_thread(app)
        try:
            t = HttpTransport(server.url)
            t.read_objects(keys[:1])  # warm connection path + page cache

            def single():
                return t.read_objects(keys)

            def parallel():
                with ThreadPoolExecutor(max_workers=PULL_WORKERS) as pool:
                    return dict(zip(keys, pool.map(
                        lambda k: t.read_object_range(k, 0, chunks[k]),
                        keys)))

            def best(fn, reps=3):
                times, out = [], None
                for _ in range(reps):
                    t0 = time.perf_counter()
                    out = fn()
                    times.append(time.perf_counter() - t0)
                return min(times), out

            raw_single, _ = best(single)
            raw_par, _ = best(parallel)
            server.delay_s = PULL_RTT_MS / 1000.0
            server.throttle_bps = PULL_BPS
            wan_single, a = best(single, reps=2)
            wan_par, b = best(parallel, reps=2)
            assert a == b and sorted(a) == sorted(keys), "pull mismatch"
        finally:
            server.shutdown()
            server.server_close()
    return {"step": "ranged_pull", "payload_mb": PULL_MB,
            "chunks": n_chunks, "workers": PULL_WORKERS,
            "rtt_ms": PULL_RTT_MS,
            "link_mb_per_s": PULL_BPS // 2 ** 20,
            "single_s": round(wan_single, 4),
            "parallel_s": round(wan_par, 4),
            "speedup": round(wan_single / max(wan_par, 1e-9), 2),
            "single_mb_per_s": round(PULL_MB / max(wan_single, 1e-9), 1),
            "parallel_mb_per_s": round(PULL_MB / max(wan_par, 1e-9), 1),
            "loopback_single_s": round(raw_single, 4),
            "loopback_parallel_s": round(raw_par, 4)}


def main() -> List[Dict]:
    rows = [bench_edit_dedup(), bench_streaming_rss(), bench_ranged_pull()]
    for r in rows:
        print(" ".join(f"{k}={v}" for k, v in r.items()))
    dedup, rss, pull = rows
    assert dedup["added_frac"] < 0.05, \
        f"0.1% edit re-stored {dedup['added_frac']:.1%} of the tensor"
    assert rss["within_budget"], \
        f"streaming RSS {rss['chunked_rss_delta_mb']} MB over 2x window"
    assert pull["speedup"] > 1.0, \
        f"parallel ranged pull slower than single-stream ({pull['speedup']}x)"
    return rows


if __name__ == "__main__":
    main()
