"""Roofline table from the dry-run artifact (experiments/dryrun.json).

Prints, per (arch x shape x mesh): the three roofline terms, dominant
bottleneck, MODEL_FLOPS / HLO_FLOPs usefulness ratio, and per-device HBM
fit — the §Roofline deliverable, derivable on demand from the cached sweep.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "dryrun.json")


def load(path: str = DEFAULT_PATH) -> Dict:
    with open(path) as f:
        return json.load(f)


def rows(path: str = DEFAULT_PATH, mesh: str = "single") -> List[Dict]:
    data = load(path)
    out = []
    for key, r in sorted(data.items()):
        if r.get("status") == "skipped":
            if key.endswith(mesh):
                out.append({"arch": r["arch"], "shape": r["shape"],
                            "status": "skipped", "reason": r["reason"]})
            continue
        if r.get("status") != "ok" or not key.endswith(mesh):
            continue
        rt = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rt["compute"], "memory_s": rt["memory"],
            "collective_s": rt["collective"], "dominant": rt["dominant"],
            "bound_s": rt["bound_s"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_frac": rt["compute"] / rt["bound_s"],
            "hbm_gb": (r["memory_analysis"]["peak_bytes_estimate"] or 0) / 2**30,
            "compile_s": r["compile_s"],
        })
    return out


def main(path: str = DEFAULT_PATH):
    table = rows(path)
    print(f"{'arch':24} {'shape':12} {'compute_s':>10} {'memory_s':>10} "
          f"{'coll_s':>9} {'dominant':>10} {'rl_frac':>8} {'useful':>7} {'HBM_GB':>7}")
    for r in table:
        if r["status"] == "skipped":
            print(f"{r['arch']:24} {r['shape']:12} SKIP: {r['reason'][:60]}")
            continue
        print(f"{r['arch']:24} {r['shape']:12} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:9.4f} "
              f"{r['dominant']:>10} {r['roofline_frac']:8.3f} "
              f"{r['useful_ratio']:7.3f} {r['hbm_gb']:7.2f}")
    return table


if __name__ == "__main__":
    main()
