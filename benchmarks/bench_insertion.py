"""Paper Figure 3: average per-model auto-insertion time vs lineage-graph size.

Larger graphs are built by replicating the G2 pool (the paper's method).
``run_store_backed`` additionally commits every inserted model through the
packfile-backed ArtifactStore and times the accounting queries, checking that
``object_count``/``physical_bytes`` stay O(1) as the store grows."""

from __future__ import annotations

import tempfile
import time
from typing import Dict, List

from benchmarks.pools import g2_adaptation
from repro.core import LineageGraph
from repro.core.auto import auto_insert
from repro.store import ArtifactStore


def run(scales=(1, 2, 4)) -> List[Dict]:
    rows = []
    for scale in scales:
        pool, _, _ = g2_adaptation(scale=scale)
        g = LineageGraph()
        t_per_model = []
        for name, artifact in pool:
            t0 = time.perf_counter()
            auto_insert(g, artifact, name)
            t_per_model.append(time.perf_counter() - t0)
        rows.append({"n_models": len(pool),
                     "avg_insert_s": sum(t_per_model) / len(t_per_model),
                     "max_insert_s": max(t_per_model)})
    return rows


def run_store_backed(scales=(1, 2)) -> List[Dict]:
    """Insertion + storage commit through the lazy/packfile engine."""
    rows = []
    for scale in scales:
        pool, _, _ = g2_adaptation(scale=scale)
        with tempfile.TemporaryDirectory() as tmp:
            store = ArtifactStore(root=tmp, t_thr=float("inf"))
            g = LineageGraph(path=tmp, store=store)
            t_per_model = []
            for name, artifact in pool:
                t0 = time.perf_counter()
                auto_insert(g, artifact, name)
                t_per_model.append(time.perf_counter() - t0)
            # accounting queries must be O(1), not directory scans
            t0 = time.perf_counter()
            for _ in range(1000):
                store.cas.object_count()
                store.cas.physical_bytes()
            t_account = (time.perf_counter() - t0) / 2000
            rows.append({"n_models": len(pool),
                         "avg_insert_s": sum(t_per_model) / len(t_per_model),
                         "max_insert_s": max(t_per_model),
                         "objects": store.cas.object_count(),
                         "ratio": store.compression_ratio(),
                         "accounting_us": t_account * 1e6})
    return rows


def run_commit_engines(scale: int = 1) -> List[Dict]:
    """Store-backed insertion through the serial vs pipelined commit engine
    (DESIGN.md §10.1) — same pool, same graph work, only the storage commit
    path differs."""
    rows = []
    pool, _, _ = g2_adaptation(scale=scale)
    for pipelined in (False, True):
        with tempfile.TemporaryDirectory() as tmp:
            store = ArtifactStore(root=tmp, t_thr=float("inf"),
                                  pipelined=pipelined,
                                  fold_enabled=pipelined)
            g = LineageGraph(path=tmp, store=store)
            t0 = time.perf_counter()
            for name, artifact in pool:
                auto_insert(g, artifact, name)
            dt = time.perf_counter() - t0
            rows.append({"engine": "pipelined" if pipelined else "serial",
                         "n_models": len(pool),
                         "total_s": dt,
                         "models_per_s": len(pool) / dt,
                         "ratio": store.compression_ratio()})
    return rows


def main():
    rows = run()
    print(f"{'n_models':>9} {'avg_insert_s':>13} {'max_insert_s':>13}")
    for r in rows:
        print(f"{r['n_models']:9d} {r['avg_insert_s']:13.3f} {r['max_insert_s']:13.3f}")
    srows = run_store_backed()
    print(f"\n{'n_models':>9} {'avg_insert_s':>13} {'objects':>8} "
          f"{'ratio':>7} {'account_us':>11}")
    for r in srows:
        print(f"{r['n_models']:9d} {r['avg_insert_s']:13.3f} {r['objects']:8d} "
              f"{r['ratio']:7.2f} {r['accounting_us']:11.2f}")
    erows = run_commit_engines()
    print(f"\n{'engine':>10} {'n_models':>9} {'total_s':>8} "
          f"{'models/s':>9} {'ratio':>7}")
    for r in erows:
        print(f"{r['engine']:>10} {r['n_models']:9d} {r['total_s']:8.2f} "
              f"{r['models_per_s']:9.2f} {r['ratio']:7.2f}")
    return rows + srows + erows


if __name__ == "__main__":
    main()
