"""Paper Figure 3: average per-model auto-insertion time vs lineage-graph size.

Larger graphs are built by replicating the G2 pool (the paper's method)."""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.pools import g2_adaptation
from repro.core import LineageGraph
from repro.core.auto import auto_insert


def run(scales=(1, 2, 4)) -> List[Dict]:
    rows = []
    for scale in scales:
        pool, _, _ = g2_adaptation(scale=scale)
        g = LineageGraph()
        t_per_model = []
        for name, artifact in pool:
            t0 = time.perf_counter()
            auto_insert(g, artifact, name)
            t_per_model.append(time.perf_counter() - t0)
        rows.append({"n_models": len(pool),
                     "avg_insert_s": sum(t_per_model) / len(t_per_model),
                     "max_insert_s": max(t_per_model)})
    return rows


def main():
    rows = run()
    print(f"{'n_models':>9} {'avg_insert_s':>13} {'max_insert_s':>13}")
    for r in rows:
        print(f"{r['n_models']:9d} {r['avg_insert_s']:13.3f} {r['max_insert_s']:13.3f}")
    return rows


if __name__ == "__main__":
    main()
