"""Synthetic lineage-graph pools mirroring the paper's G1-G5 (Table 3).

Each generator returns (pool [(name, artifact)], gold_parents {child: parent},
graph_type). Derivations reproduce the paper's regimes deterministically:

  G1'  HF-style pool: several unrelated roots + finetuned/head-swapped
       derivatives (bert/roberta/albert/distilbert analogue)
  G2'  adaptation: one MLM root, task models, perturbed-data versions
  G3'  federated learning: rounds of client updates averaged into globals
  G4'  edge specialization: magnitude pruning at increasing sparsity
  G5'  multi-task learning: task models sharing 98% of parameters exactly

Models are chain MLPs at a configurable scale (default ~1.6 MB/model) so the
full Table-4 matrix runs in minutes on one CPU core; ratios are driven by the
same delta statistics as the paper's (sparse finetune deltas, pruned zeros,
shared MTL trunks).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core import LayerGraph, LayerNode, ModelArtifact

Pool = List[Tuple[str, ModelArtifact]]


def base_model(seed: int, n_layers: int = 6, d: int = 256, head_dim: int = 8,
               prefix: str = "L", model_type: str = "toy") -> ModelArtifact:
    rng = np.random.default_rng(seed)
    layers, params = [], {}
    for i in range(n_layers):
        layers.append(LayerNode(f"{prefix}{i}", "linear",
                                params={"w": ((d, d), "float32")}))
        params[f"{prefix}{i}/w"] = rng.normal(size=(d, d)).astype(np.float32)
    layers.append(LayerNode("head", "linear",
                            params={"w": ((d, head_dim), "float32")}))
    params["head/w"] = rng.normal(size=(d, head_dim)).astype(np.float32)
    return ModelArtifact(LayerGraph.chain(layers), params, model_type=model_type)


def finetune(parent: ModelArtifact, seed: int, scale=5e-5, density=0.3,
             freeze_frac=0.0) -> ModelArtifact:
    rng = np.random.default_rng(seed)
    keys = list(parent.params)
    frozen = set(keys[:int(len(keys) * freeze_frac)])

    def f(k, v):
        if k in frozen:
            return v
        mask = rng.random(v.shape) < density
        return (v + mask * rng.normal(scale=scale, size=v.shape)).astype(v.dtype)
    return parent.map_params(f)


def reinit_head(parent: ModelArtifact, seed: int) -> ModelArtifact:
    rng = np.random.default_rng(seed)
    return parent.replace_params({
        "head/w": rng.normal(size=parent.params["head/w"].shape).astype(np.float32)})


def prune(parent: ModelArtifact, sparsity: float) -> ModelArtifact:
    def f(k, v):
        kth = np.quantile(np.abs(v), sparsity)
        return np.where(np.abs(v) < kth, 0.0, v).astype(v.dtype)
    return parent.map_params(f)


def average(models: List[ModelArtifact]) -> ModelArtifact:
    out = models[0].map_params(
        lambda k, v: np.mean([m.params[k] for m in models], axis=0).astype(v.dtype))
    return out


# ---------------------------------------------------------------------------

def g1_hf_pool(scale: int = 1, **kw) -> Tuple[Pool, Dict[str, str], str]:
    """Unrelated roots + derivatives, like the HuggingFace download pool."""
    pool: Pool = []
    gold: Dict[str, str] = {}
    for fam, (seed, d) in {"bert": (10, 256), "roberta": (20, 256),
                           "albert": (30, 192), "distil": (40, 128)}.items():
        root = base_model(seed=seed, d=d, prefix=f"{fam}_")
        pool.append((fam, root))
        gold[fam] = None
        for i in range(2 * scale):
            child = finetune(reinit_head(root, seed=seed + i), seed=seed + 50 + i,
                             scale=1e-4, density=0.15, freeze_frac=0.3)
            name = f"{fam}-task{i}"
            pool.append((name, child))
            gold[name] = fam
    return pool, gold, "huggingface"


def g2_adaptation(scale: int = 1, n_tasks: int = 5, n_versions: int = 2,
                  **kw) -> Tuple[Pool, Dict[str, str], str]:
    root = base_model(seed=0)
    pool: Pool = [("mlm", root)]
    gold: Dict[str, str] = {"mlm": None}
    for rep in range(scale):
        for t in range(n_tasks):
            name = f"task{t}_r{rep}"
            m = finetune(reinit_head(root, seed=100 + t), seed=200 + t + rep,
                         density=0.2)
            pool.append((name, m))
            gold[name] = "mlm"
            prev, prev_m = name, m
            for v in range(n_versions):
                vname = f"{name}@v{v + 2}"
                prev_m = finetune(prev_m, seed=300 + t * 10 + v, density=0.1)
                pool.append((vname, prev_m))
                gold[vname] = prev
                prev = vname
    return pool, gold, "adaptation"


def g3_federated(rounds: int = 5, clients: int = 4, **kw
                 ) -> Tuple[Pool, Dict[str, str], str]:
    global_m = base_model(seed=0)
    pool: Pool = [("global_r0", global_m)]
    gold: Dict[str, str] = {"global_r0": None}
    for r in range(1, rounds + 1):
        locals_ = []
        for c in range(clients):
            m = finetune(global_m, seed=r * 100 + c, scale=2e-4, density=0.4)
            name = f"client{c}_r{r}"
            pool.append((name, m))
            gold[name] = f"global_r{r - 1}"
            locals_.append(m)
        global_m = average(locals_)
        pool.append((f"global_r{r}", global_m))
        gold[f"global_r{r}"] = f"client0_r{r}"  # any client is a valid parent
    return pool, gold, "federated"


def g4_pruning(**kw) -> Tuple[Pool, Dict[str, str], str]:
    pool: Pool = []
    gold: Dict[str, str] = {}
    for fam, seed, d in (("resnet", 0, 256), ("densenet", 1, 192),
                         ("mobilenet", 2, 128)):
        root = base_model(seed=seed, d=d, prefix=f"{fam}_")
        pool.append((fam, root))
        gold[fam] = None
        prev_name, prev = fam, root
        for s in (0.3, 0.5, 0.7, 0.9):
            m = prune(prev, sparsity=s)
            m = finetune(m, seed=seed + int(s * 10), scale=1e-4, density=0.05)
            name = f"{fam}-sp{int(s * 100)}"
            pool.append((name, m))
            gold[name] = prev_name
            prev_name, prev = name, m
    return pool, gold, "pruning"


def g5_mtl(n_tasks: int = 9, **kw) -> Tuple[Pool, Dict[str, str], str]:
    """98% shared parameters: identical trunks, task-specific heads."""
    root = base_model(seed=0)
    pool: Pool = [("mlm", root)]
    gold: Dict[str, str] = {"mlm": None}
    for t in range(n_tasks):
        m = reinit_head(root, seed=500 + t)
        pool.append((f"mtl{t}", m))
        gold[f"mtl{t}"] = "mlm"
    return pool, gold, "mtl"


GRAPHS = {"G1": g1_hf_pool, "G2": g2_adaptation, "G3": g3_federated,
          "G4": g4_pruning, "G5": g5_mtl}
