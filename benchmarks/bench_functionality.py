"""Paper §6.4: lineage-powered functionality.

  bisect     first-failing-version search: probes used vs a linear scan
             (paper: up to 1.5x faster; asymptotically log vs linear)
  cascade    run_update_cascade end-to-end wall time over G2-style graph
  tests      graph-wide test sweep: the eager serial ``run_tests`` path vs
             the memoized parallel diagnostics runner (DESIGN.md §9.1) —
             both reported so the speedup is tracked across PRs
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.pools import base_model, finetune
from repro.core import (CreationFunction, LineageGraph, bfs, bisect,
                        register_creation_type, run_update_cascade,
                        version_chain)
from repro.diag import DiagnosticsRunner


@register_creation_type("bench-finetune")
class BenchCr(CreationFunction):
    def __call__(self, parents):
        return finetune(parents[0].get_model(), seed=self.config["seed"],
                        density=0.05)


def _version_chain_graph(n_versions: int, first_bad: int) -> LineageGraph:
    g = LineageGraph()
    m = base_model(seed=0, n_layers=2, d=64)
    g.add_node(m, "m@v1")
    prev = "m@v1"
    for v in range(2, n_versions + 1):
        m = finetune(m, seed=v, density=0.05)
        m.metadata["broken"] = v >= first_bad
        name = f"m@v{v}"
        g.add_node(m, name)
        g.add_version_edge(prev, name)
        prev = name
    return g


def run_bisect(n_versions: int = 64, first_bad: int = 37) -> Dict:
    g = _version_chain_graph(n_versions, first_bad)

    probes = {"bisect": 0, "linear": 0}

    def failing(node):
        probes["cur"] += 1
        return bool(node.get_model().metadata.get("broken"))

    probes["cur"] = 0
    t0 = time.perf_counter()
    found = bisect(g, "m@v1", failing)
    t_bisect = time.perf_counter() - t0
    probes["bisect"] = probes["cur"]

    probes["cur"] = 0
    t0 = time.perf_counter()
    found_lin = None
    for node in version_chain(g, "m@v1"):
        if failing(node):
            found_lin = node
            break
    t_linear = time.perf_counter() - t0
    probes["linear"] = probes["cur"]

    assert found.name == found_lin.name == f"m@v{first_bad}"
    return {"n_versions": n_versions, "bisect_probes": probes["bisect"],
            "linear_probes": probes["linear"],
            "probe_speedup": probes["linear"] / probes["bisect"],
            "bisect_s": t_bisect, "linear_s": t_linear}


def run_cascade(n_tasks: int = 6) -> Dict:
    g = LineageGraph()
    root = base_model(seed=0, n_layers=4, d=128)
    g.add_node(root, "mlm")
    for t in range(n_tasks):
        cr = BenchCr(seed=100 + t)
        g.add_node(cr([g.nodes["mlm"]]), f"task{t}", cr=cr)
        g.add_edge("mlm", f"task{t}")
    g.add_node(finetune(root, seed=999), "mlm@v2")
    t0 = time.perf_counter()
    created = run_update_cascade(g, "mlm", "mlm@v2")
    dt = time.perf_counter() - t0
    return {"n_tasks": n_tasks, "created": len(created), "cascade_s": dt,
            "s_per_model": dt / max(len(created), 1)}


def probe_activation(model) -> float:
    """Eval-sized probe: a 512-row batch through the model (a test whose
    cost is worth memoizing — paper §6.4 runs real eval sets)."""
    first = sorted(model.params)[0]
    d = np.asarray(model.params[first]).shape[0]
    x = np.ones((512, d), np.float32)
    for name in model.graph.topo_order():
        w = model.params.get(f"{name}/w")
        if w is None:
            continue
        x = np.tanh(x @ np.asarray(w))
    return float(np.mean(x) * 100)


def run_test_sweep(n_versions: int = 24) -> Dict:
    """Eager serial run_tests vs memoized parallel runner, same graph.

    The eager path re-executes every test each invocation; the memoized
    runner executes once and afterwards answers from the result ledger.
    Store-backed, like a real repo: memo keys come straight from manifest
    content addresses (a storeless graph would pay a param-hash pass)."""
    import shutil
    import tempfile

    from repro.store import ArtifactStore
    root_dir = tempfile.mkdtemp(prefix="mgit-bench-func-")
    g = LineageGraph(path=root_dir, store=ArtifactStore(root=root_dir))
    m = base_model(seed=0, n_layers=4, d=256)
    g.add_node(m, "m@v1")
    prev = "m@v1"
    for v in range(2, n_versions + 1):
        m = finetune(m, seed=v, density=0.05)
        name = f"m@v{v}"
        g.add_node(m, name)
        g.add_version_edge(prev, name)
        prev = name
    g.register_test_function(probe_activation, "probe/activation", mt="toy")

    t0 = time.perf_counter()
    eager1 = g.run_tests(bfs(g))
    t_eager = time.perf_counter() - t0
    t0 = time.perf_counter()
    g.run_tests(bfs(g))  # eager path pays full price again
    t_eager2 = time.perf_counter() - t0

    runner = DiagnosticsRunner(g)
    t0 = time.perf_counter()
    cold = runner.run()
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = DiagnosticsRunner(g).run()   # fresh runner: hits from the store
    t_warm = time.perf_counter() - t0

    try:
        assert warm.executed == 0 and warm.cache_hit_ratio == 1.0
        # Eager tests the node's cached in-memory artifact, the runner tests
        # the stored truth — equal only up to delta-quantization eps.
        for k, v in warm.values().items():
            assert abs(v["probe/activation"]
                       - eager1[k]["probe/activation"]) < 1e-2
        return {"n_models": n_versions, "eager_s": t_eager,
                "eager_rerun_s": t_eager2, "memo_cold_s": t_cold,
                "memo_warm_s": t_warm,
                "warm_speedup": t_eager2 / max(t_warm, 1e-9),
                "cache_hit_ratio": warm.cache_hit_ratio}
    finally:
        shutil.rmtree(root_dir, ignore_errors=True)


def main():
    b = run_bisect()
    print(f"bisect: {b['bisect_probes']} probes vs linear {b['linear_probes']} "
          f"({b['probe_speedup']:.1f}x fewer probes)")
    c = run_cascade()
    print(f"cascade: rebuilt {c['created']} models in {c['cascade_s']:.2f}s "
          f"({c['s_per_model']:.2f}s/model)")
    s = run_test_sweep()
    print(f"test sweep over {s['n_models']} models: eager re-run "
          f"{s['eager_rerun_s']*1e3:.1f}ms vs memoized warm "
          f"{s['memo_warm_s']*1e3:.1f}ms ({s['warm_speedup']:.1f}x, "
          f"hit ratio {s['cache_hit_ratio']:.0%})")
    return [b, c, s]


if __name__ == "__main__":
    main()
