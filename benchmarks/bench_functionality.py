"""Paper §6.4: lineage-powered functionality.

  bisect     first-failing-version search: probes used vs a linear scan
             (paper: up to 1.5x faster; asymptotically log vs linear)
  cascade    run_update_cascade end-to-end wall time over G2-style graph
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.pools import base_model, finetune
from repro.core import (CreationFunction, LineageGraph, bisect,
                        register_creation_type, run_update_cascade,
                        version_chain)


@register_creation_type("bench-finetune")
class BenchCr(CreationFunction):
    def __call__(self, parents):
        return finetune(parents[0].get_model(), seed=self.config["seed"],
                        density=0.05)


def _version_chain_graph(n_versions: int, first_bad: int) -> LineageGraph:
    g = LineageGraph()
    m = base_model(seed=0, n_layers=2, d=64)
    g.add_node(m, "m@v1")
    prev = "m@v1"
    for v in range(2, n_versions + 1):
        m = finetune(m, seed=v, density=0.05)
        m.metadata["broken"] = v >= first_bad
        name = f"m@v{v}"
        g.add_node(m, name)
        g.add_version_edge(prev, name)
        prev = name
    return g


def run_bisect(n_versions: int = 64, first_bad: int = 37) -> Dict:
    g = _version_chain_graph(n_versions, first_bad)

    probes = {"bisect": 0, "linear": 0}

    def failing(node):
        probes["cur"] += 1
        return bool(node.get_model().metadata.get("broken"))

    probes["cur"] = 0
    t0 = time.perf_counter()
    found = bisect(g, "m@v1", failing)
    t_bisect = time.perf_counter() - t0
    probes["bisect"] = probes["cur"]

    probes["cur"] = 0
    t0 = time.perf_counter()
    found_lin = None
    for node in version_chain(g, "m@v1"):
        if failing(node):
            found_lin = node
            break
    t_linear = time.perf_counter() - t0
    probes["linear"] = probes["cur"]

    assert found.name == found_lin.name == f"m@v{first_bad}"
    return {"n_versions": n_versions, "bisect_probes": probes["bisect"],
            "linear_probes": probes["linear"],
            "probe_speedup": probes["linear"] / probes["bisect"],
            "bisect_s": t_bisect, "linear_s": t_linear}


def run_cascade(n_tasks: int = 6) -> Dict:
    g = LineageGraph()
    root = base_model(seed=0, n_layers=4, d=128)
    g.add_node(root, "mlm")
    for t in range(n_tasks):
        cr = BenchCr(seed=100 + t)
        g.add_node(cr([g.nodes["mlm"]]), f"task{t}", cr=cr)
        g.add_edge("mlm", f"task{t}")
    g.add_node(finetune(root, seed=999), "mlm@v2")
    t0 = time.perf_counter()
    created = run_update_cascade(g, "mlm", "mlm@v2")
    dt = time.perf_counter() - t0
    return {"n_tasks": n_tasks, "created": len(created), "cascade_s": dt,
            "s_per_model": dt / max(len(created), 1)}


def main():
    b = run_bisect()
    print(f"bisect: {b['bisect_probes']} probes vs linear {b['linear_probes']} "
          f"({b['probe_speedup']:.1f}x fewer probes)")
    c = run_cascade()
    print(f"cascade: rebuilt {c['created']} models in {c['cascade_s']:.2f}s "
          f"({c['s_per_model']:.2f}s/model)")
    return [b, c]


if __name__ == "__main__":
    main()
