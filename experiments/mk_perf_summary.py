"""Generate the §Perf before/after summary table (baseline vs final)."""
import json, sys

base = json.load(open("experiments/dryrun_baseline.json"))
final = json.load(open(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_final.json"))

print("| arch | shape | bound_s before | bound_s after | speedup | dominant after | HBM GB before→after | fits |")
print("|---|---|---|---|---|---|---|---|")
total_b = total_a = 0.0
for key in sorted(base):
    if not key.endswith("single"):
        continue
    b = base[key]
    a = final.get(key, {})
    if b.get("status") != "ok" or a.get("status") != "ok":
        continue
    bb = b["roofline"]["bound_s"]; ab = a["roofline"]["bound_s"]
    hb = (b["memory_analysis"]["peak_bytes_estimate"] or 0)/2**30
    ha = (a["memory_analysis"]["peak_bytes_estimate"] or 0)/2**30
    total_b += bb; total_a += ab
    print(f"| {b['arch']} | {b['shape']} | {bb:.2f} | {ab:.2f} | "
          f"**{bb/ab:.2f}x** | {a['roofline']['dominant']} | "
          f"{hb:.1f}→{ha:.1f} | {'yes' if ha <= 16 else 'NO'} |")
print(f"\nAggregate bound across cells: {total_b:.0f}s → {total_a:.0f}s "
      f"(**{total_b/total_a:.2f}x**)")
