"""Generate the EXPERIMENTS.md roofline tables from dryrun JSON artifacts."""
import json, sys

def table(path, mesh="single"):
    data = json.load(open(path))
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s | dominant | rl_frac | useful | HBM GB/dev | fits 16GB |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(data):
        r = data[key]
        if not key.endswith(mesh):
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | SKIP | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | | | |")
            continue
        rt = r["roofline"]
        hbm = (r["memory_analysis"]["peak_bytes_estimate"] or 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rt['compute']:.3f} | {rt['memory']:.3f} | "
            f"{rt['collective']:.3f} | **{rt['dominant']}** | {rt['compute']/rt['bound_s']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {hbm:.1f} | {'yes' if hbm <= 16 else 'NO'} |")
    return "\n".join(lines)

if __name__ == "__main__":
    print(table(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.json",
                sys.argv[2] if len(sys.argv) > 2 else "single"))
