"""Per-arch smoke tests: REDUCED same-family configs, one forward/train step
on CPU, asserting output shapes and no NaNs. Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticPipeline
from repro.models import (decode_step, forward, get_config, init_params,
                          list_archs, prefill)
from repro.train.step import init_state, make_train_step

ARCHS = [
    "starcoder2-15b", "yi-6b", "qwen3-0.6b", "deepseek-coder-33b",
    "seamless-m4t-large-v2", "mamba2-780m", "llama4-scout-17b-16e",
    "mixtral-8x7b", "jamba-1.5-large-398b", "paligemma-3b",
]

SEQ = 32
BATCH = 2


def _reduced(arch):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, remat="none")


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = _reduced(arch)
    params = init_params(cfg, 0)
    batch = SyntheticPipeline(cfg, batch=BATCH, seq=SEQ).host_batch(0)
    logits = forward(cfg, params, batch)
    assert logits.shape[0] == BATCH
    assert logits.shape[-1] == cfg.vocab_size
    assert logits.shape[1] == batch["tokens"].shape[1]
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = _reduced(arch)
    state = init_state(cfg, 0)
    batch = SyntheticPipeline(cfg, batch=BATCH, seq=SEQ).host_batch(1)
    step = jax.jit(make_train_step(cfg))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(new_state["params"]),
                        jax.tree_util.tree_leaves(state["params"])))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _reduced(arch)
    params = init_params(cfg, 0)
    pipe = SyntheticPipeline(cfg, batch=BATCH, seq=SEQ)
    batch = pipe.host_batch(2)
    tokens = batch["tokens"]
    full_logits = forward(cfg, params, batch)

    prompt = dict(batch)
    prompt["tokens"] = tokens[:, :-1]
    _, cache = prefill(cfg, params, prompt, max_len=tokens.shape[1] + 4)
    prefix = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    pos = jnp.asarray(prefix + tokens.shape[1] - 1, jnp.int32)
    step_logits, _ = decode_step(cfg, params, tokens[:, -1:], cache, pos)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-2, rtol=2e-2)


def test_all_assigned_archs_registered():
    known = list_archs()
    for arch in ARCHS:
        assert arch in known
