"""Throughput engines (DESIGN.md §10): pipelined commit, segment folding,
batched checkout, zero-copy pack I/O, durability fixes."""

import json
import os
import threading

import numpy as np
import pytest

from repro.store import CAS, ArtifactStore
from repro.store.delta import host_dequant, host_snapshot

from helpers import finetune_like, make_chain_model


def _build_chain(store, depth, seed0=0, d=32):
    model = make_chain_model(seed=seed0, d=d)
    refs = [store.commit_artifact("v0", model)]
    for v in range(1, depth + 1):
        model = finetune_like(model, seed=v)
        refs.append(store.commit_artifact(f"v{v}", model,
                                          parent_ref=refs[-1]))
    return refs, model


# ---------------------------------------------------------------------------
# host twins == jax ref kernels, bitwise (the fold's load-bearing identity)
# ---------------------------------------------------------------------------


def test_host_dequant_bit_identical_to_ref_kernel():
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    for eps in (1e-4, 1e-3, 5e-5):
        p1 = (rng.normal(size=(97, 53)) * rng.uniform(0.01, 50)
              ).astype(np.float32)
        q = rng.integers(-2000, 2000, size=p1.shape).astype(np.int32)
        ref = np.asarray(ops.dequant_apply(p1, q, eps=eps, backend="ref",
                                           out_dtype="float32"))
        np.testing.assert_array_equal(ref, host_dequant(p1, q, eps))


def test_host_snapshot_bit_identical_to_ref_kernel():
    from repro.kernels import ops
    rng = np.random.default_rng(1)
    for eps in (1e-4, 1e-3):
        p1 = (rng.normal(size=(64, 40)) * 3).astype(np.float32)
        p2 = (p1 + rng.normal(scale=rng.uniform(1e-6, 1e-2),
                              size=p1.shape)).astype(np.float32)
        qj, nzj, _fp, narrow_j = ops.snapshot_fused(p1, p2, eps=eps,
                                                    backend="ref")
        q, nz, narrow = host_snapshot(p1, p2, eps)
        assert nz == nzj and narrow == narrow_j
        np.testing.assert_array_equal(np.asarray(qj, np.int32),
                                      q.astype(np.int32))


# ---------------------------------------------------------------------------
# segment folding
# ---------------------------------------------------------------------------


def test_depth5_chain_folds_to_one_dequant(tmp_path):
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    refs, final = _build_chain(store, 5)
    store.cache.clear()
    store.fold_cache.clear()
    store.reset_io_stats()
    v = store.materialize_param(refs[-1], "L0/w")
    io = store.io_stats
    assert io["chain_hops"] == 5          # every blob decoded
    assert io["dequant_calls"] == 1       # ...ONE dequant applied
    assert io["hops_folded"] == 4
    np.testing.assert_allclose(v, final.params["L0/w"], atol=5e-4)


def test_batch_equals_per_param_equals_recursive_bitwise(tmp_path):
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    refs, _ = _build_chain(store, 6)
    store.cache.clear()
    store.fold_cache.clear()
    batch = store.materialize_artifact(refs[-1])
    store.cache.clear()
    store.fold_cache.clear()
    for k in batch.params:
        np.testing.assert_array_equal(np.asarray(batch.params[k]),
                                      store.materialize_param(refs[-1], k))
    recursive = store.load_artifact_recursive(refs[-1])
    for k in batch.params:
        np.testing.assert_array_equal(np.asarray(batch.params[k]),
                                      np.asarray(recursive.params[k]))


def test_fold_cache_eviction_cannot_change_bits(tmp_path):
    with_cache = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    refs, _ = _build_chain(with_cache, 5)
    warm = with_cache.materialize_artifact(refs[-1])  # fold states warm
    # a second store with NO fold cache (budget 0) folds cold from base
    no_cache = ArtifactStore(root=str(tmp_path), max_chain_depth=8,
                             fold_budget_bytes=0)
    cold = no_cache.materialize_artifact(refs[-1])
    for k in warm.params:
        np.testing.assert_array_equal(np.asarray(warm.params[k]),
                                      np.asarray(cold.params[k]))


def test_mixed_eps_chain_segments_and_stays_consistent(tmp_path):
    """eps changes mid-chain: folding must split segments (structural rule)
    and still agree bitwise across all three materialization paths."""
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8, eps=1e-4)
    model = make_chain_model(seed=0, d=32)
    refs = [store.commit_artifact("v0", model)]
    for v in range(1, 3):
        model = finetune_like(model, seed=v)
        refs.append(store.commit_artifact(f"v{v}", model,
                                          parent_ref=refs[-1]))
    store.eps = 1e-3  # reconfigured store keeps committing onto the chain
    for v in range(3, 5):
        model = finetune_like(model, seed=v)
        refs.append(store.commit_artifact(f"v{v}", model,
                                          parent_ref=refs[-1]))

    store.cache.clear()
    store.fold_cache.clear()
    store.reset_io_stats()
    tip = store.materialize_param(refs[-1], "L0/w")
    io = store.io_stats
    assert io["chain_hops"] == 4
    assert io["dequant_calls"] == 2       # one per same-eps segment
    np.testing.assert_allclose(tip, model.params["L0/w"], atol=5e-3)

    store.cache.clear()
    store.fold_cache.clear()
    batch = store.materialize_artifact(refs[-1])
    recursive = store.load_artifact_recursive(refs[-1])
    for k in batch.params:
        np.testing.assert_array_equal(np.asarray(batch.params[k]),
                                      np.asarray(recursive.params[k]))
    np.testing.assert_array_equal(np.asarray(batch.params["L0/w"]), tip)


def test_reopened_store_reproduces_committed_hashes(tmp_path):
    """Stored truth round-trips: manifest hash fields match what a fresh
    store materializes (commit fold == checkout fold)."""
    from repro.common.hashing import tensor_hash
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    refs, _ = _build_chain(store, 4)
    fresh = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    manifest = fresh.get_manifest(refs[-1])
    for key, e in manifest["params"].items():
        value = fresh.materialize_param(refs[-1], key)
        assert tensor_hash(np.asarray(value)) == e["hash"], key


def test_truth_marker_rejects_mismatched_reopen(tmp_path):
    """One reconstruction-truth definition per repository (§10.2): a repo
    committed under fold truth must refuse a hop-by-hop reopen (and vice
    versa) instead of silently materializing different bits than its
    manifest hashes."""
    store = ArtifactStore(root=str(tmp_path))
    _build_chain(store, 2)
    with pytest.raises(ValueError, match="reconstruction truth"):
        ArtifactStore(root=str(tmp_path), pipelined=False)


def test_legacy_repo_without_marker_adopts_hopwise(tmp_path):
    """A store_stats.json predating the truth marker (PR-1..3 repo) means
    hop-by-hop chains: reopening with the fold default must adopt hopwise
    so materialized bits keep matching the recorded manifest hashes."""
    from repro.common.hashing import tensor_hash
    store = ArtifactStore(root=str(tmp_path), pipelined=False)
    refs, _ = _build_chain(store, 3)
    stats_path = os.path.join(str(tmp_path), "store_stats.json")
    payload = json.load(open(stats_path))
    del payload["truth"]  # simulate the pre-§10 file format
    json.dump(payload, open(stats_path, "w"))

    reopened = ArtifactStore(root=str(tmp_path))  # fold default
    assert not reopened.fold_enabled
    manifest = reopened.get_manifest(refs[-1])
    for key, e in manifest["params"].items():
        value = reopened.materialize_param(refs[-1], key)
        assert tensor_hash(np.asarray(value)) == e["hash"], key


def test_pipelined_commit_respects_accuracy_gate(tmp_path):
    from repro.core.lineage import RegisteredTest
    store = ArtifactStore(root=str(tmp_path), t_thr=0.0, eps=10.0)
    parent = make_chain_model(seed=0)
    child = finetune_like(parent, seed=1, scale=1e-2, density=1.0)
    r1 = store.commit_artifact("p", parent)
    probe = RegisteredTest(name="l2", model_type="toy",
                           fn=lambda m: float(np.linalg.norm(
                               np.asarray(m.params["L0/w"], np.float64))))
    r2 = store.commit_artifact("c", child, parent_ref=r1, tests=[probe])
    # huge eps + zero tolerance: compression must be rejected -> full commit
    assert store.get_manifest(r2)["depth"] == 0
    assert all(e["kind"] == "full"
               for e in store.get_manifest(r2)["params"].values())


# ---------------------------------------------------------------------------
# durability + miss-path satellites
# ---------------------------------------------------------------------------


def test_write_loose_fsyncs_before_replace(tmp_path, monkeypatch):
    """Crash-sim regression: the tmp file must be fsynced BEFORE os.replace
    publishes it — otherwise a crash can leave a truncated object under its
    content-addressed (i.e. trusted) name."""
    events = []
    real_fsync, real_replace = os.fsync, os.replace

    def spy_fsync(fd):
        events.append(("fsync",))
        return real_fsync(fd)

    def spy_replace(src, dst):
        if str(src).endswith(".tmp"):
            events.append(("replace", os.path.basename(dst)))
        return real_replace(src, dst)

    monkeypatch.setattr(os, "fsync", spy_fsync)
    monkeypatch.setattr(os, "replace", spy_replace)
    cas = CAS(str(tmp_path), pack_threshold=16)
    key = cas.put_bytes(os.urandom(4096))
    replace_i = next(i for i, e in enumerate(events)
                     if e[0] == "replace" and e[1] == key)
    assert ("fsync",) in events[:replace_i], events


def test_get_bytes_missing_key_is_keyerror(tmp_path):
    cas = CAS(str(tmp_path))
    for fn in (cas.get_bytes, cas.get_view):
        with pytest.raises(KeyError):
            fn("deadbeef" * 8)
    mem = CAS(None)
    with pytest.raises(KeyError):
        mem.get_bytes("deadbeef" * 8)


def test_loose_overwrite_invalidates_mmap_pool(tmp_path):
    """Overwrite-in-place of a loose object (forced diag ledger re-record
    whose payload crossed the pack threshold) swaps the inode — a pooled
    map of the old file must not keep serving the superseded bytes."""
    cas = CAS(str(tmp_path), pack_threshold=16)
    key = "t_demo_ledger_entry"
    cas.put_bytes(b"A" * 4096, key=key)
    assert cas.get_bytes(key) == b"A" * 4096  # maps the file
    cas.put_bytes(b"B" * 4096, key=key, overwrite=True)
    assert cas.get_bytes(key) == b"B" * 4096
    assert bytes(cas.get_view(key)) == b"B" * 4096


def test_batch_single_fsync_per_pack(tmp_path):
    cas = CAS(str(tmp_path), pack_threshold=4096)
    with cas.batch():
        keys = [cas.put_bytes(os.urandom(200)) for _ in range(64)]
        # records must be readable mid-batch (handle flushed per record)
        assert cas.get_bytes(keys[0])
    assert cas.stats["fsyncs"] == 1  # one pack, one fsync at the commit point
    cas.flush()
    reopened = CAS(str(tmp_path), pack_threshold=4096)
    for k in keys:
        assert len(reopened.get_bytes(k)) == 200


def test_zero_copy_get_tensor_is_readonly_view(tmp_path):
    cas = CAS(str(tmp_path), pack_threshold=1024)
    x = np.arange(8192, dtype=np.float32).reshape(128, 64)
    key = cas.put_tensor(x)
    before = cas.stats["zero_copy_gets"]
    y = cas.get_tensor(key)
    np.testing.assert_array_equal(x, y)
    assert not y.flags.writeable           # aliases the shared mmap
    assert y.base is not None              # a view, not an owned copy
    assert cas.stats["zero_copy_gets"] > before


def test_lzma_preset_knob_roundtrips_across_presets(tmp_path):
    """Blobs are container-self-describing: a store tuned to any preset
    reads chains written by any other."""
    fast = ArtifactStore(root=str(tmp_path), lzma_preset=0)
    refs, final = _build_chain(fast, 2)
    strong = ArtifactStore(root=str(tmp_path), lzma_preset=6)
    model = finetune_like(final, seed=9)
    ref3 = strong.commit_artifact("v3", model, parent_ref=refs[-1])
    fresh = ArtifactStore(root=str(tmp_path))  # default preset
    loaded = fresh.materialize_artifact(ref3)
    for k in loaded.params:
        np.testing.assert_allclose(np.asarray(loaded.params[k]),
                                   model.params[k], atol=5e-4)


# ---------------------------------------------------------------------------
# concurrency
# ---------------------------------------------------------------------------


def test_concurrent_commits_keep_store_consistent(tmp_path):
    """Many threads committing different children of one base through the
    batched writer: counters, refcounts and fsck must all stay exact."""
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    base = make_chain_model(seed=0, d=32)
    base_ref = store.commit_artifact("base", base)
    refs, errors = [], []

    def commit_one(i):
        try:
            child = finetune_like(base, seed=100 + i)
            refs.append(store.commit_artifact(f"c{i}", child,
                                              parent_ref=base_ref))
        except Exception as exc:  # pragma: no cover - failure detail
            errors.append(exc)

    threads = [threading.Thread(target=commit_one, args=(i,))
               for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(set(refs)) == 6

    report = store.fsck(roots=[base_ref] + refs)
    assert report["ok"], report
    # O(1) counters agree with a fresh rebuild from disk
    reopened = ArtifactStore(root=str(tmp_path))
    assert reopened.cas.object_count() == store.cas.object_count()
    assert reopened.cas.physical_bytes() == store.cas.physical_bytes()
    assert reopened.fsck(roots=[base_ref] + refs)["ok"]
    # every child materializes bit-identically from both instances
    for r in refs:
        a = store.materialize_artifact(r)
        b = reopened.materialize_artifact(r)
        for k in a.params:
            np.testing.assert_array_equal(np.asarray(a.params[k]),
                                          np.asarray(b.params[k]))


def test_fsck_clean_after_pipelined_commit_gc_compaction(tmp_path):
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8,
                          pack_threshold=512)
    refs, _ = _build_chain(store, 6, d=16)
    # drop some mid-chain refs (lineage still holds chain deps), gc+compact
    extra = store.commit_artifact("spare", make_chain_model(seed=42, d=16))
    store.release(extra)
    store.gc()
    assert store.fsck(roots=refs)["ok"]
    reopened = ArtifactStore(root=str(tmp_path), pack_threshold=512)
    assert reopened.fsck(roots=refs)["ok"]


# ---------------------------------------------------------------------------
# batched checkout surface
# ---------------------------------------------------------------------------


def test_materialize_artifact_subset_and_cache_seeding(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    refs, final = _build_chain(store, 3)
    store.cache.clear()
    store.fold_cache.clear()
    sub = store.materialize_artifact(refs[-1], keys=["L0/w", "L1/w"])
    assert set(sub.params) == {"L0/w", "L1/w"}
    # batch checkout seeds the tensor cache: lazy access is now free
    store.reset_io_stats()
    lazy = store.load_artifact(refs[-1])
    np.testing.assert_array_equal(np.asarray(lazy.params["L0/w"]),
                                  np.asarray(sub.params["L0/w"]))
    assert store.io_stats["tensors_materialized"] == 0


def test_load_artifact_eager_routes_through_batch_engine(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    refs, final = _build_chain(store, 2)
    eager = store.load_artifact(refs[-1], lazy=False)
    assert not eager.is_lazy
    for k in final.params:
        np.testing.assert_allclose(np.asarray(eager.params[k]),
                                   final.params[k], atol=5e-4)
