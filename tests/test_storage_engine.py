"""Lazy plan-based storage engine: chain resolver, per-tensor materialization,
packfile CAS, byte-budget cache (DESIGN.md §3)."""

import json
import os

import numpy as np
import pytest

from repro.core import LineageGraph, module_diff
from repro.core.artifact import LazyParams
from repro.store import CAS, ArtifactStore

from helpers import finetune_like, make_chain_model


def _build_chain(store, depth, seed0=0, d=32):
    """Commit a (depth+1)-long version chain; returns (refs, final_model)."""
    model = make_chain_model(seed=seed0, d=d)
    refs = [store.commit_artifact("v0", model)]
    for v in range(1, depth + 1):
        model = finetune_like(model, seed=v)
        refs.append(store.commit_artifact(f"v{v}", model,
                                          parent_ref=refs[-1]))
    return refs, model


# ---------------------------------------------------------------------------
# chain resolver + plans
# ---------------------------------------------------------------------------

def test_chain_reconstruction_at_max_depth(tmp_path):
    depth = 8
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=depth)
    refs, final = _build_chain(store, depth)
    # every committed link was accepted as a delta up to the cap
    assert store.get_manifest(refs[-1])["depth"] == depth
    loaded = store.load_artifact(refs[-1])
    for k in final.params:
        assert np.max(np.abs(loaded.params[k] - final.params[k])) < 5 * 1e-4


def test_plan_is_flat_and_bounded(tmp_path):
    depth = 5
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    refs, _ = _build_chain(store, depth)
    store.cache.clear()  # commits warm the cache; plan from cold
    plan = store.resolve_chain(refs[-1], "L0/w")
    assert plan.base_kind == "full"
    assert plan.depth == depth
    # hops run bottom-up: first hop reconstructs v1, last the tip
    assert plan.hops[-1].ref == refs[-1]
    assert plan.hops[0].ref == refs[1]


def test_plan_short_circuits_on_cache_hit(tmp_path):
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    refs, _ = _build_chain(store, 4)
    store.cache.clear()
    store.materialize_param(refs[2], "L0/w")  # warm an intermediate link
    plan = store.resolve_chain(refs[-1], "L0/w")
    assert plan.base_kind == "cache"
    assert plan.base == (refs[2], "L0/w")
    assert plan.depth == 2  # only the two hops above the cached link


def test_lazy_vs_recursive_loader_equivalence(tmp_path):
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8)
    refs, _ = _build_chain(store, 6)
    lazy = store.load_artifact(refs[-1])
    eager = store.load_artifact_recursive(refs[-1])
    for k in eager.params:
        np.testing.assert_array_equal(np.asarray(lazy.params[k]),
                                      np.asarray(eager.params[k]))


# ---------------------------------------------------------------------------
# lazy single-param access
# ---------------------------------------------------------------------------

def test_single_param_access_skips_siblings(tmp_path):
    depth = 8
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=depth)
    refs, final = _build_chain(store, depth)

    store.cache.clear()
    store.fold_cache.clear()
    store.reset_io_stats()
    art = store.load_artifact(refs[-1])
    assert isinstance(art.params, LazyParams)
    assert store.io_stats["tensors_materialized"] == 0  # checkout is free

    value = art.params["L0/w"]
    np.testing.assert_allclose(value, final.params["L0/w"], atol=5e-4)

    # Only L0/w's chain was touched — and the whole same-eps chain FOLDED
    # into one accumulated int32 delta + a single dequant (DESIGN.md §10.2):
    # the only tensors produced are the chain base and the final value.
    tensor_bytes = np.asarray(final.params["L0/w"]).nbytes
    stats = store.io_stats
    assert stats["chain_hops"] == depth       # every blob decoded once
    assert stats["dequant_calls"] == 1        # ...but ONE dequant applies
    assert stats["hops_folded"] == depth - 1
    assert stats["tensors_materialized"] == 2
    assert stats["bytes_materialized"] == tensor_bytes * 2
    # O(tensor), NOT O(model x depth) like the old recursive loader
    assert stats["bytes_materialized"] < final.nbytes() * (depth + 1)
    # sibling tensors never entered the cache
    assert all(k[1] == "L0/w" for k in store.cache._entries)
    assert all(k[1] == "L0/w" for k in store.fold_cache._entries)


def test_lazy_nbytes_and_hashes_without_materialization(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    m = make_chain_model(seed=0)
    ref = store.commit_artifact("a", m)
    store.cache.clear()
    store.reset_io_stats()
    art = store.load_artifact(ref)
    assert art.nbytes() == m.nbytes()
    hashes = art.param_hashes()
    assert set(hashes) == set(m.params.keys())
    assert store.io_stats["tensors_materialized"] == 0


def test_contextual_diff_does_not_materialize(tmp_path):
    store = ArtifactStore(root=str(tmp_path), t_thr=float("inf"))
    parent = make_chain_model(seed=0)
    child = finetune_like(parent, seed=1)
    r1 = store.commit_artifact("p", parent)
    r2 = store.commit_artifact("c", child, parent_ref=r1)
    store.cache.clear()
    store.reset_io_stats()
    d = module_diff(store.load_artifact(r1), store.load_artifact(r2),
                    mode="contextual")
    assert d.n_nodes_a == d.n_nodes_b
    assert store.io_stats["tensors_materialized"] == 0


# ---------------------------------------------------------------------------
# byte-budget tensor cache
# ---------------------------------------------------------------------------

def test_cache_byte_budget_eviction(tmp_path):
    d = 32
    tensor_bytes = d * d * 4
    # budget fits ~3 weight tensors — a depth-4 chain of full models cannot fit
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=8,
                          cache_budget_bytes=3 * tensor_bytes + 1)
    refs, final = _build_chain(store, 4, d=d)
    store.cache.clear()
    art = store.load_artifact(refs[-1])
    for k in final.params:
        art.params[k]
    assert store.cache.bytes_used <= 3 * tensor_bytes + 1
    assert store.cache.evictions > 0
    # values still correct after eviction-forced replans
    np.testing.assert_allclose(np.asarray(art.params["L0/w"]),
                               final.params["L0/w"], atol=5e-4)


def test_cache_hit_avoids_rework(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    refs, _ = _build_chain(store, 4)
    store.cache.clear()
    store.reset_io_stats()
    store.materialize_param(refs[-1], "L0/w")
    first = store.io_stats["tensors_materialized"]
    store.materialize_param(refs[-1], "L0/w")
    assert store.io_stats["tensors_materialized"] == first  # pure cache hit


# ---------------------------------------------------------------------------
# packfile CAS
# ---------------------------------------------------------------------------

def test_packfile_roundtrip_and_reopen(tmp_path):
    cas = CAS(str(tmp_path), pack_threshold=1024)
    small = {f"k{i}".ljust(8, "_"): os.urandom(100 + i) for i in range(20)}
    big = os.urandom(4096)
    keys = {k: cas.put_bytes(v, key=k) for k, v in small.items()}
    big_key = cas.put_bytes(big)
    assert cas.pack_stats()["packed_objects"] == 20
    assert cas.object_count() == 21
    for k, v in small.items():
        assert cas.get_bytes(keys[k]) == v
        assert cas.size(keys[k]) == len(v)
    assert cas.get_bytes(big_key) == big
    # small objects share pack files instead of 1 file each
    objdir = os.listdir(os.path.join(str(tmp_path), "objects"))
    assert len(objdir) == 1  # only the big object is loose

    # reopen WITHOUT a persisted index: recovered by scanning pack tails
    idx = os.path.join(str(tmp_path), "packs", "pack-index.json")
    if os.path.exists(idx):
        os.remove(idx)
    cas2 = CAS(str(tmp_path), pack_threshold=1024)
    for k, v in small.items():
        assert cas2.get_bytes(k) == v
    assert cas2.object_count() == 21


def test_packfile_gc_compaction(tmp_path):
    cas = CAS(str(tmp_path), pack_threshold=1024)
    keys = [cas.put_bytes(os.urandom(200)) for _ in range(10)]
    before = cas.physical_bytes()
    for k in keys[:8]:
        cas.decref(k)
    reclaimed = cas.gc()
    assert reclaimed > 0
    assert cas.object_count() == 2
    assert cas.physical_bytes() < before  # compaction rewrote the pack
    for k in keys[8:]:
        assert len(cas.get_bytes(k)) == 200  # survivors intact

    # O(1) counters agree with ground truth after compaction
    cas2 = CAS(str(tmp_path), pack_threshold=1024)
    assert cas2.object_count() == 2


def test_accounting_counters_match_disk(tmp_path):
    cas = CAS(str(tmp_path), pack_threshold=512)
    for i in range(5):
        cas.put_bytes(os.urandom(100))     # packed
        cas.put_bytes(os.urandom(1000))    # loose
    total_disk = 0
    for sub in ("objects", "packs"):
        d = os.path.join(str(tmp_path), sub)
        total_disk += sum(os.path.getsize(os.path.join(d, f))
                          for f in os.listdir(d)
                          if not f.endswith(".json") and not f.endswith(".tmp"))
    assert cas.physical_bytes() == total_disk
    assert cas.object_count() == 10


# ---------------------------------------------------------------------------
# decref durability (crash-safety fix)
# ---------------------------------------------------------------------------

def test_decref_clamps_and_persists(tmp_path):
    cas = CAS(str(tmp_path))
    k = cas.put_bytes(b"x" * 5000)
    cas.decref(k)
    cas.decref(k)  # double-release: must clamp at 0, not go negative
    assert cas.refcounts[k] == 0
    # persisted BEFORE gc: a fresh instance (simulated crash) sees the zero
    with open(os.path.join(str(tmp_path), "refcounts.json")) as f:
        assert json.load(f)[k] == 0
    cas2 = CAS(str(tmp_path))
    assert cas2.refcounts[k] == 0
    assert cas2.gc() > 0          # no leak: the object is collectable
    assert not cas2.has(k)
    cas2.incref(k)                # resurrection attempt cannot double-free
    assert cas2.gc() == 0


def test_reopen_with_smaller_depth_knob_still_reads(tmp_path):
    """A chain written at depth 6 must stay readable when the store is
    reopened with a smaller max_chain_depth (write-side knob only)."""
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=16)
    refs, final = _build_chain(store, 6)
    store2 = ArtifactStore(root=str(tmp_path), max_chain_depth=2)
    loaded = store2.load_artifact(refs[-1])
    np.testing.assert_allclose(np.asarray(loaded.params["L0/w"]),
                               final.params["L0/w"], atol=5e-4)


def test_pack_reopen_does_not_proliferate(tmp_path):
    """Reopening must append to the newest pack, not start a stub per run."""
    for _ in range(4):
        cas = CAS(str(tmp_path), pack_threshold=1024)
        cas.put_bytes(os.urandom(100))
        cas.flush()
    packs = [f for f in os.listdir(os.path.join(str(tmp_path), "packs"))
             if f.endswith(".pack")]
    assert len(packs) == 1


def test_compaction_survivors_readable_after_reopen(tmp_path):
    cas = CAS(str(tmp_path), pack_threshold=1024)
    keys = [cas.put_bytes(bytes([i]) * 300) for i in range(10)]
    for k in keys[:8]:
        cas.decref(k)
    cas.gc()  # compacts: live records copied before the old pack is removed
    cas2 = CAS(str(tmp_path), pack_threshold=1024)
    for i, k in enumerate(keys[8:], start=8):
        assert cas2.get_bytes(k) == bytes([i]) * 300


def test_recompress_refreshes_stale_lazy_artifact(tmp_path):
    """add_node-then-add_edge recommits as a delta; the node's cached lazy
    artifact must not keep resolving against the released old manifest."""
    store = ArtifactStore(root=str(tmp_path))
    g = LineageGraph(path=str(tmp_path), store=store)
    parent = make_chain_model(seed=0)
    child = finetune_like(parent, seed=1)
    g.add_node(parent, "p")
    g.add_node(child, "c")           # committed full (no edge yet)
    g.nodes["c"].get_model()          # cache a lazy view of the full commit
    g.add_version_edge("p", "c")      # triggers recompress + release + gc
    loaded = g.get_model("c")         # must resolve against the NEW manifest
    np.testing.assert_allclose(np.asarray(loaded.params["L0/w"]),
                               child.params["L0/w"], atol=5e-4)


def test_release_full_lifecycle(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    g = LineageGraph(path=str(tmp_path), store=store)
    refs, _ = _build_chain(store, 3)
    n_before = store.cas.object_count()
    for r in reversed(refs):
        store.release(r)
    store.gc()
    assert store.cas.object_count() < n_before


def test_overwrite_in_place_and_crash_recovery(tmp_path):
    """Ledger-scheme overwrite (diag --force, DESIGN.md §9.1): the newer
    record must win both live and after a crash that lost the index flush —
    the pack tail scan is last-wins, with the stale bytes marked dead."""
    cas = CAS(str(tmp_path), pack_threshold=1024)
    cas.put_bytes(b'{"v": 1}', key="t_demo")
    cas.flush()
    cas.put_bytes(b'{"v": 2}', key="t_demo", overwrite=True)
    assert cas.get_bytes("t_demo") == b'{"v": 2}'
    assert cas.refcounts["t_demo"] == 1          # identity, not a new ref
    assert cas.object_count() == 1

    # crash before the post-overwrite flush: reopen recovers the NEW value
    cas2 = CAS(str(tmp_path), pack_threshold=1024)
    assert cas2.get_bytes("t_demo") == b'{"v": 2}'
    assert sum(cas2._pack_dead.values()) > 0     # stale record is dead bytes

    # loose-object overwrite path (above the pack threshold)
    big1, big2 = b"a" * 2048, b"b" * 2048
    cas2.put_bytes(big1, key="t_big")
    cas2.put_bytes(big2, key="t_big", overwrite=True)
    assert cas2.get_bytes("t_big") == big2
    before = cas2.physical_bytes()
    assert before == sum(
        os.path.getsize(os.path.join(str(tmp_path), "objects", f))
        for f in os.listdir(os.path.join(str(tmp_path), "objects"))
        if not f.endswith(".tmp")) + sum(cas2._pack_sizes.values())
