"""Shared test fixtures: toy model artifacts and derivation operators."""

from __future__ import annotations

import numpy as np

from repro.core import LayerGraph, LayerNode, ModelArtifact


def make_chain_model(seed=0, n_layers=4, d=16, head_dim=4, prefix="L",
                     model_type="toy") -> ModelArtifact:
    rng = np.random.default_rng(seed)
    layers, params = [], {}
    for i in range(n_layers):
        layers.append(LayerNode(f"{prefix}{i}", "linear",
                                params={"w": ((d, d), "float32"),
                                        "b": ((d,), "float32")}))
        params[f"{prefix}{i}/w"] = rng.normal(size=(d, d)).astype(np.float32)
        params[f"{prefix}{i}/b"] = rng.normal(size=(d,)).astype(np.float32)
    layers.append(LayerNode("head", "linear",
                            params={"w": ((d, head_dim), "float32")}))
    params["head/w"] = rng.normal(size=(d, head_dim)).astype(np.float32)
    return ModelArtifact(LayerGraph.chain(layers), params, model_type=model_type)


def finetune_like(parent: ModelArtifact, seed=1, scale=5e-5,
                  density=0.3) -> ModelArtifact:
    """Sparse, tiny parameter perturbation — the adaptation regime."""
    rng = np.random.default_rng(seed)
    return parent.map_params(
        lambda k, v: (v + (rng.normal(scale=scale, size=v.shape) *
                           (rng.random(v.shape) < density)).astype(v.dtype)))


def perturb(parent: ModelArtifact, key: str, seed=1,
            scale=1e-3) -> ModelArtifact:
    """Single-tensor perturbation — maximal param sharing with the parent."""
    rng = np.random.default_rng(seed)
    v = parent.params[key]
    return parent.replace_params(
        {key: (v + rng.normal(scale=scale, size=v.shape)).astype(v.dtype)})


def reinit_head(parent: ModelArtifact, seed=2) -> ModelArtifact:
    rng = np.random.default_rng(seed)
    new_head = rng.normal(size=parent.params["head/w"].shape).astype(np.float32)
    return parent.replace_params({"head/w": new_head})


def prune_like(parent: ModelArtifact, sparsity=0.5) -> ModelArtifact:
    """Magnitude pruning — the edge-specialization regime."""
    def prune(k, v):
        flat = np.abs(v).ravel()
        kth = np.quantile(flat, sparsity)
        return np.where(np.abs(v) < kth, 0.0, v).astype(v.dtype)
    return parent.map_params(prune)


def l2_test(model: ModelArtifact) -> float:
    """Cheap deterministic 'accuracy' stand-in: mean output of a probe."""
    x = np.ones((2, model.params["L0/w"].shape[0]), np.float32)
    for name in model.graph.topo_order():
        w = model.params.get(f"{name}/w")
        if w is None:
            continue
        x = np.tanh(x @ w)
    return float(np.mean(x) * 100)
