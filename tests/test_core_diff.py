"""diff primitive (Algorithm 3) + automated graph construction (§3.2)."""

import numpy as np
import pytest

from repro.core import (LayerGraph, LayerNode, LineageGraph, ModelArtifact,
                        auto_construct, divergence_scores, module_diff)

from helpers import finetune_like, make_chain_model, reinit_head


def test_identical_models_diff_empty():
    a = make_chain_model(seed=0)
    b = make_chain_model(seed=0)
    d = module_diff(a, b, mode="contextual")
    assert d.identical
    assert d.divergence == 0.0


def test_structural_vs_contextual():
    a = make_chain_model(seed=0)
    b = finetune_like(a, seed=1, scale=0.5, density=1.0)  # same shape, new values
    ds, dc = divergence_scores(a, b)
    assert ds == 0.0          # structure unchanged
    assert dc > 0.5           # every layer's content changed


def test_head_change_localized():
    a = make_chain_model(seed=0)
    b = reinit_head(a)
    d = module_diff(a, b, mode="contextual")
    assert set(d.add_nodes) == {"head"}
    assert set(d.del_nodes) == {"head"}
    # trunk layers all matched
    assert {m[0] for m in d.matched_nodes} == {f"L{i}" for i in range(4)}


def test_structural_addition():
    a = make_chain_model(seed=0, n_layers=3)
    # b = a with an adapter layer appended between L2 and head
    b_graph = LayerGraph()
    for name in a.graph.topo_order():
        b_graph.add_node(LayerNode.from_json(a.graph.nodes[name].to_json()))
    adapter = LayerNode("adapter", "adapter", params={"w": ((16, 16), "float32")})
    params = dict(a.params)
    params["adapter/w"] = np.zeros((16, 16), np.float32)
    b_graph.nodes.pop("head")
    nodes = [b_graph.nodes[n] for n in list(b_graph.nodes)]
    g = LayerGraph.chain(nodes + [adapter, LayerNode.from_json(a.graph.nodes["head"].to_json())])
    b = ModelArtifact(g, params, model_type="toy")
    d = module_diff(a, b, mode="structural")
    assert d.add_nodes == ["adapter"]
    assert d.del_nodes == []
    assert 0 < d.divergence < 0.5


def test_divergence_unrelated_models():
    a = make_chain_model(seed=0, d=16)
    b = make_chain_model(seed=1, d=32, n_layers=3, prefix="M")
    ds, dc = divergence_scores(a, b)
    assert ds == 1.0 and dc == 1.0


def test_auto_construct_recovers_gold_graph():
    """The paper's G1 experiment in miniature: insert a pool of derived
    models and check parents are recovered (22/23 in the paper)."""
    root_a = make_chain_model(seed=0, d=16)
    root_b = make_chain_model(seed=7, d=24, n_layers=5, prefix="M")
    pool = [("root_a", root_a), ("root_b", root_b)]
    gold = {"root_a": None, "root_b": None}
    for i in range(3):
        m = finetune_like(root_a, seed=20 + i, density=0.1)
        pool.append((f"ft_a{i}", m))
        gold[f"ft_a{i}"] = "root_a"
    m = reinit_head(root_b)
    pool.append(("head_b", m))
    gold["head_b"] = "root_b"

    g = LineageGraph()
    chosen = auto_construct(g, pool)
    correct = sum(1 for k, v in gold.items()
                  if (chosen[k] is None) == (v is None)
                  and (v is None or chosen[k] in (v,) or
                       g.nodes[chosen[k]].parents == [v]
                       or chosen[k].startswith(v[:4])))
    # roots must be roots; finetunes must attach within root_a's family
    assert chosen["root_a"] is None and chosen["root_b"] is None
    for i in range(3):
        parent = chosen[f"ft_a{i}"]
        assert parent is not None and (parent == "root_a" or parent.startswith("ft_a"))
    assert chosen["head_b"] == "root_b"
    assert correct >= len(gold) - 1


def test_diff_moe_routing_models():
    """diff works on models with routing layers (paper: MoE support)."""
    layers = [LayerNode("router", "router", params={"w": ((8, 4), "float32")}),
              *[LayerNode(f"expert{i}", "mlp", params={"w": ((8, 8), "float32")})
                for i in range(4)]]
    g = LayerGraph()
    for l in layers:
        g.add_node(l)
    for i in range(4):
        g.add_edge("router", f"expert{i}")
    rng = np.random.default_rng(0)
    params = {f"{l.name}/w": rng.normal(size=l.params["w"][0]).astype(np.float32)
              for l in layers}
    a = ModelArtifact(g, params, model_type="moe")
    b = a.replace_params({"expert2/w": params["expert2/w"] + 1.0})
    d = module_diff(a, b, mode="contextual")
    assert set(d.del_nodes) == {"expert2"}
    assert set(d.add_nodes) == {"expert2"}
