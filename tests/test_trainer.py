"""Fault-tolerant Trainer: checkpoint/restart continuity + straggler hook."""

import dataclasses

import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.train import Trainer

CFG = ModelConfig(name="trainer-toy", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                  head_dim=16, dtype="float32", attn_chunk=16, remat="none")


def test_trainer_checkpoint_restart_continuity(tmp_path):
    # run 1: train 6 steps, checkpoint every 3
    t1 = Trainer(CFG, batch=4, seq=16, checkpoint_dir=str(tmp_path),
                 checkpoint_every=3, seed=3)
    h1 = t1.run(6)
    t1.ckpt.wait()

    # "crash" + restart: a fresh Trainer over the same dir resumes at step 6
    t2 = Trainer(CFG, batch=4, seq=16, checkpoint_dir=str(tmp_path),
                 checkpoint_every=3, seed=3)
    assert t2.start_step == 6
    # restored params match within the delta-quantization bound: MGit
    # checkpoints are LOSSY by design (paper §4, eps=1e-4, accuracy-gated);
    # the reconstructed tensors are persisted as the version's truth, so the
    # error is bounded per chain link, not compounding per save
    import jax
    bound = 3 * 2 * np.log1p(1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(t1.state["params"]),
                    jax.tree_util.tree_leaves(t2.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=bound)
    # training continues from the same data position (deterministic pipeline)
    assert t2.pipeline.step == 6
    h2 = t2.run(2)
    assert len(h2["loss"]) == 2 and np.isfinite(h2["loss"]).all()


def test_trainer_checkpoints_are_versioned_and_compressed(tmp_path):
    t = Trainer(CFG, batch=4, seq=16, checkpoint_dir=str(tmp_path),
                checkpoint_every=2, seed=0)
    t.run(4)
    t.ckpt.wait()
    lineage = t.ckpt.lineage
    names = [n for n in lineage.nodes if n.startswith("trainer-toy/step")]
    assert len(names) == 2
    # consecutive checkpoints are linked by version edges
    first = f"trainer-toy/step2"
    assert lineage.nodes[first].version_children == ["trainer-toy/step4"]


def test_trainer_straggler_hook():
    t = Trainer(CFG, batch=2, seq=16)
    # feed synthetic timings through the same timer the loop uses
    for i in range(8):
        t.timer.record(i, 0.05)
    ev = t.timer.record(9, 0.5)
    assert ev is not None
    assert t.policy.on_event(ev) in ("log", "rebalance", "evict")
