"""End-to-end system test: the full MGit workflow over a real (tiny) trained
model family — finetune lineage, compressed storage, testing via traversal,
update cascade, merge — the paper's §6.4 functionality in one scenario."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CreationFunction, LineageGraph, ModelArtifact, bfs,
                        register_creation_type, run_update_cascade)
from repro.data import SyntheticPipeline
from repro.models import forward, get_config, init_params
from repro.store import ArtifactStore
from repro.store.checkpoint import flatten_state, state_graph, unflatten_state
from repro.train.step import init_state, make_train_step


def _cfg():
    return dataclasses.replace(get_config("paper-bert-small"),
                               n_layers=2, d_model=64, d_ff=128,
                               vocab_size=256, attn_chunk=16)


def _to_artifact(cfg, params, name):
    flat = flatten_state(params)
    return ModelArtifact(state_graph(flat, cfg.name), flat,
                         model_type=cfg.name, metadata={"arch": cfg.name})


def _train(cfg, params, seed, steps=3):
    state = {"params": params, "opt": __import__("repro.optim", fromlist=["adamw"]).adamw.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(make_train_step(cfg))
    pipe = SyntheticPipeline(cfg, batch=4, seq=16, seed=seed)
    for i in range(steps):
        state, _ = step_fn(state, pipe.host_batch(i))
    return state["params"]


@register_creation_type("sys-finetune")
class SysFinetune(CreationFunction):
    def __call__(self, parents):
        cfg = _cfg()
        parent_flat = parents[0].get_model().params
        params = unflatten_state(init_params(cfg, 0), parent_flat)
        tuned = _train(cfg, params, seed=self.config["seed"])
        return _to_artifact(cfg, tuned, "ft")


@pytest.fixture(scope="module")
def system(tmp_path_factory):
    tmp = str(tmp_path_factory.mktemp("mgit"))
    cfg = _cfg()
    store = ArtifactStore(root=tmp, codec="lzma")
    g = LineageGraph(path=tmp, store=store)

    base = init_params(cfg, 0)
    base = _train(cfg, base, seed=1, steps=5)
    g.add_node(_to_artifact(cfg, base, "base"), "base")

    for i in range(2):
        cr = SysFinetune(seed=50 + i)
        child = cr([g.nodes["base"]])
        g.add_node(child, f"task{i}", cr=cr)
        g.add_edge("base", f"task{i}")
    return cfg, g, store


def test_lineage_stores_real_models_compressed(system):
    cfg, g, store = system
    stats = store.stats()
    assert stats["compression_ratio"] > 1.2  # finetune deltas compress
    loaded = g.get_model("task0")
    assert loaded.params["embed/tok"].shape == (cfg.vocab_size, cfg.d_model)


def test_traversal_testing_real_models(system):
    cfg, g, store = system

    def loss_probe(artifact):
        params = unflatten_state(init_params(cfg, 0), artifact.params)
        batch = SyntheticPipeline(cfg, batch=2, seq=16, seed=99).host_batch(0)
        logits = forward(cfg, params, batch)
        return float(jnp.mean(logits))

    g.register_test_function(lambda m: 1.0, "alive", mt=cfg.name)
    results = g.run_tests(bfs(g), pattern="alive", match="regex")
    assert set(results) == {"base", "task0", "task1"}


def test_update_cascade_on_real_models(system):
    cfg, g, store = system
    base2 = _train(cfg, unflatten_state(init_params(cfg, 0),
                                        g.get_model("base").params),
                   seed=77, steps=2)
    g.add_node(_to_artifact(cfg, base2, "base2"), "base@v2",
               model_type=cfg.name)
    created = run_update_cascade(g, "base", "base@v2")
    assert sorted(created) == ["task0@v2", "task1@v2"]
    m = g.get_model("task0@v2")
    assert np.isfinite(m.params["embed/tok"]).all()
    # provenance rewired to the new upstream
    assert g.nodes["task0@v2"].parents == ["base@v2"]


def test_storage_savings_reported(system):
    _, _, store = system
    s = store.stats()
    assert s["objects"] > 0
    assert s["logical_bytes"] > s["physical_bytes"]
