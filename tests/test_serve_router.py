"""Router + watcher + HTTP surface (DESIGN.md §13.2–§13.4): endpoint specs,
branch-head resolution, quarantine gate, zero-drop hot swap, lineage watch."""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import LineageGraph
from repro.serve import (EndpointUnavailable, LineageWatcher,
                         LocalLineageSource, ModelPool, Router, ServeApp,
                         parse_endpoint_spec, resolve_branch_head,
                         start_in_thread)
from repro.store import ArtifactStore

from helpers import make_chain_model, perturb


@pytest.fixture
def repo(tmp_path):
    """base@v1 with two branch derivatives sharing it as common ancestor."""
    store = ArtifactStore(root=str(tmp_path))
    g = LineageGraph(path=str(tmp_path), store=store)
    base = make_chain_model(seed=0)
    g.add_node(base, "base@v1")
    for name, key, seed in (("main", "L0/w", 11), ("ab-test", "L3/w", 12)):
        g.add_edge("base@v1", name)
        g.add_node(perturb(base, key, seed=seed), name)
    return str(tmp_path), store, g, base


# ---------------------------------------------------------------------------
# endpoint specs
# ---------------------------------------------------------------------------

def test_parse_endpoint_spec_forms():
    assert parse_endpoint_spec("prod=branch:main") == {
        "name": "prod", "mode": "branch", "target": "main"}
    assert parse_endpoint_spec("prod=main")["mode"] == "branch"  # bare
    assert parse_endpoint_spec("pin=node:x@v2")["target"] == "x@v2"
    assert parse_endpoint_spec("raw=ref:m_abc")["mode"] == "ref"


@pytest.mark.parametrize("bad", ["noeq", "a=", "=branch:x", "a=weird:x"])
def test_parse_endpoint_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_endpoint_spec(bad)


def test_router_rejects_duplicate_endpoints(repo):
    _, store, g, base = repo
    with pytest.raises(ValueError, match="duplicate"):
        Router(ModelPool(store), ["p=branch:main", "p=branch:ab-test"])


# ---------------------------------------------------------------------------
# branch-head resolution
# ---------------------------------------------------------------------------

def _n(name, children=(), parents=(), vc=(), vp=()):
    return {"name": name, "children": list(children),
            "parents": list(parents), "version_children": list(vc),
            "version_parents": list(vp)}


def _nodes(*docs):
    return {d["name"]: d for d in docs}


def test_branch_head_walks_version_chain():
    nodes = _nodes(_n("m", vc=["m@v2"]),
                   _n("m@v2", vp=["m"], vc=["m@v3"]),
                   _n("m@v3", vp=["m@v2"]))
    assert resolve_branch_head(nodes, "m") == "m@v3"


def test_branch_head_ignores_derivations():
    # deriving FROM a branch (1-parent child) does not advance it
    nodes = _nodes(_n("m", children=["ft"]), _n("ft", parents=["m"]))
    assert resolve_branch_head(nodes, "m") == "m"


def test_branch_head_follows_joins():
    # merging INTO a branch does advance it: promote = merge
    nodes = _nodes(_n("m", children=["ft", "merge(m,o)"]),
                   _n("o", children=["merge(m,o)"]),
                   _n("ft", parents=["m"]),
                   _n("merge(m,o)", parents=["m", "o"]))
    assert resolve_branch_head(nodes, "m") == "merge(m,o)"
    assert resolve_branch_head(nodes, "o") == "merge(m,o)"


def test_branch_head_missing_root_and_cycles():
    with pytest.raises(KeyError):
        resolve_branch_head({}, "m")
    nodes = _nodes(_n("a", vc=["b"]), _n("b", vc=["a"]))
    assert resolve_branch_head(nodes, "a") == "b"  # terminates


# ---------------------------------------------------------------------------
# lineage-driven routing: branches, merges, quarantine
# ---------------------------------------------------------------------------

def test_router_branch_endpoints_and_merge_promotion(repo):
    _, store, g, base = repo
    router = Router(ModelPool(store),
                    ["prod=branch:main", "canary=branch:ab-test"])
    report = router.refresh(g.to_payload())
    assert report["prod"]["status"] == "swapped"
    assert report["canary"]["status"] == "swapped"
    a, b = router.predict("prod"), router.predict("canary")
    assert a["ref"] != b["ref"]
    assert a["y"] != b["y"]

    # deriving an experiment FROM main must not advance prod
    g.add_edge("main", "experiment")
    g.add_node(perturb(base, "L2/w", seed=5), "experiment")
    assert router.refresh(g.to_payload())["prod"]["status"] == "unchanged"

    # promote = merge: both branch heads land on the merge node
    g.merge("main", "ab-test")
    r3 = router.refresh(g.to_payload())
    assert r3["prod"]["status"] == "swapped"
    assert r3["prod"]["node"] == "merge(main,ab-test)"
    assert r3["canary"]["node"] == "merge(main,ab-test)"
    assert (router.predict("prod")["ref"]
            == router.predict("canary")["ref"])


def test_quarantine_gates_traffic(repo):
    _, store, g, base = repo
    pool = ModelPool(store)
    router = Router(pool, ["prod=branch:main"])
    router.refresh(g.to_payload())
    good = router.predict("prod")

    g.nodes["main"].metadata["quarantined"] = True
    g.save()
    report = router.refresh(g.to_payload())
    assert report["prod"]["status"] == "gate_blocked"
    assert router.endpoints["prod"].stats()["gate"]
    # the last healthy view keeps serving...
    assert router.predict("prod")["ref"] == good["ref"]

    # ...but an endpoint with no healthy view ever refuses outright
    r2 = Router(pool, ["p2=branch:main"])
    assert r2.refresh(g.to_payload())["p2"]["status"] == "gate_blocked"
    with pytest.raises(EndpointUnavailable, match="quarantined"):
        r2.predict("p2")

    # release: traffic resumes
    g.nodes["main"].metadata["quarantined"] = False
    g.save()
    assert r2.refresh(g.to_payload())["p2"]["status"] == "swapped"
    assert r2.predict("p2")["ref"] == good["ref"]


def test_refresh_failure_isolated_per_endpoint(repo):
    _, store, g, base = repo
    router = Router(ModelPool(store),
                    ["prod=branch:main", "ghost=branch:nope"])
    report = router.refresh(g.to_payload())
    assert report["prod"]["status"] == "swapped"
    assert report["ghost"]["status"] == "error"
    router.predict("prod")
    with pytest.raises(EndpointUnavailable):
        router.predict("ghost")


# ---------------------------------------------------------------------------
# zero-drop hot swap
# ---------------------------------------------------------------------------

def _publish_v2(g, base):
    g.add_node(perturb(base, "L1/w", seed=77), "main@v2")
    g.add_version_edge("main", "main@v2")


def test_swap_is_zero_drop_under_lease(repo):
    _, store, g, base = repo
    router = Router(ModelPool(store), ["prod=branch:main"])
    router.refresh(g.to_payload())
    ep = router.endpoints["prod"]
    with ep.lease() as view:
        before = view.probe()
        _publish_v2(g, base)  # a publish lands mid-request
        assert router.refresh(g.to_payload())["prod"]["status"] == "swapped"
        # the endpoint moved on; the leased view is untouched and draining
        assert ep.current_ref != view.ref
        assert ep.stats()["draining"] == 1
        np.testing.assert_array_equal(view.probe(), before)
    # lease released -> drained view reaped
    assert ep.stats()["draining"] == 0
    assert router.predict("prod")["node"] == "main@v2"


def test_concurrent_predicts_survive_swaps(repo):
    _, store, g, base = repo
    router = Router(ModelPool(store), ["prod=branch:main"])
    p1 = g.to_payload()
    _publish_v2(g, base)
    p2 = g.to_payload()
    router.refresh(p1)
    errors, stop = [], threading.Event()

    def hammer():
        while not stop.is_set():
            try:
                router.predict("prod")
            except Exception as exc:  # noqa: BLE001 — any drop is a failure
                errors.append(exc)
                return

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for payload in (p2, p1, p2, p1, p2):
        router.refresh(payload)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert not errors
    assert router.endpoints["prod"].swaps >= 6  # initial + 5 flips


# ---------------------------------------------------------------------------
# lineage watcher
# ---------------------------------------------------------------------------

def test_local_watcher_detects_publish(repo):
    root, store, g, base = repo
    router = Router(ModelPool(store), ["prod=branch:main"])
    watcher = LineageWatcher(LocalLineageSource(root), router,
                             interval_s=0.01)
    r1 = watcher.poll()
    assert r1["changed"] and r1["endpoints"]["prod"]["status"] == "swapped"
    assert watcher.poll()["changed"] is False  # same etag: no re-resolve
    _publish_v2(g, base)
    r3 = watcher.poll()
    assert r3["changed"]
    assert r3["endpoints"]["prod"]["node"] == "main@v2"
    assert watcher.stats()["changes"] == 2


# ---------------------------------------------------------------------------
# HTTP surface
# ---------------------------------------------------------------------------

def _get(url):
    with urllib.request.urlopen(url, timeout=10) as r:
        return json.loads(r.read())


def _post(url, obj):
    req = urllib.request.Request(
        url, data=json.dumps(obj).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=10) as r:
        return json.loads(r.read())


def test_http_serving_surface(repo):
    root, store, g, base = repo
    router = Router(ModelPool(store),
                    ["prod=branch:main", "canary=branch:ab-test"])
    watcher = LineageWatcher(LocalLineageSource(root), router, interval_s=30)
    watcher.poll()
    server, _ = start_in_thread(ServeApp(router, router.pool, watcher))
    try:
        ping = _get(server.url + "/api/ping")
        assert ping["ok"] and ping["endpoints"] == ["canary", "prod"]
        eps = _get(server.url + "/api/endpoints")["endpoints"]
        assert {e["name"] for e in eps} == {"canary", "prod"}
        pa = _post(server.url + "/api/predict/prod", {})
        pb = _post(server.url + "/api/predict/canary",
                   {"x": [[1.0] * 16]})
        assert pa["ref"] != pb["ref"]

        # merge canary into main, then force one poll over HTTP
        g.merge("main", "ab-test")
        assert _post(server.url + "/api/refresh", {})["changed"]
        pa2 = _post(server.url + "/api/predict/prod", {})
        assert pa2["node"] == "merge(main,ab-test)"

        stats = _get(server.url + "/api/stats")
        assert stats["predictions"] == 3
        assert stats["pool"]["base_ref"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(server.url + "/api/predict/nope", {})
        assert ei.value.code == 400
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(server.url + "/api/nothing")
        assert ei.value.code == 404
    finally:
        server.shutdown()
        server.server_close()


def test_http_gate_refusal_is_503(repo):
    root, store, g, base = repo
    g.nodes["main"].metadata["quarantined"] = True
    g.save()
    router = Router(ModelPool(store), ["prod=branch:main"])
    watcher = LineageWatcher(LocalLineageSource(root), router, interval_s=30)
    watcher.poll()
    app = ServeApp(router, router.pool, watcher)
    server, _ = start_in_thread(app)
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(server.url + "/api/predict/prod", {})
        assert ei.value.code == 503
        assert "quarantined" in json.loads(ei.value.read())["error"]
        assert app.counters["gate_refusals"] == 1
    finally:
        server.shutdown()
        server.server_close()
