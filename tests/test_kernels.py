"""Pallas kernels vs jnp oracles: shape/dtype sweep, interpret=True on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import quant_scale

SHAPES = [(8,), (100,), (128, 128), (257, 33), (1024,), (3, 5, 7),
          (2048, 128), (1, 1)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_delta_quantize_kernel_matches_oracle(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    p2 = jnp.asarray(rng.normal(size=shape), dtype=dtype)
    p1 = p2 + jnp.asarray(rng.normal(scale=1e-4, size=shape), dtype=dtype)
    q_ref, nz_ref = ops.delta_quantize(p1, p2, backend="ref")
    q_pal, nz_pal = ops.delta_quantize(p1, p2, backend="interpret")
    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_pal))
    assert nz_ref == nz_pal


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_dequant_apply_kernel_matches_oracle(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    p1 = jnp.asarray(rng.normal(size=shape), dtype=dtype)
    q = jnp.asarray(rng.integers(-100, 100, size=shape), dtype=jnp.int32)
    out_ref = ops.dequant_apply(p1, q, backend="ref")
    out_pal = ops.dequant_apply(p1, q, backend="interpret")
    np.testing.assert_allclose(np.asarray(out_ref, np.float32),
                               np.asarray(out_pal, np.float32),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [(100,), (257, 33), (128, 128), (3, 5, 7)])
@pytest.mark.parametrize("k", [1, 3, 6])
def test_chain_apply_kernel_matches_oracle(shape, k):
    """Fused chain-apply == base - sum(q)*scale (DESIGN.md §10.2)."""
    rng = np.random.default_rng(hash((shape, k)) % 2**31)
    base = jnp.asarray(rng.normal(size=shape), dtype=jnp.float32)
    qs = [rng.integers(-100, 100, size=shape).astype(np.int8)
          for _ in range(k)]
    out_ref = ops.chain_apply(base, qs, backend="ref")
    out_pal = ops.chain_apply(base, qs, backend="interpret")
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_pal),
                               rtol=1e-6, atol=1e-6)
    # the fold identity vs single dequant of the exact int32 sum
    qsum = np.zeros(shape, np.int32)
    for q in qs:
        qsum += q
    single = ops.dequant_apply(base, qsum, backend="ref",
                               out_dtype="float32")
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(single))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES + [jnp.int32], ids=str)
def test_fingerprint_kernel_matches_oracle(shape, dtype):
    rng = np.random.default_rng(hash(shape) % 2**31)
    if dtype == jnp.int32:
        x = jnp.asarray(rng.integers(-1000, 1000, size=shape), dtype)
    else:
        x = jnp.asarray(rng.normal(size=shape), dtype)
    assert ops.fingerprint(x, backend="ref") == ops.fingerprint(x, backend="interpret")


def test_fingerprint_sensitivity():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)),
                    jnp.float32)
    f0 = ops.fingerprint(x, backend="ref")
    y = x.at[13, 200].add(1e-6)
    assert ops.fingerprint(y, backend="ref") != f0          # value change
    assert ops.fingerprint(x.reshape(128, 512), backend="ref") != f0  # shape salt
    assert ops.fingerprint(x, backend="ref") == f0          # deterministic


@given(scale=st.floats(1e-6, 1e-2), eps=st.sampled_from([1e-5, 1e-4, 1e-3]))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(scale, eps):
    """|dequant(quant(p1-p2)) - (p1-p2)| <= quant step / 2 (+ float eps)."""
    rng = np.random.default_rng(0)
    p2 = rng.normal(size=(500,)).astype(np.float32)
    p1 = (p2 + rng.normal(scale=scale, size=(500,))).astype(np.float32)
    q, _ = ops.delta_quantize(p1, p2, eps=eps, backend="ref")
    rec = np.asarray(ops.dequant_apply(p1, q, eps=eps, backend="ref"))
    assert np.max(np.abs(rec - p2)) <= quant_scale(eps) * 0.51 + 1e-6


def test_zero_stats_prefilter():
    p2 = np.zeros(4096, np.float32)
    p1 = p2.copy()
    p1[:64] += 1.0
    q, nz, blocks = ops.delta_quantize(jnp.asarray(p1), jnp.asarray(p2),
                                       backend="interpret",
                                       return_block_zeros=True)
    assert nz == 4096 - 64
    assert blocks is not None and int(np.sum(blocks)) >= nz


# ---------------------------------------------------------------------------
# fused snapshot kernel (§Perf-C)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(100,), (256, 1024), (257, 33)])
def test_snapshot_fused_matches_unfused(shape):
    rng = np.random.default_rng(hash(shape) % 2**31)
    p2 = jnp.asarray(rng.normal(size=shape), jnp.float32)
    p1 = p2 + jnp.asarray(rng.normal(scale=1e-5, size=shape), jnp.float32)
    q_f, nz_f, fp_f, narrow = ops.snapshot_fused(p1, p2, backend="ref")
    q_u, nz_u = ops.delta_quantize(p1, p2, backend="ref")
    assert narrow  # tiny deltas always fit int8
    np.testing.assert_array_equal(np.asarray(q_f, np.int32), np.asarray(q_u))
    assert nz_f == nz_u
    assert fp_f == ops.fingerprint(p2, backend="ref")


@pytest.mark.parametrize("shape", [(100,), (256, 1024)])
def test_snapshot_fused_interpret_parity(shape):
    rng = np.random.default_rng(0)
    p2 = jnp.asarray(rng.normal(size=shape), jnp.float32)
    p1 = p2 + jnp.asarray(rng.normal(scale=1e-5, size=shape), jnp.float32)
    q_r, nz_r, fp_r, na_r = ops.snapshot_fused(p1, p2, backend="ref")
    q_i, nz_i, fp_i, na_i = ops.snapshot_fused(p1, p2, backend="interpret")
    np.testing.assert_array_equal(np.asarray(q_r), np.asarray(q_i))
    assert (nz_r, fp_r, na_r) == (nz_i, fp_i, na_i)


def test_snapshot_fused_overflow_fallback():
    p2 = jnp.zeros(1000, jnp.float32)
    p1 = p2.at[3].set(1.0)  # delta / 2e-4 = 5000 >> int8
    q, nz, fp, narrow = ops.snapshot_fused(p1, p2, backend="ref")
    assert not narrow
    assert q.dtype == jnp.int32
    assert int(q[3]) > 127


# ---------------------------------------------------------------------------
# flash attention kernel (§Perf iteration 3) — interpret vs dense oracle
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


@pytest.mark.parametrize("spec", [
    dict(B=2, Hq=4, Hkv=2, S=64, hd=16, causal=True),
    dict(B=1, Hq=8, Hkv=1, S=32, hd=8, causal=True),          # MQA
    dict(B=2, Hq=4, Hkv=4, S=64, hd=16, causal=True, window=24),
    dict(B=1, Hq=4, Hkv=2, S=48, hd=16, causal=True, prefix_len=16),
    dict(B=2, Hq=2, Hkv=2, S=64, hd=16, causal=False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=str)
def test_flash_attention_matches_oracle(spec, dtype):
    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, hd = (spec[k] for k in ("B", "Hq", "Hkv", "S", "hd"))
    kw = {k: spec[k] for k in ("causal", "window", "prefix_len") if k in spec}
    q = jnp.asarray(rng.normal(size=(B, Hq, S, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, hd)), dtype)
    out = flash_attention(q, k, v, qc=16, kc=16, interpret=True, **kw)
    ref = flash_attention_ref(q, k, v, **kw)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_hbm_contract():
    from repro.kernels.flash_attention import hbm_bytes
    # q+out once, k+v per q block
    b = hbm_bytes(B=1, Hq=4, Hkv=2, Sq=1024, Skv=1024, hd=64, qc=512)
    assert b == (2 * 1 * 4 * 1024 * 64 * 2) + 2 * (2 * 1 * 2 * 1024 * 64 * 2)
