"""Fault-injecting and in-process transports for hub tests.

``RacingTransport`` and ``FlakyHttpTransport`` were born inline in
test_hub_http.py (PR 5); they live here now so every suite can inject the
same races. ``AppTransport`` is new: the full Transport interface over an
in-process :class:`~repro.hub.app.HubApp`, so property tests and stress
sequences can drive the real publish/import/finalize/GC code paths without
sockets — deterministic and ~100x faster than loopback HTTP."""

from __future__ import annotations

import threading
from typing import Dict, Mapping, Optional, Sequence, Set, Tuple

from repro.remote.http import HttpTransport
from repro.remote.transport import Transport


class RacingTransport(HttpTransport):
    """Injects a competing publish between our fetch and our publish —
    the tightest interleaving the optimistic swap must survive."""

    def __init__(self, url, app, racer_payload, **kw):
        super().__init__(url, **kw)
        self._app = app
        self._racer_payload = racer_payload
        self._raced = False

    def publish_lineage(self, payload, expected=None):
        if not self._raced:
            self._raced = True
            self._app.publish(self._racer_payload)  # the racer lands first
        return super().publish_lineage(payload, expected=expected)


class FlakyHttpTransport(HttpTransport):
    """Connection drops after N successful object uploads."""

    def __init__(self, url, fail_after=1, **kw):
        super().__init__(url, **kw)
        self.fail_after = fail_after
        self._writes = 0
        self._guard = threading.Lock()

    def write_objects(self, objects):
        with self._guard:
            self._writes += 1
            n = self._writes
        if n > self.fail_after:
            raise ConnectionError("simulated mid-push network drop")
        super().write_objects(objects)


class AppTransport(Transport):
    """In-process Transport over a HubApp: same locks, same kill-points,
    same refcount accounting as the HTTP path, minus the socket layer."""

    def __init__(self, app) -> None:
        self.app = app
        self.url = f"app://{app.name}"

    def ensure_repo(self) -> None:
        pass

    def fetch_lineage(self) -> Optional[Dict]:
        return self.app.lineage()[0]

    def fetch_lineage_versioned(self) -> Tuple[Optional[Dict], str]:
        return self.app.lineage()

    def publish_lineage(self, payload: Dict,
                        expected: Optional[str] = None) -> Optional[Dict]:
        return self.app.publish(payload, expected=expected)

    def have(self, keys: Sequence[str]) -> Set[str]:
        return set(self.app.have(keys))

    def read_objects(self, keys: Sequence[str]) -> Dict[str, bytes]:
        cas = self.app.store.cas
        return {k: cas.get_bytes(k) for k in keys if cas.has(k)}

    def object_sizes(self, keys: Sequence[str]) -> Optional[Dict[str, int]]:
        sizes, _missing = self.app.object_sizes(keys)
        return sizes

    def write_objects(self, objects: Mapping[str, bytes]) -> None:
        self.app.import_objects(dict(objects))

    def finalize(self, roots: Sequence[str]) -> None:
        self.app.finalize()

    def journal_load(self, transfer_id: str) -> Optional[Dict]:
        return self.app.journal.journal_load(transfer_id)

    def journal_write(self, transfer_id: str, payload: Dict) -> None:
        self.app.journal.journal_write(transfer_id, payload)

    def journal_clear(self, transfer_id: str) -> None:
        self.app.journal.journal_clear(transfer_id)

    def journal_list(self) -> Sequence[str]:
        return self.app.journal.journal_list()
