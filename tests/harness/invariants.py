"""Post-fault invariant checks — the assertions every scenario ends with.

A fault test that only checks "it didn't crash" proves nothing; these
verify the §16 contract: the store is fsck-clean, the refcount table is
*exactly* what a from-scratch replay of the surviving roots would build,
and surviving heads are bit-identical to their source of truth."""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import numpy as np


def check_refcounts(service, converged: bool = False) -> None:
    """Reachable keys must carry exactly the expected-replay counts.

    Unreachable-but-counted keys are legal mid-flight — in-flight imports
    and orphans still waiting out their grace/confirmation cycles. With
    ``converged=True`` (call it after a few quiescent maintenance cycles)
    they must be gone too: that is the GC convergence guarantee."""
    store = service.store
    expected = {k: v for k, v in
                store.expected_refcounts(service.all_roots()).items() if v > 0}
    with store.cas._lock:
        actual = {k: v for k, v in store.cas.refcounts.items() if v > 0}
    reachable = {k: v for k, v in actual.items() if k in expected}
    assert reachable == expected, (
        f"refcount divergence on reachable keys: "
        f"mismatched={[k for k in expected if reachable.get(k) != expected[k]][:5]} "
        f"missing={sorted(set(expected) - set(reachable))[:5]}")
    if converged:
        stray = set(actual) - set(expected)
        assert not stray, (
            f"unreachable keys still counted after convergence: "
            f"{sorted(stray)[:5]}")


def check_service(service, converged: bool = False) -> Dict[str, Any]:
    """Full §16 invariant bundle: fsck clean + exact refcounts."""
    report = service.fsck()
    assert report["ok"], report
    assert not report.get("refcount_drift"), report
    check_refcounts(service, converged=converged)
    return report


def assert_bit_identical(g1, g2,
                         names: Optional[Sequence[str]] = None) -> None:
    """Every named node's params load bit-for-bit equal from both graphs."""
    for name in names or g1.nodes:
        a = g1.store.load_artifact(g1.nodes[name].artifact_ref)
        b = g2.store.load_artifact(g2.nodes[name].artifact_ref)
        assert set(a.params) == set(b.params), name
        for k in a.params:
            x, y = np.asarray(a.params[k]), np.asarray(b.params[k])
            assert x.dtype == y.dtype and x.shape == y.shape, (name, k)
            assert np.array_equal(x, y), (name, k)
