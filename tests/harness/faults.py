"""pytest-facing wrappers over the production kill-point registry.

The registry itself lives in :mod:`repro.common.faults` (it must import
from production code). These helpers add the two things tests want on
top: scoped arming that cannot leak into the next test, and readable
names for the two firing modes."""

from __future__ import annotations

import contextlib
from typing import Callable, Iterator, Optional

from repro.common import faults as _faults

KillPointError = _faults.KillPointError
fired = _faults.fired
disarm_all = _faults.disarm_all


@contextlib.contextmanager
def crash_at(name: str, after: int = 0, count: int = 1) -> Iterator[None]:
    """Arm ``name`` to raise :class:`KillPointError` on its next ``count``
    hits (after skipping ``after``), disarming on exit either way."""
    _faults.arm(name, after=after, count=count)
    try:
        yield
    finally:
        _faults.disarm(name)


@contextlib.contextmanager
def callback_at(name: str, callback: Callable[[], None], after: int = 0,
                count: int = 1) -> Iterator[None]:
    """Arm ``name`` to run ``callback`` in the hitting thread — the
    deterministic replacement for hand-rolled sleep-based interleavings:
    the competing operation executes at exactly the instrumented seam."""
    _faults.arm(name, after=after, count=count, callback=callback)
    try:
        yield
    finally:
        _faults.disarm(name)
