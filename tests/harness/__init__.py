"""Deterministic fault-injection toolkit for hub/store tests (DESIGN.md §16.6).

Three layers, composable per scenario:

* **kill-point helpers** (:mod:`harness.faults`) — context managers over
  :mod:`repro.common.faults` that arm a named production seam to crash
  (:func:`crash_at`) or run a competing operation in the hitting thread
  (:func:`callback_at`), and disarm on exit even when the test fails;
* **fault transports** (:mod:`harness.transports`) — transport subclasses
  injecting races and connection drops at client-visible seams
  (``RacingTransport``/``FlakyHttpTransport``, ported from their original
  inline homes in test_hub_http.py), plus ``AppTransport``, an in-process
  socketless Transport over a HubApp for fast deterministic sequences;
* **invariant checks** (:mod:`harness.invariants`) — the assertions every
  fault scenario must end with: fsck clean, refcounts exactly equal to an
  expected-replay, heads bit-identical.
"""

from harness.faults import (KillPointError, callback_at, crash_at,
                            disarm_all, fired)
from harness.invariants import (assert_bit_identical, check_refcounts,
                                check_service)
from harness.transports import (AppTransport, FlakyHttpTransport,
                                RacingTransport)

__all__ = [
    "KillPointError", "crash_at", "callback_at", "disarm_all", "fired",
    "AppTransport", "FlakyHttpTransport", "RacingTransport",
    "assert_bit_identical", "check_refcounts", "check_service",
]
