"""Lineage graph API (paper Tables 1-2): nodes, edges, traversals, tests."""

import os

import pytest

from repro.core import LineageGraph, bfs, bisect, dfs, version_chain
from repro.core.lineage import RegisteredTest

from helpers import finetune_like, l2_test, make_chain_model


@pytest.fixture
def graph(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    root = make_chain_model(seed=0)
    g.add_node(root, "root")
    for i in range(3):
        child = finetune_like(root, seed=10 + i)
        g.add_node(child, f"child{i}")
        g.add_edge("root", f"child{i}")
    return g


def test_add_and_query(graph):
    assert len(graph) == 4
    assert [n.name for n in graph.roots()] == ["root"]
    assert sorted(graph.nodes["root"].children) == ["child0", "child1", "child2"]
    assert graph.nodes["child0"].parents == ["root"]


def test_persistence_roundtrip(graph, tmp_path):
    g2 = LineageGraph(path=str(tmp_path))
    assert set(g2.nodes) == set(graph.nodes)
    assert g2.nodes["child1"].parents == ["root"]


def test_version_edges_and_chain(graph):
    v2 = finetune_like(graph.get_model("child0"), seed=99)
    graph.add_node(v2, "child0@v2")
    graph.add_version_edge("child0", "child0@v2")
    chain = [n.name for n in version_chain(graph, "child0@v2")]
    assert chain == ["child0", "child0@v2"]
    assert graph.get_next_version("child0").name == "child0@v2"


def test_version_edge_type_mismatch(graph):
    other = make_chain_model(seed=5, model_type="other")
    graph.add_node(other, "other")
    with pytest.raises(ValueError):
        graph.add_version_edge("root", "other")


def test_remove_node_subtree(graph):
    gc = finetune_like(graph.get_model("child0"), seed=42)
    graph.add_node(gc, "gc")
    graph.add_edge("child0", "gc")
    graph.remove_node("child0")
    assert "child0" not in graph
    assert "gc" not in graph  # subtree removed
    assert "child1" in graph


def test_bfs_dfs_orders(graph):
    names_bfs = [n.name for n in bfs(graph)]
    names_dfs = [n.name for n in dfs(graph)]
    assert names_bfs[0] == "root" and names_dfs[0] == "root"
    assert set(names_bfs) == set(names_dfs) == set(graph.nodes)


def test_skip_and_terminate(graph):
    out = [n.name for n in bfs(graph, skip_fn=lambda n: n.name == "child1")]
    assert "child1" not in out and "child2" in out
    out = [n.name for n in bfs(graph, terminate_fn=lambda n: n.name.startswith("child"))]
    assert out == ["root"]


def test_run_tests_with_regex(graph):
    graph.register_test_function(l2_test, "probe/l2", mt="toy")
    graph.register_test_function(lambda m: 1.0, "other", mt="toy")
    results = graph.run_tests(bfs(graph), pattern="probe.*", match="regex")
    assert set(results) == set(graph.nodes)
    assert all(set(v) == {"probe/l2"} for v in results.values())
    graph.deregister_test_function("probe/l2", mt="toy")
    assert all(t.name != "probe/l2" for t in graph.tests)


def test_run_tests_pattern_modes_are_explicit(graph):
    """Regex and glob are distinct modes — "l2*" as a glob anchors both
    ends and misses "acc/l2"; as a regex, re.search finds it."""
    graph.register_test_function(lambda m: 1.0, "acc/l2", mt="toy")
    assert graph.run_tests(bfs(graph), pattern="l2*", match="regex")
    assert not graph.run_tests(bfs(graph), pattern="l2*", match="glob")
    assert graph.run_tests(bfs(graph), pattern="acc*", match="glob")
    with pytest.raises(ValueError):
        graph.run_tests(bfs(graph), pattern="x", match="bogus")


def test_run_tests_re_pattern_deprecation_shim(graph):
    """The legacy kwarg warns but keeps the old regex-OR-glob union."""
    graph.register_test_function(lambda m: 1.0, "acc/l2", mt="toy")
    with pytest.warns(DeprecationWarning):
        legacy = graph.run_tests(bfs(graph), re_pattern="acc*")
    assert legacy  # matched via the glob half of the union
    with pytest.raises(ValueError):
        graph.run_tests(bfs(graph), re_pattern="a", pattern="b")


def test_run_function(graph):
    out = graph.run_function(bfs(graph), lambda m: m.nbytes())
    assert set(out) == set(graph.nodes)
    assert all(v > 0 for v in out.values())


def test_bisect_finds_first_failing(graph):
    # version chain v1..v8; versions >= 5 "fail"
    prev = "child0"
    for v in range(2, 9):
        m = finetune_like(graph.get_model(prev), seed=v)
        m.metadata["broken"] = v >= 5
        name = f"child0@v{v}"
        graph.add_node(m, name)
        graph.add_version_edge(prev, name)
        prev = name
    calls = []

    def failing(node):
        calls.append(node.name)
        return bool(node.get_model().metadata.get("broken"))

    first = bisect(graph, "child0", failing)
    assert first.name == "child0@v5"
    assert len(calls) < 8  # fewer probes than linear scan


def test_bisect_no_failure(graph):
    assert bisect(graph, "child0", lambda n: False) is None


def _make_versions(graph, n, first_bad):
    prev = "child0"
    for v in range(2, n + 1):
        m = finetune_like(graph.get_model(prev), seed=v)
        m.metadata["broken"] = v >= first_bad
        name = f"child0@v{v}"
        graph.add_node(m, name)
        graph.add_version_edge(prev, name)
        prev = name


def _broken(node):
    return bool(node.get_model().metadata.get("broken"))


def test_bisect_single_node_chain(graph):
    # no version edges at all: a one-element chain
    assert bisect(graph, "child1", lambda n: False) is None
    assert bisect(graph, "child1", lambda n: True).name == "child1"


def test_bisect_all_versions_passing(graph):
    _make_versions(graph, n=6, first_bad=99)
    assert bisect(graph, "child0", _broken) is None


def test_bisect_failure_at_chain_head(graph):
    _make_versions(graph, n=6, first_bad=0)   # every version broken
    graph.get_model("child0").metadata["broken"] = True
    assert bisect(graph, "child0", _broken).name == "child0"


def test_bisect_skip_fn_excludes_unprobeable_versions(graph):
    _make_versions(graph, n=8, first_bad=5)
    # the true first-bad version cannot be probed: the search lands on the
    # first failing version that CAN be (git-bisect-skip semantics)
    found = bisect(graph, "child0", _broken,
                   skip_fn=lambda n: n.name == "child0@v5")
    assert found.name == "child0@v6"
    # skipping passing versions must not change the answer
    found = bisect(graph, "child0", _broken,
                   skip_fn=lambda n: n.name in ("child0@v2", "child0@v3"))
    assert found.name == "child0@v5"
    # probes never land on skipped nodes
    probed = []

    def failing(node):
        probed.append(node.name)
        return _broken(node)

    bisect(graph, "child0", failing, skip_fn=lambda n: n.name == "child0@v4")
    assert "child0@v4" not in probed


def test_bisect_skip_everything(graph):
    _make_versions(graph, n=4, first_bad=2)
    assert bisect(graph, "child0", _broken, skip_fn=lambda n: True) is None
