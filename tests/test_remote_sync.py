"""Remote sync engine: push/pull/clone, negotiation dedup, merge, fsck (§5/DESIGN.md §8)."""

import json
import os

import numpy as np
import pytest

from repro.core import (CONFLICT, NO_CONFLICT, POSSIBLE_CONFLICT, LayerGraph,
                        LayerNode, LineageGraph, ModelArtifact)
from repro.remote import (LocalTransport, RemoteState, clone, merge_lineage,
                          pull, push, remote_add, remote_list, remote_remove,
                          resolve_transport)
from repro.store import ArtifactStore

from helpers import finetune_like, make_chain_model


def _repo(path, **store_kw):
    path = str(path)
    return LineageGraph(path=path, store=ArtifactStore(root=path, **store_kw))


def _seed_repo(path):
    """base -> ft chain with a version edge (delta-compressed storage)."""
    g = _repo(path)
    base = make_chain_model(seed=0, d=32)
    g.add_node(base, "m@v1")
    g.add_edge("m@v1", "m@v2")
    g.add_node(finetune_like(base, seed=1), "m@v2")
    g.add_version_edge("m@v1", "m@v2")
    return g

def _stored(g, name):
    return g.store.load_artifact(g.nodes[name].artifact_ref)


def _assert_bit_identical(g1, g2, names=None):
    for name in names or g1.nodes:
        a, b = _stored(g1, name), _stored(g2, name)
        assert set(a.params) == set(b.params)
        for k in a.params:
            np.testing.assert_array_equal(np.asarray(a.params[k]),
                                          np.asarray(b.params[k]))


def _roots(g):
    return [n.artifact_ref for n in g.nodes.values() if n.artifact_ref]


# ---------------------------------------------------------------------------
# Round trip + negotiation dedup (the acceptance criteria)
# ---------------------------------------------------------------------------


def test_push_clone_roundtrip_bit_identical(tmp_path):
    g = _seed_repo(tmp_path / "src")
    rep = push(g, LocalTransport(str(tmp_path / "remote")),
               state=RemoteState(g.path, "origin"))
    assert rep.published and rep.objects_transferred == rep.objects_total > 0

    clone(str(tmp_path / "remote"), str(tmp_path / "dst"))
    g2 = _repo(tmp_path / "dst")
    assert sorted(g2.nodes) == sorted(g.nodes)
    # content-addressed refs survive the round trip unchanged
    for name in g.nodes:
        assert g2.nodes[name].artifact_ref == g.nodes[name].artifact_ref
    assert g2.nodes["m@v2"].parents == ["m@v1"]
    assert g2.nodes["m@v1"].version_children == ["m@v2"]
    _assert_bit_identical(g, g2)
    # both sides pass integrity checks with exact refcounts
    assert g.store.fsck(_roots(g))["ok"]
    assert g2.store.fsck(_roots(g2))["ok"]


def test_second_push_transfers_zero_objects(tmp_path):
    g = _seed_repo(tmp_path / "src")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g, remote, state=RemoteState(g.path, "origin"))
    rep = push(g, remote, state=RemoteState(g.path, "origin"))
    assert rep.objects_transferred == 0
    assert rep.bytes_transferred == 0
    assert rep.dedup_ratio == 1.0


def test_incremental_push_transfers_only_new_objects(tmp_path):
    g = _seed_repo(tmp_path / "src")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g, remote, state=RemoteState(g.path, "origin"))
    g.add_edge("m@v2", "m@v3")
    g.add_node(finetune_like(_stored(g, "m@v2"), seed=3), "m@v3")
    rep = push(g, remote, state=RemoteState(g.path, "origin"))
    assert 0 < rep.objects_transferred < rep.objects_total
    g2 = _repo(tmp_path / "dst")
    pull(g2, remote)
    _assert_bit_identical(g, g2)


def test_pull_into_fresh_repo_equals_clone(tmp_path):
    g = _seed_repo(tmp_path / "src")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g, remote)
    g2 = _repo(tmp_path / "dst")
    rep = pull(g2, remote)
    assert rep.merge.status == NO_CONFLICT
    assert sorted(g2.nodes) == sorted(g.nodes)
    _assert_bit_identical(g, g2)


# ---------------------------------------------------------------------------
# Shallow (filtered) sync + delta-chain awareness
# ---------------------------------------------------------------------------


def test_shallow_clone_filters_nodes_but_completes_chains(tmp_path):
    g = _seed_repo(tmp_path / "src")
    push(g, LocalTransport(str(tmp_path / "remote")))
    clone(str(tmp_path / "remote"), str(tmp_path / "dst"), filter="m@v2")
    g2 = _repo(tmp_path / "dst")
    assert sorted(g2.nodes) == ["m@v2"]
    assert g2.nodes["m@v2"].parents == []  # dangling edges pruned
    # the delta chain rode along as storage-only objects: params materialize
    _assert_bit_identical(g, g2, names=["m@v2"])
    assert g2.store.fsck(_roots(g2))["ok"]


def test_shallow_push_flattens_when_base_missing(tmp_path):
    g = _seed_repo(tmp_path / "src")
    assert g.store.get_manifest(g.nodes["m@v2"].artifact_ref)["depth"] >= 1
    before = g.store.cas.object_count()
    rep = push(g, LocalTransport(str(tmp_path / "remote")), filter="m@v2")
    assert rep.flattened  # chain base not selected + absent remotely
    gr = _repo(tmp_path / "remote")
    manifest = gr.store.get_manifest(gr.nodes["m@v2"].artifact_ref)
    assert manifest["depth"] == 0
    assert all(e["kind"] == "full" for e in manifest["params"].values())
    _assert_bit_identical(g, gr, names=["m@v2"])
    # flattening is transient: the SENDER's store gained nothing and stays
    # refcount-clean (no orphan manifest, no shared-tensor drift)
    assert g.store.cas.object_count() == before
    assert g.store.fsck(_roots(g))["ok"]


def test_shallow_push_prefers_delta_when_base_present(tmp_path):
    g = _seed_repo(tmp_path / "src")
    remote = str(tmp_path / "remote")
    push(g, LocalTransport(remote), filter="m@v1")
    rep = push(g, LocalTransport(remote), filter="m@v2")
    assert rep.flattened == {}
    gr = _repo(remote)
    assert (gr.nodes["m@v2"].artifact_ref == g.nodes["m@v2"].artifact_ref)
    assert gr.store.get_manifest(gr.nodes["m@v2"].artifact_ref)["depth"] >= 1


# ---------------------------------------------------------------------------
# Concurrent growth: three-way lineage merge on pull
# ---------------------------------------------------------------------------


def test_pull_merges_concurrently_grown_graphs(tmp_path):
    g = _seed_repo(tmp_path / "src")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g, remote, state=RemoteState(g.path, "origin"))
    clone(str(tmp_path / "remote"), str(tmp_path / "dst"))
    g2 = _repo(tmp_path / "dst")

    g.add_edge("m@v2", "m@v3")
    g.add_node(finetune_like(_stored(g, "m@v2"), seed=7), "m@v3")
    push(g, remote, state=RemoteState(g.path, "origin"))

    g2.add_edge("m@v1", "side")
    g2.add_node(finetune_like(_stored(g2, "m@v1"), seed=8), "side")
    rep = pull(g2, LocalTransport(str(tmp_path / "remote")),
               state=RemoteState(g2.path, "origin"))
    assert rep.merge.status == NO_CONFLICT
    assert sorted(g2.nodes) == ["m@v1", "m@v2", "m@v3", "side"]
    assert "side" in g2.nodes["m@v1"].children  # local edge survived
    assert "m@v3" in g2.nodes["m@v2"].children  # remote edge merged in
    # the merged document persisted and reloads
    g3 = LineageGraph(path=g2.path)
    assert sorted(g3.nodes) == sorted(g2.nodes)


def test_pull_divergent_same_layer_is_conflict_keeps_local(tmp_path):
    g = _seed_repo(tmp_path / "src")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g, remote, state=RemoteState(g.path, "origin"))
    clone(str(tmp_path / "remote"), str(tmp_path / "dst"))
    g2 = _repo(tmp_path / "dst")

    d = np.asarray(_stored(g, "m@v1").params["L0/w"]).shape[0]
    g.add_node(_stored(g, "m@v1").replace_params(
        {"L0/w": np.zeros((d, d), np.float32)}), "m@v1")
    push(g, remote, state=RemoteState(g.path, "origin"), force=True)
    remote_ref = g.nodes["m@v1"].artifact_ref
    g2.add_node(_stored(g2, "m@v1").replace_params(
        {"L0/w": np.ones((d, d), np.float32)}), "m@v1")
    local_ref = g2.nodes["m@v1"].artifact_ref

    rep = pull(g2, LocalTransport(str(tmp_path / "remote")),
               state=RemoteState(g2.path, "origin"))
    assert rep.merge.status == CONFLICT
    assert rep.merge.conflicts == ["m@v1"]
    assert g2.nodes["m@v1"].artifact_ref == local_ref  # local kept


def test_conflict_does_not_advance_base_so_push_still_refuses(tmp_path):
    """A conflicted pull must NOT record the remote's version as agreed:
    otherwise the next plain push would classify the still-divergent node
    as fast-forward and silently clobber the remote (lost update)."""
    g = _seed_repo(tmp_path / "src")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g, remote, state=RemoteState(g.path, "origin"))
    clone(str(tmp_path / "remote"), str(tmp_path / "dst"))
    g2 = _repo(tmp_path / "dst")

    d = np.asarray(_stored(g, "m@v1").params["L0/w"]).shape[0]
    g.add_node(_stored(g, "m@v1").replace_params(
        {"L0/w": np.zeros((d, d), np.float32)}), "m@v1")
    push(g, remote, state=RemoteState(g.path, "origin"), force=True)
    remote_ref = g.nodes["m@v1"].artifact_ref
    g2.add_node(_stored(g2, "m@v1").replace_params(
        {"L0/w": np.ones((d, d), np.float32)}), "m@v1")

    rep = pull(g2, LocalTransport(str(tmp_path / "remote")),
               state=RemoteState(g2.path, "origin"))
    assert rep.merge.status == CONFLICT

    rep = push(g2, LocalTransport(str(tmp_path / "remote")),
               state=RemoteState(g2.path, "origin"))
    assert not rep.published  # non-fast-forward still detected
    gr = _repo(tmp_path / "remote")
    assert gr.nodes["m@v1"].artifact_ref == remote_ref  # remote intact


def test_pull_auto_merges_independent_model_edits(tmp_path):
    gph = LayerGraph()
    for n in ("stem", "head_a", "head_b"):
        gph.add_node(LayerNode(n, "linear", params={"w": ((8, 8), "float32")}))
    gph.add_edge("stem", "head_a")
    gph.add_edge("stem", "head_b")
    rng = np.random.default_rng(0)
    art = ModelArtifact(gph, {f"{n}/w": rng.normal(size=(8, 8)).astype(
        np.float32) for n in gph.nodes}, model_type="toy")

    g = _repo(tmp_path / "src", delta_enabled=False)
    g.add_node(art, "model")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g, remote, state=RemoteState(g.path, "origin"))
    clone(str(tmp_path / "remote"), str(tmp_path / "dst"))
    g2 = _repo(tmp_path / "dst", delta_enabled=False)

    a = _stored(g, "model")
    g.add_node(a.replace_params(
        {"head_a/w": np.asarray(a.params["head_a/w"]) + 1}), "model")
    push(g, remote, state=RemoteState(g.path, "origin"))
    b = _stored(g2, "model")
    g2.add_node(b.replace_params(
        {"head_b/w": np.asarray(b.params["head_b/w"]) + 2}), "model")

    rep = pull(g2, LocalTransport(str(tmp_path / "remote")),
               state=RemoteState(g2.path, "origin"))
    assert rep.merge.status == NO_CONFLICT
    merged = _stored(g2, "model")
    np.testing.assert_allclose(np.asarray(merged.params["head_a/w"]),
                               np.asarray(a.params["head_a/w"]) + 1)
    np.testing.assert_allclose(np.asarray(merged.params["head_b/w"]),
                               np.asarray(b.params["head_b/w"]) + 2)


def test_push_conflict_aborts_unless_forced(tmp_path):
    g = _seed_repo(tmp_path / "src")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g, remote, state=RemoteState(g.path, "origin"))

    # a second user rewrites m@v1 on the remote
    other = _repo(tmp_path / "other")
    pull(other, remote, state=RemoteState(other.path, "origin"))
    other.add_node(finetune_like(_stored(other, "m@v1"), seed=42), "m@v1")
    push(other, remote, state=RemoteState(other.path, "origin"))

    g.add_node(finetune_like(_stored(g, "m@v1"), seed=43), "m@v1")
    rep = push(g, remote, state=RemoteState(g.path, "origin"))
    assert not rep.published and rep.merge.status == CONFLICT
    rep = push(g, remote, state=RemoteState(g.path, "origin"), force=True)
    assert rep.published
    gr = _repo(tmp_path / "remote")
    assert gr.nodes["m@v1"].artifact_ref == g.nodes["m@v1"].artifact_ref


def test_merge_lineage_edge_union_and_deletion():
    base = {"nodes": [
        {"name": "a", "parents": [], "children": ["b"], "artifact_ref": "r1"},
        {"name": "b", "parents": ["a"], "children": [], "artifact_ref": "r2"},
    ]}
    ours = {"nodes": [
        {"name": "a", "parents": [], "children": ["b", "c"],
         "artifact_ref": "r1"},
        {"name": "b", "parents": ["a"], "children": [], "artifact_ref": "r2"},
        {"name": "c", "parents": ["a"], "children": [], "artifact_ref": "r3"},
    ]}
    theirs = {"nodes": [  # deleted b, added d
        {"name": "a", "parents": [], "children": ["d"], "artifact_ref": "r1"},
        {"name": "d", "parents": ["a"], "children": [], "artifact_ref": "r4"},
    ]}
    merged, report = merge_lineage(base, ours, theirs)
    names = {n["name"] for n in merged["nodes"]}
    assert names == {"a", "c", "d"}  # b's deletion propagated
    a = next(n for n in merged["nodes"] if n["name"] == "a")
    assert set(a["children"]) == {"c", "d"}  # union minus deleted
    assert report.status == NO_CONFLICT


# ---------------------------------------------------------------------------
# Interrupted transfer: journal + resume + consistency
# ---------------------------------------------------------------------------


class FlakyTransport(LocalTransport):
    """Drops the connection after N successful object batches."""

    def __init__(self, url, fail_after=1):
        super().__init__(url)
        self.writes = 0
        self.fail_after = fail_after

    def write_objects(self, objects):
        self.writes += 1
        if self.writes > self.fail_after:
            raise IOError("simulated network drop")
        super().write_objects(objects)


def test_interrupted_push_leaves_remote_consistent_and_resumes(tmp_path):
    g = _repo(tmp_path / "src")
    g.add_node(make_chain_model(seed=0, d=48, n_layers=6), "m@v1")
    remote_dir = str(tmp_path / "remote")

    with pytest.raises(IOError):
        push(g, FlakyTransport(remote_dir, fail_after=1), chunk_size=3,
             state=RemoteState(g.path, "origin"))
    # consistency: the lineage document never published...
    assert not os.path.exists(os.path.join(remote_dir, "lineage.json"))
    # ...and the journal records the in-flight transfer for fsck
    journal_dir = os.path.join(remote_dir, "transfers")
    assert len(os.listdir(journal_dir)) == 1

    rep = push(g, LocalTransport(remote_dir), chunk_size=3,
               state=RemoteState(g.path, "origin"))
    assert rep.published
    # negotiation skipped everything the crashed attempt already landed
    assert rep.objects_transferred < rep.objects_total
    assert os.listdir(journal_dir) == []  # journal retired
    gr = _repo(remote_dir)
    assert gr.store.fsck(_roots(gr))["ok"]
    _assert_bit_identical(g, gr)


def test_stale_journal_does_not_suppress_transfer(tmp_path):
    """The want-list is authoritative over the journal: a forged/stale done
    marker for objects the receiver does NOT have must not skip them."""
    g = _repo(tmp_path / "src")
    g.add_node(make_chain_model(seed=1, d=32), "m@v1")
    remote_dir = str(tmp_path / "remote")
    t = LocalTransport(remote_dir)

    from repro.remote import transfer_id, chunk_id
    from repro.remote.negotiate import chunked, plan_transfer, walk_manifests
    from repro.remote.sync import _local_fetch
    closure = walk_manifests(_local_fetch(g.store),
                             [g.nodes["m@v1"].artifact_ref])
    plan = plan_transfer(closure, set())
    tid = transfer_id(plan.order, "push")
    first = list(chunked(plan.order, 3))[0]
    t.ensure_repo()
    # journal claims the first chunk landed — but nothing did
    t.journal_write(tid, {"done": [chunk_id(first)], "total": 0})

    rep = push(g, t, chunk_size=3, state=RemoteState(g.path, "origin"))
    assert rep.chunks_resumed == 0          # no credit for a stale marker
    assert rep.objects_transferred == rep.objects_total  # everything moved
    assert rep.published
    gr = _repo(remote_dir)
    assert gr.store.fsck(_roots(gr))["ok"]  # nothing lost to the stale entry


def test_journal_resume_after_partial_transfer_matches_chunks(tmp_path):
    """After a REAL partial transfer (some chunks landed), the retry's
    chunk ids still match the journal: chunking is over the stable closure
    order, not the shrunken want-list."""
    g = _repo(tmp_path / "src")
    g.add_node(make_chain_model(seed=0, d=48, n_layers=6), "m@v1")
    remote_dir = str(tmp_path / "remote")
    with pytest.raises(IOError):
        push(g, FlakyTransport(remote_dir, fail_after=2), chunk_size=3,
             state=RemoteState(g.path, "origin"))
    t = LocalTransport(remote_dir)
    tids = t.journal_list()
    assert len(tids) == 1
    done_before = set(t.journal_load(tids[0])["done"])
    assert done_before  # at least one chunk landed and was journalled

    rep = push(g, t, chunk_size=3, state=RemoteState(g.path, "origin"))
    assert rep.published
    # every journalled chunk was recognized and skipped on resume
    assert rep.chunks_resumed == len(done_before)
    assert t.journal_list() == []


# ---------------------------------------------------------------------------
# fsck
# ---------------------------------------------------------------------------


def test_fsck_detects_corruption_and_drift(tmp_path):
    g = _seed_repo(tmp_path / "src")
    roots = _roots(g)
    assert g.store.fsck(roots)["ok"]

    # bit-rot a loose object (force one below the pack threshold first —
    # the throughput default packs everything this small)
    g.store.cas.pack_threshold = 16
    g.store.cas.put_bytes(os.urandom(64))
    objdir = os.path.join(g.path, "objects")
    victim = sorted(os.listdir(objdir))[0]
    path = os.path.join(objdir, victim)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(blob))
    report = g.store.fsck(roots)
    assert not report["ok"] and victim in report["corrupt"]
    with open(path, "wb") as f:  # restore
        f.write(bytes(blob[:len(blob) // 2]
                      + bytes([blob[len(blob) // 2] ^ 0xFF])
                      + blob[len(blob) // 2 + 1:]))
    assert g.store.fsck(roots)["ok"]

    # refcount drift: tamper with one count
    key = next(iter(g.store.expected_refcounts(roots)))
    g.store.cas.refcounts[key] += 5
    report = g.store.fsck(roots)
    assert not report["ok"] and key in report["refcount_drift"]
    actual, expected = report["refcount_drift"][key]
    assert actual == expected + 5
    # and the rebuild repairs it
    g.store.rebuild_refcounts(roots)
    assert g.store.fsck(roots)["ok"]


def test_fsck_reports_dangling_refs(tmp_path):
    g = _seed_repo(tmp_path / "src")
    g.store.cas.refcounts["deadbeef" * 8] = 2
    report = g.store.cas.fsck()
    assert "deadbeef" * 8 in report["dangling_refs"]
    assert not report["ok"]


def test_cli_remote_push_pull_fsck(tmp_path):
    from repro.cli import main as cli
    src = str(tmp_path / "src")
    _seed_repo(src)
    remote = str(tmp_path / "remote")
    dst = str(tmp_path / "dst")
    assert cli(["-C", src, "remote", "add", "origin", remote]) == 0
    assert cli(["-C", src, "push", "origin"]) == 0
    assert cli(["clone", remote, dst]) == 0
    assert cli(["-C", dst, "log"]) == 0
    assert cli(["-C", dst, "fsck"]) == 0
    assert cli(["-C", dst, "pull", "origin"]) == 0
    g2 = LineageGraph(path=dst)
    assert sorted(g2.nodes) == ["m@v1", "m@v2"]


# ---------------------------------------------------------------------------
# Remote configuration + atomic lineage persistence
# ---------------------------------------------------------------------------


def test_remote_config_roundtrip(tmp_path):
    repo = str(tmp_path)
    remote_add(repo, "origin", str(tmp_path / "r1"))
    remote_add(repo, "backup", str(tmp_path / "r2"))
    assert set(remote_list(repo)) == {"origin", "backup"}
    transport, name = resolve_transport(repo, "origin")
    assert name == "origin" and transport.url == str(tmp_path / "r1")
    transport, name = resolve_transport(repo, str(tmp_path / "elsewhere"))
    assert name is None
    remote_remove(repo, "backup")
    assert set(remote_list(repo)) == {"origin"}


def test_lineage_save_leaves_no_temp_and_survives_stale_tmp(tmp_path):
    g = _seed_repo(tmp_path)
    meta = os.path.join(str(tmp_path), "lineage.json")
    assert os.path.exists(meta) and not os.path.exists(meta + ".tmp")
    # a stale tmp from a crashed writer must not confuse load or save
    with open(meta + ".tmp", "w") as f:
        f.write("{ torn json")
    g2 = LineageGraph(path=str(tmp_path))
    assert sorted(g2.nodes) == sorted(g.nodes)
    g2.save()
    assert not os.path.exists(meta + ".tmp")
    assert json.load(open(meta))["nodes"]
