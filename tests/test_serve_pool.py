"""Serving pool (DESIGN.md §13.1): one resident base, delta-derived views,
content-hash aliasing, bit-identity against store truth, LRU eviction."""

import numpy as np
import pytest

from repro.core import LayerGraph, LayerNode, ModelArtifact
from repro.serve import BitIdentityError, ModelPool
from repro.store import ArtifactStore

from helpers import make_chain_model, perturb

# small grid so multi-chunk behavior shows on test-sized tensors
CHUNK_KW = dict(chunk_threshold=64 * 1024, chunk_min=16 * 1024,
                chunk_avg=32 * 1024, chunk_max=64 * 1024)


def seed_store(tmp_path, keys=("L0/w", "L3/w"), **kw):
    """Base model + one single-layer derivative per key, all delta-chained."""
    store = ArtifactStore(root=str(tmp_path), **kw)
    base = make_chain_model(seed=0)
    base_ref = store.commit_artifact("base", base)
    refs = [store.commit_artifact(f"d{i}", perturb(base, key, seed=10 + i),
                                  parent_ref=base_ref)
            for i, key in enumerate(keys)]
    return store, base, base_ref, refs


# ---------------------------------------------------------------------------
# bit-identity + aliasing
# ---------------------------------------------------------------------------

def test_pool_view_bit_identical_to_store_truth(tmp_path):
    store, base, base_ref, (r0, _) = seed_store(tmp_path)
    pool = ModelPool(store)
    view = pool.get(r0)
    truth = store.materialize_artifact(r0)
    assert set(view.params) == set(truth.params)
    for k in truth.params:
        np.testing.assert_array_equal(np.asarray(view.params[k]),
                                      np.asarray(truth.params[k]), err_msg=k)
    # only the perturbed tensor is private; everything else aliases the base
    assert "L0/w" not in view.aliased
    assert len(view.aliased) == len(truth.params) - 1
    assert view.private_bytes < pool.base_bytes
    s = pool.stats()
    assert s["params_aliased"] == len(view.aliased)
    assert s["bytes_aliased"] > 0
    assert s["params_applied"] == 1


def test_pool_aliases_share_memory_across_views(tmp_path):
    store, base, base_ref, (r0, r1) = seed_store(tmp_path)
    pool = ModelPool(store)
    v0, v1 = pool.get(r0), pool.get(r1)
    # unchanged tensors are the SAME resident array in every view
    assert v0.params["L1/w"] is v1.params["L1/w"]
    assert pool.stats()["resident"] == 2
    # two models resident for (far) less than two full copies
    assert pool.private_bytes() < pool.base_bytes


def test_pool_folded_chain_matches_store(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    cur = make_chain_model(seed=0)
    ref = store.commit_artifact("v0", cur)
    for i in range(1, 4):
        cur = perturb(cur, "L1/w", seed=i)
        ref = store.commit_artifact(f"v{i}", cur, parent_ref=ref)
    pool = ModelPool(store)
    view = pool.get(ref)
    truth = store.materialize_artifact(ref)
    for k in truth.params:
        np.testing.assert_array_equal(np.asarray(view.params[k]),
                                      np.asarray(truth.params[k]), err_msg=k)
    if store.get_manifest(ref)["depth"] == 3 and store.fold_enabled:
        s = pool.stats()
        assert s["chain_hops"] >= 3
        assert s["segments_applied"] >= 1


def test_pool_verify_catches_divergence(tmp_path, monkeypatch):
    store, base, base_ref, (r0, _) = seed_store(tmp_path)
    pool = ModelPool(store)
    pool.ensure_base(r0)
    bad = lambda *a, **k: np.zeros((1,), np.float32)  # noqa: E731
    monkeypatch.setattr(pool, "_apply_chain", bad)
    monkeypatch.setattr(store, "materialize_param", bad)
    with pytest.raises(BitIdentityError):
        pool.get(r0)


def test_pool_one_family_guard(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    ra = store.commit_artifact("a", make_chain_model(seed=0))
    rb = store.commit_artifact("b", make_chain_model(seed=7))
    pool = ModelPool(store)
    pool.get(ra)
    with pytest.raises(ValueError, match="one pool per model family"):
        pool.get(rb)


# ---------------------------------------------------------------------------
# chunked params through the pool (kind: chunked)
# ---------------------------------------------------------------------------

def test_pool_chunked_param_bit_identical(tmp_path):
    store = ArtifactStore(root=str(tmp_path), **CHUNK_KW)
    rng = np.random.default_rng(0)
    big = rng.standard_normal((256, 300)).astype(np.float32)  # ~300 KB
    head = rng.standard_normal((300, 4)).astype(np.float32)
    g = LayerGraph.chain([
        LayerNode("big", "linear", params={"w": ((256, 300), "float32")}),
        LayerNode("head", "linear", params={"w": ((300, 4), "float32")}),
    ])
    base = ModelArtifact(g, {"big/w": big, "head/w": head})
    base_ref = store.commit_artifact("base", base)
    edited = big.copy()
    edited.reshape(-1)[:64] += 0.5
    ref = store.commit_artifact("d", base.replace_params({"big/w": edited}),
                                parent_ref=base_ref)
    assert store.get_manifest(ref)["params"]["big/w"]["kind"] == "chunked"
    pool = ModelPool(store)
    view = pool.get(ref)
    truth = store.materialize_artifact(ref)
    for k in truth.params:
        np.testing.assert_array_equal(np.asarray(view.params[k]),
                                      np.asarray(truth.params[k]), err_msg=k)
    # the untouched small param aliases; the chunked edit is verified private
    assert "head/w" in view.aliased
    assert "big/w" not in view.aliased
    assert pool.stats()["params_verified"] >= 1


# ---------------------------------------------------------------------------
# LRU + cache-eviction bit-neutrality
# ---------------------------------------------------------------------------

def test_pool_lru_eviction_and_hits(tmp_path):
    store, base, base_ref, refs = seed_store(
        tmp_path, keys=("L0/w", "L2/w", "L3/w"))
    pool = ModelPool(store, max_resident=2)
    pool.get(refs[0])
    pool.get(refs[0])
    assert pool.stats()["hits"] == 1
    pool.get(refs[1])
    pool.get(refs[2])
    assert len(pool.resident_refs) == 2
    assert refs[0] not in pool.resident_refs
    assert pool.stats()["evictions"] == 1
    # an evicted ref rebuilds on demand, bit-identical again
    view = pool.get(refs[0])
    truth = store.materialize_artifact(refs[0])
    np.testing.assert_array_equal(np.asarray(view.params["L0/w"]),
                                  np.asarray(truth.params["L0/w"]))


def test_pool_budget_evicts_private_bytes(tmp_path):
    store, base, base_ref, refs = seed_store(tmp_path)
    pool = ModelPool(store, budget_bytes=1)  # any private byte is over
    pool.get(refs[0])
    pool.get(refs[1])
    assert pool.resident_refs == [refs[1]]  # never evicts below one view
    assert pool.stats()["evictions"] == 1


def test_store_reload_picks_up_foreign_commits(tmp_path):
    """A long-running reader (serve daemon) sees another process's commit
    after ``reload()`` — the cross-process hot-swap path."""
    writer = ArtifactStore(root=str(tmp_path))
    base = make_chain_model(seed=0)
    base_ref = writer.commit_artifact("base", base)
    reader = ArtifactStore(root=str(tmp_path))  # snapshot of the index now
    ref = writer.commit_artifact("d", perturb(base, "L0/w", seed=3),
                                 parent_ref=base_ref)
    with pytest.raises(KeyError):
        reader.get_manifest(ref)
    reader.reload()
    view = ModelPool(reader).get(ref)
    truth = writer.materialize_artifact(ref)
    for k in truth.params:
        np.testing.assert_array_equal(np.asarray(view.params[k]),
                                      np.asarray(truth.params[k]), err_msg=k)


def test_pool_rebuild_after_cache_clear_is_bit_neutral(tmp_path):
    store, base, base_ref, (r0, r1) = seed_store(tmp_path)
    pool = ModelPool(store, max_resident=1)
    first = {k: np.asarray(v).copy()
             for k, v in pool.get(r0).params.items()}
    pool.get(r1)  # evicts r0's view
    assert pool.stats()["evictions"] == 1
    # drop the store's tensor + fold caches: the rebuild must come cold off
    # the CAS and still be byte-for-byte what the first build produced
    store.cache.clear()
    store.fold_cache.clear()
    again = pool.get(r0)
    assert pool.stats()["views_built"] == 3
    assert set(again.params) == set(first)
    for k, v in again.params.items():
        np.testing.assert_array_equal(np.asarray(v), first[k], err_msg=k)
