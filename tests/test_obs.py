"""Observability layer (DESIGN.md §14): metrics registry, trace spans,
Prometheus exposition on both daemons, retry/watcher visibility."""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.cli import main as cli
from repro.core import LineageGraph
from repro.hub import HubApp
from repro.hub import start_in_thread as hub_start
from repro.obs import (REGISTRY, Histogram, Registry, propagate, reset_trace,
                       span, tracing)
from repro.obs import export_chrome_trace, is_enabled
from repro.remote import (HttpTransport, LocalTransport, RemoteState, push)
from repro.remote.http import HubUnavailable, endpoint_family
from repro.serve import (LineageWatcher, LocalLineageSource, ModelPool,
                         Router, ServeApp)
from repro.serve import start_in_thread as serve_start
from repro.store import ArtifactStore

from helpers import finetune_like, make_chain_model, perturb


@pytest.fixture(autouse=True)
def _clean_trace():
    """Tracing state is process-global; leave it as we found it (off)."""
    reset_trace()
    yield
    assert not is_enabled()
    reset_trace()


def _repo(path):
    path = str(path)
    return LineageGraph(path=path, store=ArtifactStore(root=path))


def _seed(g):
    base = make_chain_model(seed=0, d=32)
    g.add_node(base, "m@v1")
    g.add_edge("m@v1", "m@v2")
    g.add_node(finetune_like(base, seed=1), "m@v2")
    return base


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_identity_and_kind_guard():
    r = Registry()
    c = r.counter("t_reqs", help="h", route="/a")
    assert r.counter("t_reqs", route="/a") is c      # same child handle
    assert r.counter("t_reqs", route="/b") is not c  # new label set
    c.inc()
    c.inc(4)
    assert c.get() == 5
    g = r.gauge("t_depth")
    g.inc(3)
    g.dec()
    assert g.get() == 2
    with pytest.raises(ValueError):
        r.gauge("t_reqs")  # family kind is fixed at first registration


def test_counter_increments_are_thread_safe():
    r = Registry()
    c = r.counter("t_par")

    def worker():
        for _ in range(10_000):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.get() == 80_000


def test_histogram_quantile_matches_numpy_within_bucket_width():
    r = Registry()
    h = r.histogram("t_lat", buckets=[b / 1000 for b in range(1, 101)])
    rng = np.random.default_rng(7)
    obs = rng.uniform(0.001, 0.1, size=5000)
    for v in obs:
        h.observe(float(v))
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        exact = float(np.percentile(obs, q * 100))
        # linear interpolation inside a 1ms bucket: within one bucket width
        assert abs(est - exact) <= 0.001 + 1e-9, (q, est, exact)
    assert r.histogram("t_lat").count == 5000


def test_histogram_edge_cases():
    r = Registry()
    h = r.histogram("t_edge", buckets=[0.1, 1.0])
    assert h.quantile(0.5) is None  # empty
    h.observe(50.0)                 # beyond the last bound -> +Inf bucket
    assert h.quantile(0.99) == 1.0  # clamps to last finite bound
    text = r.render_prometheus()
    assert 't_edge_bucket{le="+Inf"} 1' in text
    assert 't_edge_bucket{le="1"} 0' in text


def _parse_prometheus(text):
    """Minimal exposition-format parser: {(name, labels_str): value}."""
    samples, types = {}, {}
    for line in text.strip().splitlines():
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        metric, _, value = line.rpartition(" ")
        assert metric and value, f"unparseable line {line!r}"
        float(value)  # must be a number
        samples[metric] = float(value)
    return samples, types


def test_prometheus_rendering_is_parseable_and_escaped():
    r = Registry()
    r.counter("t_esc", help="has labels", path='a"b\\c\nd').inc(2)
    h = r.histogram("t_hist", buckets=[0.5])
    h.observe(0.1)
    h.observe(9.0)
    samples, types = _parse_prometheus(r.render_prometheus())
    assert types == {"t_esc": "counter", "t_hist": "histogram"}
    assert samples['t_esc{path="a\\"b\\\\c\\nd"}'] == 2
    assert samples['t_hist_bucket{le="0.5"}'] == 1
    assert samples['t_hist_bucket{le="+Inf"}'] == 2  # cumulative
    assert samples["t_hist_count"] == 2


def test_metric_group_dict_compat():
    r = Registry()
    g = r.group("t_grp", keys=("a", "b"), instance="x")
    g["a"] += 3          # legacy increment pattern
    g.inc("b", 2)
    g["dynamic"] = 7     # unknown keys materialize on first write
    assert g["a"] == 3 and g.get("b") == 2 and g.get("nope", -1) == -1
    assert set(g) == {"a", "b", "dynamic"} and len(g) == 3
    assert dict(g) == {"a": 3, "b": 2, "dynamic": 7}
    assert {**g, "extra": 1}["a"] == 3
    assert g == {"a": 3, "b": 2, "dynamic": 7}
    assert 't_grp_a{instance="x"} 3' in r.render_prometheus()


def test_metric_group_reset_is_atomic_under_concurrent_increments():
    r = Registry()
    g = r.group("t_atomic", keys=("x", "y"))
    stop = threading.Event()
    torn = []

    def resetter():
        while not stop.is_set():
            snap = g.reset()
            # x and y are always incremented together under the group
            # lock via inc(); a reset can never observe one without the
            # other drifting by more than the in-flight pair
            if abs(snap["x"] - snap["y"]) > 1:
                torn.append(snap)

    t = threading.Thread(target=resetter)
    t.start()
    for _ in range(20_000):
        with g._lock:
            for k in ("x", "y"):
                g._metrics[k].value += 1
    stop.set()
    t.join()
    assert not torn


def test_store_reset_io_stats_snapshots_atomically(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    g = LineageGraph(path=str(tmp_path), store=store)
    _seed(g)
    store.materialize_artifact(g.nodes["m@v2"].artifact_ref)
    snap = store.io_stats.snapshot()
    assert snap["tensors_materialized"] > 0
    before = store.reset_io_stats()
    assert before["tensors_materialized"] == snap["tensors_materialized"]
    assert store.io_stats.snapshot()["tensors_materialized"] == 0
    # the registry sees the same (now reset) counters
    text = REGISTRY.render_prometheus()
    assert (f'mgit_store_tensors_materialized{{instance='
            f'"{store.io_stats.instance}"}} 0') in text


# ---------------------------------------------------------------------------
# Trace spans
# ---------------------------------------------------------------------------

def _span_events():
    return [e for e in export_chrome_trace()["traceEvents"]
            if e.get("ph") == "X"]


def test_disabled_tracing_records_nothing():
    with span("invisible", cat="test"):
        pass
    assert _span_events() == []
    fn = lambda: 1  # noqa: E731
    assert propagate(fn) is fn  # disabled: callable returned untouched


def test_span_tree_nests_and_propagates_across_threads():
    with tracing():
        with span("parent", cat="test"):
            with span("child", cat="test"):
                pass

            def task():
                with span("pooled", cat="test"):
                    return 1

            t = threading.Thread(target=propagate(task))
            t.start()
            t.join()
    evs = {e["name"]: e["args"] for e in _span_events()}
    assert evs["child"]["parent_id"] == evs["parent"]["span_id"]
    assert evs["pooled"]["parent_id"] == evs["parent"]["span_id"]


def test_span_records_error_and_trees_reconnect():
    with tracing():
        with pytest.raises(RuntimeError):
            with span("boom", cat="test"):
                raise RuntimeError("x")
    (ev,) = _span_events()
    assert ev["args"]["error"] == "RuntimeError"
    assert ev["dur"] >= 0


def test_traced_commit_is_one_connected_tree(tmp_path):
    store = ArtifactStore(root=str(tmp_path), io_workers=4)
    g = LineageGraph(path=str(tmp_path), store=store)
    base = make_chain_model(seed=0, d=32)
    with tracing():
        g.add_node(base, "m@v1")
        g.add_edge("m@v1", "m@v2")
        g.add_node(finetune_like(base, seed=1), "m@v2")
    evs = _span_events()
    by_id = {e["args"]["span_id"]: e for e in evs}
    roots = [e for e in evs if e["args"]["parent_id"] is None]
    assert {e["name"] for e in roots} == {"store.commit"}
    names = {e["name"] for e in evs}
    assert {"commit.delta", "commit.encode", "commit.hash",
            "commit.pack_fsync"} <= names
    # every worker-side span reaches a store.commit root via parent_id —
    # propagate() carried the submitting span into the pool threads
    for e in evs:
        cur = e
        while cur["args"]["parent_id"] is not None:
            cur = by_id[cur["args"]["parent_id"]]
        assert cur["name"] == "store.commit"


def test_traced_push_connects_transfer_chunks(tmp_path):
    g = _repo(tmp_path / "src")
    _seed(g)
    dst = str(tmp_path / "dst")
    with tracing():
        rep = push(g, LocalTransport(dst), state=RemoteState(g.path, "o"))
    assert rep.published
    evs = _span_events()
    by_id = {e["args"]["span_id"]: e for e in evs}
    names = {e["name"] for e in evs}
    assert {"sync.push", "sync.negotiate", "sync.transfer",
            "sync.publish", "journal.chunk"} <= names
    chunks = [e for e in evs if e["name"] == "journal.chunk"]
    assert chunks and all(
        by_id[c["args"]["parent_id"]]["name"] == "sync.transfer"
        for c in chunks)
    (root,) = [e for e in evs if e["args"]["parent_id"] is None]
    assert root["name"] == "sync.push"
    # LocalTransport has no retry_stats: report shows zeros, not crashes
    assert rep.transport_retries == 0 and rep.transport_retries_by_family == {}


def test_chrome_trace_has_thread_metadata():
    with tracing():
        with span("s", cat="test"):
            pass
    doc = export_chrome_trace()
    metas = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    assert any(m["name"] == "process_name" for m in metas)
    assert any(m["name"] == "thread_name" for m in metas)
    json.dumps(doc)  # exportable as-is


# ---------------------------------------------------------------------------
# Daemon exposition: /api/metrics and per-route latency
# ---------------------------------------------------------------------------

def _get_text(url):
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.headers.get("Content-Type", ""), r.read().decode()


def _get_json(url):
    with urllib.request.urlopen(url, timeout=10) as r:
        return json.loads(r.read())


def _post_json(url, obj):
    req = urllib.request.Request(
        url, data=json.dumps(obj).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=10) as r:
        return json.loads(r.read())


def test_hub_api_metrics_and_latency(tmp_path):
    g = _repo(tmp_path / "src")
    _seed(g)
    app = HubApp(str(tmp_path / "hub"))
    server, _ = hub_start(app)
    try:
        push(g, HttpTransport(server.url, retries=0),
             state=RemoteState(g.path, "origin"))
        ctype, text = _get_text(server.url + "/api/metrics")
        assert ctype.startswith("text/plain")
        samples, types = _parse_prometheus(text)
        assert types.get("mgit_http_request_seconds") == "histogram"
        inst = app.stats.instance
        assert samples[f'mgit_hub_requests{{instance="{inst}"}}'] > 0
        served = sum(v for k, v in samples.items()
                     if k.startswith("mgit_http_request_seconds_count")
                     and f'service="hub"' in k and f'instance="{inst}"' in k)
        assert served > 0
        # journal writes land under the :tid route family, not raw paths
        assert any('route="/api/journal/:tid"' in k for k in samples)
        stats = _get_json(server.url + "/api/stats")
        lat = stats["request_latency"]
        key = next(k for k in lat if "/api/journal/:tid" in k)
        assert lat[key]["count"] > 0 and lat[key]["p99_ms"] >= 0
    finally:
        server.shutdown()
        server.server_close()


def test_serve_api_metrics_and_latency(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    g = LineageGraph(path=str(tmp_path), store=store)
    base = make_chain_model(seed=0)
    g.add_node(base, "main")
    g.add_edge("main", "canary")
    g.add_node(perturb(base, "L0/w", seed=3), "canary")
    router = Router(ModelPool(store), ["prod=branch:main"])
    watcher = LineageWatcher(LocalLineageSource(str(tmp_path)), router,
                             interval_s=30)
    watcher.poll()
    app = ServeApp(router, router.pool, watcher)
    server, _ = serve_start(app)
    try:
        for _ in range(3):
            _post_json(server.url + "/api/predict/prod", {})
        ctype, text = _get_text(server.url + "/api/metrics")
        assert ctype.startswith("text/plain")
        samples, types = _parse_prometheus(text)
        inst = app.counters.instance
        assert samples[f'mgit_serve_predictions{{instance="{inst}"}}'] == 3
        key = ('mgit_http_request_seconds_count{instance="%s",'
               'method="POST",route="/api/predict/:endpoint",'
               'service="serve"}' % inst)
        assert samples[key] == 3
        lat = _get_json(server.url + "/api/stats")["request_latency"]
        assert lat["POST /api/predict/:endpoint"]["count"] == 3
        assert lat["POST /api/predict/:endpoint"]["p50_ms"] >= 0
    finally:
        server.shutdown()
        server.server_close()


def test_unknown_paths_collapse_to_other_route_label(tmp_path):
    app = HubApp(str(tmp_path / "hub"))
    server, _ = hub_start(app)
    try:
        for i in range(3):  # distinct junk paths -> ONE label value
            with pytest.raises(urllib.error.HTTPError):
                _get_json(server.url + f"/api/junk{i}")
        samples, _ = _parse_prometheus(
            _get_text(server.url + "/api/metrics")[1])
        junk = [k for k in samples if "junk" in k]
        assert not junk  # cardinality stays bounded
        inst = app.stats.instance
        key = ('mgit_http_request_seconds_count{instance="%s",'
               'method="GET",route="other",service="hub"}' % inst)
        assert samples[key] == 3
    finally:
        server.shutdown()
        server.server_close()


# ---------------------------------------------------------------------------
# Satellite: watcher failure visibility
# ---------------------------------------------------------------------------

class _FlakySource:
    def __init__(self, fail_times, name):
        self.fail_times = fail_times
        self.name = name  # unique: the registry child is keyed on describe()
        self.calls = 0

    def fetch(self):
        self.calls += 1
        if self.calls <= self.fail_times:
            raise ConnectionError(f"flake #{self.calls}")
        return None, "absent"

    def describe(self):
        return f"flaky:{self.name}"


def test_watcher_counts_failures_and_recovers(tmp_path, caplog):
    store = ArtifactStore(root=str(tmp_path))
    router = Router(ModelPool(store), ["prod=ref:nothing"])
    src = _FlakySource(fail_times=2, name="poll-test")
    w = LineageWatcher(src, router, interval_s=0.01)
    with caplog.at_level("WARNING", logger="repro.serve.watch"):
        for _ in range(2):
            try:
                w.poll()
            except ConnectionError as exc:
                w._record_failure(exc)
    assert w.consecutive_failures == 2
    assert "flake #1" in w.last_error or "flake #2" in w.last_error
    # one WARN per outage, not one per tick
    warns = [r for r in caplog.records if "lineage watch poll" in r.message]
    assert len(warns) == 1
    w.poll()  # source recovered
    st = w.stats()
    assert st["consecutive_failures"] == 0 and st["last_error"] is None
    assert st["poll_failures"] == 2


def test_watcher_run_loop_survives_failures(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    router = Router(ModelPool(store), ["prod=ref:nothing"])
    src = _FlakySource(fail_times=3, name="run-loop-test")
    w = LineageWatcher(src, router, interval_s=0.005)
    w.start()
    try:
        deadline = threading.Event()
        for _ in range(400):
            if src.calls > 4:
                break
            deadline.wait(0.01)
        assert src.calls > 4  # kept polling straight through the failures
    finally:
        w.stop()
    assert w.stats()["poll_failures"] == 3


# ---------------------------------------------------------------------------
# Satellite: transport retries visible per endpoint family
# ---------------------------------------------------------------------------

def test_endpoint_family_mapping():
    assert endpoint_family("/api/objects/abc123") == "objects"
    assert endpoint_family("/api/journal/t1") == "journal"
    assert endpoint_family("/api/lineage") == "lineage"
    assert endpoint_family("/api/have") == "negotiate"
    assert endpoint_family("/api/finalize") == "finalize"
    assert endpoint_family("/api/ping") == "ping"
    assert endpoint_family("/api/whatever") == "other"


def test_http_retries_are_counted_per_family():
    t = HttpTransport("http://127.0.0.1:9", retries=1, backoff=0.001)
    with pytest.raises(HubUnavailable):
        t.have(["k"])
    st = t.retry_stats()
    assert st["retries"] == {"negotiate": 1}
    assert st["terminal_failures"] == {"negotiate": 1}
    assert st["backoff_s"]["negotiate"] > 0


def test_push_report_surfaces_transport_retries(tmp_path):
    g = _repo(tmp_path / "src")
    _seed(g)
    t = HttpTransport("http://127.0.0.1:9", retries=1, backoff=0.001)
    with pytest.raises(HubUnavailable):
        push(g, t, state=RemoteState(g.path, "o"))
    # pre-seed noise, then a live push: the report counts ONLY its own sync
    app = HubApp(str(tmp_path / "hub"))
    server, _ = hub_start(app)
    try:
        t2 = HttpTransport(server.url, retries=1, backoff=0.001)
        rep = push(g, t2, state=RemoteState(g.path, "o"))
        assert rep.published
        assert rep.transport_retries == 0
        assert rep.transport_retries_by_family == {}
        assert rep.transport_terminal_failures == 0
    finally:
        server.shutdown()
        server.server_close()
    assert rep.to_json()["transport_retries"] == 0


# ---------------------------------------------------------------------------
# CLI: obs metrics / obs trace
# ---------------------------------------------------------------------------

def test_cli_obs_metrics(tmp_path, capsys):
    g = _repo(tmp_path)
    _seed(g)
    assert cli(["-C", str(tmp_path), "obs", "metrics"]) == 0
    samples, types = _parse_prometheus(capsys.readouterr().out)
    assert any(k.startswith("mgit_store_") for k in samples)


def test_cli_obs_trace_emits_perfetto_json(tmp_path, capsys):
    g = _repo(tmp_path)
    _seed(g)
    out = str(tmp_path / "trace.json")
    assert cli(["-C", str(tmp_path), "obs", "--out", out, "trace",
                "checkout", "m@v2"]) == 0
    capsys.readouterr()
    doc = json.load(open(out))
    evs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    names = {e["name"] for e in evs}
    assert {"store.checkout", "checkout.param"} <= names
    assert not is_enabled()  # tracing restored off after the run
