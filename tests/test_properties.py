"""Hypothesis property tests on system invariants (diff/traversal/storage)."""

import numpy as np

from hyp_compat import given, settings, st

from repro.core import (LayerGraph, LayerNode, LineageGraph, ModelArtifact,
                        all_parents_first, module_diff)

from helpers import finetune_like, make_chain_model


# -- random DAG artifacts ----------------------------------------------------

@st.composite
def dag_artifacts(draw):
    n = draw(st.integers(2, 8))
    seed = draw(st.integers(0, 1000))
    rng = np.random.default_rng(seed)
    g = LayerGraph()
    params = {}
    for i in range(n):
        d = draw(st.sampled_from([4, 8]))
        g.add_node(LayerNode(f"n{i}", draw(st.sampled_from(["linear", "conv"])),
                             params={"w": ((d, d), "float32")}))
        params[f"n{i}/w"] = rng.normal(size=(d, d)).astype(np.float32)
    for j in range(1, n):  # random DAG: each node gets >=1 earlier parent
        for i in draw(st.sets(st.integers(0, j - 1), min_size=1, max_size=2)):
            g.add_edge(f"n{i}", f"n{j}")
    return ModelArtifact(g, params, model_type="prop")


@given(dag_artifacts())
@settings(max_examples=30, deadline=None)
def test_diff_self_is_identical(a):
    d = module_diff(a, a, mode="contextual")
    assert d.identical and d.divergence == 0.0


@given(dag_artifacts(), dag_artifacts())
@settings(max_examples=30, deadline=None)
def test_diff_partitions_nodes(a, b):
    """matched ∪ deleted = A's nodes; matched ∪ added = B's nodes (disjoint)."""
    d = module_diff(a, b, mode="structural")
    a_matched = {x for x, _ in d.matched_nodes}
    b_matched = {y for _, y in d.matched_nodes}
    assert a_matched | set(d.del_nodes) == set(a.graph.nodes)
    assert a_matched & set(d.del_nodes) == set()
    assert b_matched | set(d.add_nodes) == set(b.graph.nodes)
    assert b_matched & set(d.add_nodes) == set()
    assert 0.0 <= d.divergence <= 1.0


@given(dag_artifacts(), dag_artifacts())
@settings(max_examples=20, deadline=None)
def test_diff_matching_is_one_to_one_and_order_preserving(a, b):
    d = module_diff(a, b, mode="structural")
    xs = [x for x, _ in d.matched_nodes]
    ys = [y for _, y in d.matched_nodes]
    assert len(set(xs)) == len(xs) and len(set(ys)) == len(ys)
    # kept matches are increasing in both topological orders (Algorithm 3's
    # inverse-match filter)
    ta = {n: i for i, n in enumerate(a.graph.topo_order())}
    tb = {n: i for i, n in enumerate(b.graph.topo_order())}
    pairs = sorted(d.matched_nodes, key=lambda m: ta[m[0]])
    assert all(tb[pairs[i][1]] < tb[pairs[i + 1][1]]
               for i in range(len(pairs) - 1))


@given(st.integers(0, 500), st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_all_parents_first_invariant(seed, n_children):
    g = LineageGraph()
    root = make_chain_model(seed=seed)
    g.add_node(root, "root")
    rng = np.random.default_rng(seed)
    names = ["root"]
    for i in range(n_children):
        parents = rng.choice(names, size=min(2, len(names)), replace=False)
        name = f"c{i}"
        g.add_node(finetune_like(root, seed=seed + i), name)
        for p in parents:
            g.add_edge(str(p), name)
        names.append(name)
    seen = set()
    for node in all_parents_first(g):
        assert all(p in seen for p in node.parents)
        seen.add(node.name)
    assert seen == set(g.nodes)


@given(st.floats(1e-6, 1e-3), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_delta_chain_error_grows_linearly_at_most(scale, depth):
    """Loading through a depth-k delta chain accumulates <= k quant steps."""
    from repro.store import ArtifactStore
    store = ArtifactStore(root=None, codec="zlib", t_thr=float("inf"))
    cur = make_chain_model(seed=0, d=32)
    ref = store.commit_artifact("v0", cur)
    originals = [cur]
    for k in range(depth):
        cur = finetune_like(cur, seed=k + 1, scale=scale, density=0.5)
        originals.append(cur)
        ref = store.commit_artifact(f"v{k + 1}", cur, parent_ref=ref)
    loaded = store.load_artifact(ref)
    bound = (depth + 1) * 2 * np.log1p(1e-4) + 1e-6
    for key in cur.params:
        assert np.max(np.abs(loaded.params[key] - cur.params[key])) <= bound
