"""Chunk layer (DESIGN.md §12): CDC boundaries, chunked commit/checkout,
chunk-granular dedup/fsck/sync, shard-scoped fetch, ranged transfer."""

import json
import os

import numpy as np
import pytest

from repro.core import LayerGraph, LayerNode, LineageGraph, ModelArtifact
from repro.store import ArtifactStore, CAS
from repro.store import chunks as chunklib
from repro.common.hashing import tensor_hash
from repro.remote.sync import fetch_objects, fetch_param_shard
from repro.remote.transport import LocalTransport

# small grid so multi-chunk behavior shows on test-sized tensors
CHUNK_KW = dict(chunk_threshold=64 * 1024, chunk_min=16 * 1024,
                chunk_avg=32 * 1024, chunk_max=64 * 1024)


def big_artifact(seed=0, rows=256, cols=300):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    g = LayerGraph.chain([LayerNode("big", "linear",
                                    params={"w": ((rows, cols), "float32")})])
    return ModelArtifact(g, {"big/w": w}), w


def edit(w, frac=0.001, seed=1):
    """Localized edit touching ``frac`` of the elements."""
    rng = np.random.default_rng(seed)
    out = w.copy()
    n = max(1, int(w.size * frac))
    start = rng.integers(0, w.size - n)
    out.reshape(-1)[start:start + n] += 0.5
    return out


# ---------------------------------------------------------------------------
# content-defined chunking
# ---------------------------------------------------------------------------

def _mem_read(data):
    return lambda off, n: data[off:off + n]


def test_cut_points_invariants():
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, size=500_000, dtype=np.uint8).tobytes()
    cuts = chunklib.cut_points(_mem_read(data), len(data), 4,
                               min_size=8 * 1024, avg_size=16 * 1024,
                               max_size=64 * 1024, mode="cdc", segments=None)
    assert cuts[-1] == len(data)
    assert cuts == sorted(set(cuts))
    spans = chunklib.spans_of(cuts)
    for off, n in spans[:-1]:           # last chunk may undershoot min
        assert 8 * 1024 <= n <= 64 * 1024
        assert n % 4 == 0               # itemsize-aligned
    # deterministic: same bytes, same grid
    assert cuts == chunklib.cut_points(
        _mem_read(data), len(data), 4, min_size=8 * 1024,
        avg_size=16 * 1024, max_size=64 * 1024, mode="cdc", segments=None)


def test_cut_points_boundary_stability_under_prefix_shift():
    """The CDC property: content far from an insertion keeps its cuts."""
    rng = np.random.default_rng(1)
    tail = rng.integers(0, 256, size=400_000, dtype=np.uint8).tobytes()
    a = rng.integers(0, 256, size=64, dtype=np.uint8).tobytes() + tail
    b = rng.integers(0, 256, size=4096, dtype=np.uint8).tobytes() + tail
    kw = dict(min_size=8 * 1024, avg_size=16 * 1024, max_size=64 * 1024,
              mode="cdc", segments=None)
    cuts_a = chunklib.cut_points(_mem_read(a), len(a), 1, **kw)
    cuts_b = chunklib.cut_points(_mem_read(b), len(b), 1, **kw)
    # cuts are content-anchored: tail cuts realign modulo the shift
    tail_a = {c - 64 for c in cuts_a if c > 70_000}
    tail_b = {c - 4096 for c in cuts_b if c > 70_000}
    common = tail_a & tail_b
    assert len(common) >= 0.8 * max(1, len(tail_a))


def test_segments_are_hard_cuts():
    data = bytes(range(256)) * 2048          # 512 KiB, highly regular
    seg = [200_000, 400_000]
    cuts = chunklib.cut_points(_mem_read(data), len(data), 4,
                               min_size=8 * 1024, avg_size=16 * 1024,
                               max_size=64 * 1024, mode="fixed",
                               segments=seg)
    assert set(seg) <= set(cuts)


def test_fixed_mode_grid():
    data = bytes(1_000_000)
    cuts = chunklib.cut_points(_mem_read(data), len(data), 4,
                               min_size=8 * 1024, avg_size=32 * 1024,
                               max_size=64 * 1024, mode="fixed",
                               segments=None)
    spans = chunklib.spans_of(cuts)
    assert all(n == 32 * 1024 for _, n in spans[:-1])
    assert sum(n for _, n in spans) == len(data)


# ---------------------------------------------------------------------------
# chunked commit / checkout
# ---------------------------------------------------------------------------

def test_chunked_commit_checkout_bit_identity(tmp_path):
    store = ArtifactStore(root=str(tmp_path), **CHUNK_KW)
    art, w = big_artifact()
    ref = store.commit_artifact("m", art)
    e = store.get_manifest(ref)["params"]["big/w"]
    assert e["kind"] == "chunked" and len(e["chunks"]) > 1
    assert e["hash"] == tensor_hash(w)
    got = store.materialize_param(ref, "big/w")
    np.testing.assert_array_equal(got, w)
    # the lazy-load path and the recursive path agree
    lazy = store.load_artifact(ref)
    assert lazy.params.spec_of("big/w") == (w.shape, "float32")
    np.testing.assert_array_equal(np.asarray(lazy.params["big/w"]), w)


def test_chunked_dedup_on_small_edit(tmp_path):
    store = ArtifactStore(root=str(tmp_path), **CHUNK_KW)
    art, w = big_artifact()
    r1 = store.commit_artifact("m", art)
    before = store.cas.physical_bytes()
    w2 = edit(w, frac=0.001)
    art2 = ModelArtifact(art.graph, {"big/w": w2})
    r2 = store.commit_artifact("m", art2, parent_ref=r1)
    added = store.cas.physical_bytes() - before
    assert added < 0.05 * w.nbytes, f"0.1% edit re-stored {added} bytes"
    np.testing.assert_array_equal(
        store.materialize_param(r2, "big/w"),
        store._materialize_chunked(r2, "big/w"))
    e2 = store.get_manifest(r2)["params"]["big/w"]
    kinds = {("c" if "c" in it else "b" if "b" in it else "p")
             for it in e2["chunks"]}
    assert e2.get("parent_ref") == r1
    assert "c" in kinds or "p" in kinds   # untouched chunks were not re-sent


def test_chunked_streaming_and_range(tmp_path):
    store = ArtifactStore(root=str(tmp_path), **CHUNK_KW)
    art, w = big_artifact()
    ref = store.commit_artifact("m", art)
    raw = w.tobytes()
    # stream covers the tensor in order
    got = bytearray(len(raw))
    for off, data in store.stream_param(ref, "big/w"):
        got[off:off + len(data)] = data
    assert bytes(got) == raw
    # file checkout digest equals the entry hash (bit-identity marker)
    path = str(tmp_path / "w.bin")
    digest = store.materialize_param_to_file(ref, "big/w", path)
    assert digest == store.get_manifest(ref)["params"]["big/w"]["hash"]
    with open(path, "rb") as f:
        assert f.read() == raw
    # arbitrary byte range
    assert store.materialize_param_range(ref, "big/w", 100, 70_000) == \
        raw[100:70_000]


def test_chunked_release_gc_leaves_nothing(tmp_path):
    store = ArtifactStore(root=str(tmp_path), **CHUNK_KW)
    art, w = big_artifact()
    r1 = store.commit_artifact("m", art)
    art2 = ModelArtifact(art.graph, {"big/w": edit(w)})
    r2 = store.commit_artifact("m", art2, parent_ref=r1)
    store.release(r2)
    store.release(r1)
    store.cas.gc()
    assert store.cas.object_count() == 0


def test_sub_threshold_params_unchanged(tmp_path):
    """Small tensors never chunk; chunking off reproduces the old layout."""
    store = ArtifactStore(root=str(tmp_path), **CHUNK_KW)
    art, _ = big_artifact(rows=16, cols=16)   # 1 KiB, far below threshold
    ref = store.commit_artifact("m", art)
    assert store.get_manifest(ref)["params"]["big/w"]["kind"] == "full"
    off = ArtifactStore(root=str(tmp_path / "off"), chunk_threshold=0)
    art2, _ = big_artifact()
    ref2 = off.commit_artifact("m", art2)
    assert off.get_manifest(ref2)["params"]["big/w"]["kind"] == "full"


# ---------------------------------------------------------------------------
# fsck pinpoints chunk damage
# ---------------------------------------------------------------------------

def _loose_chunk_store(tmp_path):
    """Chunk objects land loose (tiny pack threshold) so tests can corrupt
    a single chunk file on disk."""
    return ArtifactStore(root=str(tmp_path), pack_threshold=1024, **CHUNK_KW)


def test_fsck_pinpoints_corrupt_chunk(tmp_path):
    store = _loose_chunk_store(tmp_path)
    art, _ = big_artifact()
    ref = store.commit_artifact("m", art)
    e = store.get_manifest(ref)["params"]["big/w"]
    victim = next(it["c"] for it in e["chunks"] if "c" in it)
    vpath = os.path.join(str(tmp_path), "objects", victim)
    data = bytearray(open(vpath, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(vpath, "wb").write(bytes(data))

    report = store.fsck([ref])
    assert victim in report["corrupt"]
    damage = [d for d in report["chunk_damage"] if d["object"] == victim]
    assert damage and damage[0]["ref"] == ref
    assert damage[0]["param"] == "big/w"
    assert damage[0]["problem"] == "corrupt"
    # the hit names exactly the bad chunk's index, not the whole tensor
    idx = damage[0]["chunk"]
    assert e["chunks"][idx]["c"] == victim
    healthy = [d for d in report["chunk_damage"] if d["object"] != victim]
    assert not healthy


def test_fsck_detects_dangling_chunk_ref(tmp_path):
    store = _loose_chunk_store(tmp_path)
    art, _ = big_artifact(seed=3)
    ref = store.commit_artifact("m", art)
    e = store.get_manifest(ref)["params"]["big/w"]
    victim = next(it["c"] for it in e["chunks"] if "c" in it)
    os.remove(os.path.join(str(tmp_path), "objects", victim))

    report = store.fsck([ref])
    assert not report["ok"]
    assert victim in report["missing_objects"]
    damage = [d for d in report["chunk_damage"] if d["object"] == victim]
    assert damage and damage[0]["problem"] == "missing"


def test_fsck_clean_chunked_repo_ok(tmp_path):
    store = ArtifactStore(root=str(tmp_path), **CHUNK_KW)
    art, w = big_artifact()
    r1 = store.commit_artifact("m", art)
    r2 = store.commit_artifact(
        "m", ModelArtifact(art.graph, {"big/w": edit(w)}), parent_ref=r1)
    report = store.fsck([r1, r2])
    assert report["ok"] and not report["chunk_damage"]
    assert not report["refcount_drift"]


# ---------------------------------------------------------------------------
# mmap pool eviction leaves outstanding views valid
# ---------------------------------------------------------------------------

def test_mmap_pool_eviction_keeps_views_alive(tmp_path):
    cas = CAS(str(tmp_path), pack_threshold=10 ** 9, mmap_pool_max=2)
    arrays = {f"t{i}": np.full(4096, i, dtype=np.float32) for i in range(8)}
    keys = {name: cas.put_tensor(arr) for name, arr in arrays.items()}
    # hold zero-copy views of every object while the pool (capacity 2)
    # evicts the earlier maps many times over
    views = {name: cas.get_tensor(keys[name]) for name in arrays}
    raw = {name: cas.get_view(keys[name]) for name in arrays}
    assert len(cas._mmap_pool) <= 2
    for name, arr in arrays.items():
        np.testing.assert_array_equal(views[name], arr)   # evicted map alive
        # the raw view is the stored npy payload; it must still read
        # correctly even though its backing map was evicted from the pool
        assert bytes(raw[name]) == cas.get_bytes_nomap(keys[name])
        assert not views[name].flags.writeable


def test_small_mmap_pool_serves_chunked_checkout(tmp_path):
    store = ArtifactStore(root=str(tmp_path), **CHUNK_KW)
    store.cas._mmap_pool_max = 1
    art, w = big_artifact()
    ref = store.commit_artifact("m", art)
    np.testing.assert_array_equal(store.materialize_param(ref, "big/w"), w)


# ---------------------------------------------------------------------------
# sync: chunk-granular negotiation, ranged fetch, shard pull
# ---------------------------------------------------------------------------

def _lineage(tmp_path, name, **kw):
    root = str(tmp_path / name)
    store = ArtifactStore(root=root, **kw)
    return LineageGraph(path=root, store=store), store


def test_pull_moves_only_edited_chunks(tmp_path):
    from repro.remote.sync import pull, push
    g1, store = _lineage(tmp_path, "src", **CHUNK_KW)
    art, w = big_artifact()
    g1.add_node(art, "m")
    remote = LocalTransport(str(tmp_path / "remote"))
    push(g1, remote)
    g2, _ = _lineage(tmp_path, "dst", **CHUNK_KW)
    pull(g2, remote)
    baseline = push(g1, remote).objects_transferred
    assert baseline == 0                       # fully synced

    g1.add_node(ModelArtifact(art.graph, {"big/w": edit(w)}), "m2")
    g1.add_version_edge("m", "m2")
    rep = push(g1, remote)
    e = store.get_manifest(g1.nodes["m2"].artifact_ref)["params"]["big/w"]
    total_chunks = len(e["chunks"])
    # only the new manifest + the few changed chunk objects moved
    assert 0 < rep.objects_transferred < total_chunks
    rep2 = pull(g2, remote)
    assert 0 < rep2.objects_transferred < total_chunks
    got = np.asarray(g2.store.load_artifact(
        g2.nodes["m2"].artifact_ref).params["big/w"])
    np.testing.assert_array_equal(
        got, np.asarray(store.load_artifact(
            g1.nodes["m2"].artifact_ref).params["big/w"]))


def test_fetch_objects_local_transport(tmp_path):
    g1, store = _lineage(tmp_path, "src", **CHUNK_KW)
    art, _ = big_artifact()
    g1.add_node(art, "m")
    t = LocalTransport(str(store.cas.root))
    ref = g1.nodes["m"].artifact_ref
    e = store.get_manifest(ref)["params"]["big/w"]
    keys = [it["c"] for it in e["chunks"] if "c" in it][:4] + [ref]
    got = fetch_objects(t, keys)
    assert set(got) == set(keys)
    for k in keys:
        assert got[k] == store.cas.get_bytes(k)
    assert t.object_sizes(keys) == {k: len(got[k]) for k in keys}
    assert t.object_sizes(["missing_key"]) == {}


def test_fetch_param_shard_local(tmp_path):
    g1, store = _lineage(tmp_path, "src", chunk_shards=4, **CHUNK_KW)
    art, w = big_artifact()
    g1.add_node(art, "m")
    ref = g1.nodes["m"].artifact_ref
    t = LocalTransport(str(store.cas.root))
    raw = w.tobytes()
    row_bytes = w.shape[1] * 4
    consumer = ArtifactStore(root=str(tmp_path / "host2"))
    got = fetch_param_shard(consumer, t, ref, "big/w", 2, 4)
    rows = w.shape[0]
    start = (2 * rows) // 4 * row_bytes
    end = (3 * rows) // 4 * row_bytes
    assert got == raw[start:end]
    # the consumer imported strictly fewer chunk objects than exist
    e = json.loads(consumer.cas.get_bytes(ref))["params"]["big/w"]
    total_c = sum(1 for it in e["chunks"] if "c" in it)
    held = sum(1 for it in e["chunks"]
               if "c" in it and consumer.cas.has(it["c"]))
    assert 0 < held < total_c
    with pytest.raises(ValueError):
        fetch_param_shard(consumer, t, ref, "big/w", 4, 4)


def test_shard_grid_respects_mesh_cuts(tmp_path):
    """No chunk straddles a shard boundary when chunk_shards is set."""
    store = ArtifactStore(root=str(tmp_path), chunk_shards=4, **CHUNK_KW)
    art, w = big_artifact()
    ref = store.commit_artifact("m", art)
    e = store.get_manifest(ref)["params"]["big/w"]
    cuts = set(np.cumsum([int(it["n"]) for it in e["chunks"]]).tolist())
    from repro.dist.sharding import shard_cuts
    expected = shard_cuts("big/w", w.shape, 4, 4)
    assert expected and set(expected) <= cuts


def test_http_parallel_ranged_read_matches_single_stream(tmp_path):
    from repro.hub import HubApp, start_in_thread
    from repro.remote.http import HttpTransport
    app = HubApp(str(tmp_path / "hub"))
    payload = np.random.default_rng(0).bytes(3 * 2 ** 20)
    key = app.store.cas.put_bytes(payload)
    server, _ = start_in_thread(app)
    try:
        t = HttpTransport(server.url, retries=1, backoff=0.01)
        sizes = t.object_sizes([key, "nope"])
        assert sizes == {key: len(payload)}
        whole = t.read_object_range(key, 0, len(payload))
        par = t.read_object_parallel(key, len(payload),
                                     part_bytes=256 * 1024, workers=4)
        assert par == whole == payload
        # tiny objects short-circuit to one request
        assert t.read_object_parallel(key, len(payload),
                                      part_bytes=len(payload) + 1) == payload
    finally:
        server.shutdown()
        server.server_close()
