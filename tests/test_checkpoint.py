"""CheckpointManager: versioned saves, restart, verification, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.store.checkpoint import (CheckpointManager, flatten_state,
                                    unflatten_state)


def _state(step=0, scale=1.0):
    return {
        "params": {"w": jnp.full((64, 64), scale), "b": jnp.zeros(64)},
        "opt": {"mu": jnp.zeros((64, 64))},
        "step": jnp.asarray(step, jnp.int32),
    }


def test_flatten_unflatten_roundtrip():
    s = _state()
    flat = flatten_state(s)
    assert "params/w" in flat and "opt/mu" in flat
    s2 = unflatten_state(s, flat)
    assert jnp.allclose(s2["params"]["w"], s["params"]["w"])
    assert s2["step"] == s["step"]


def test_save_restore_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m")
    cm.save(10, _state(10, 1.0), blocking=True)
    cm.save(20, _state(20, 1.001), blocking=True)
    restored, step = cm.restore(template=_state())
    assert step == 20
    assert float(restored["params"]["w"][0, 0]) == pytest.approx(1.001, abs=1e-3)


def test_async_save_and_wait(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=True)
    for s in range(3):
        cm.save(s, _state(s, 1.0 + s * 1e-4))
    cm.wait()
    assert cm.latest_step() == 2


def test_restore_specific_step(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m")
    cm.save(1, _state(1, 1.0), blocking=True)
    cm.save(2, _state(2, 2.0), blocking=True)
    restored, step = cm.restore(step=1, template=_state())
    assert step == 1 and float(restored["params"]["w"][0, 0]) == pytest.approx(1.0, abs=1e-3)


def test_delta_compression_across_steps(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m")
    s = _state(0, 1.0)
    cm.save(0, s, blocking=True)
    for i in range(1, 4):  # small optimizer excursions
        s = jax.tree_util.tree_map(lambda x: x + 1e-5, s)
        cm.save(i, s, blocking=True)
    assert cm.store.compression_ratio() > 2.0


def test_verification_detects_corruption(tmp_path):
    from repro.store import ArtifactStore
    # small pack threshold so the weight tensor lands loose (the throughput
    # default packs objects this small; corruption should hit ONE object)
    cm = CheckpointManager(
        str(tmp_path), model_name="m", delta_enabled=False,
        store=ArtifactStore(root=str(tmp_path), t_thr=float("inf"),
                            delta_enabled=False, pack_threshold=1024))
    cm.save(0, _state(0), blocking=True)
    # flip bytes in the largest object (the weight tensor)
    objdir = os.path.join(str(tmp_path), "objects")
    victim = max(os.listdir(objdir),
                 key=lambda f: os.path.getsize(os.path.join(objdir, f)))
    path = os.path.join(objdir, victim)
    data = bytearray(open(path, "rb").read())
    data[-100] ^= 0xFF
    open(path, "wb").write(bytes(data))
    cm2 = CheckpointManager(str(tmp_path), model_name="m")
    with pytest.raises(IOError):
        cm2.restore(template=_state(), verify=True)


def test_crash_restart_resumes_from_committed(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m")
    cm.save(5, _state(5), blocking=True)
    # simulate crash: a fresh manager over the same dir
    cm2 = CheckpointManager(str(tmp_path), model_name="m")
    assert cm2.latest_step() == 5
    restored, step = cm2.restore(template=_state())
    assert step == 5


def test_elastic_restore_sharded(tmp_path):
    """Checkpoint written unsharded restores onto explicit device placements
    (the mesh-reshape path used after node loss)."""
    cm = CheckpointManager(str(tmp_path), model_name="m")
    cm.save(0, _state(0, 3.0), blocking=True)
    dev = jax.devices()[0]
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                       sharding=jax.sharding.SingleDeviceSharding(dev)),
        _state())
    restored, step = cm.restore_sharded(template)
    assert float(restored["params"]["w"][0, 0]) == 3.0
    assert restored["params"]["w"].sharding.device_set == {dev}
