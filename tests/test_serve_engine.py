"""ServeEngine ragged batches: left-alignment, positions, n_tokens edges."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config, init_params
from repro.serve import ServeEngine, batch_lengths, left_align


@pytest.fixture(scope="module")
def engine():
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              remat="none")
    return ServeEngine(cfg, init_params(cfg, 0), max_len=16)


def _toks(rows):
    return jnp.asarray(np.array(rows, np.int32))


# ---------------------------------------------------------------------------
# alignment helpers
# ---------------------------------------------------------------------------

def test_left_align_shifts_rows_right():
    t = _toks([[1, 2, 3, 4], [5, 6, 7, 8]])
    out = left_align(t, jnp.asarray([4, 2], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out),
                                  [[1, 2, 3, 4], [0, 0, 5, 6]])


def test_left_align_custom_pad_id():
    out = left_align(_toks([[9, 9, 0]]), jnp.asarray([1], jnp.int32),
                     pad_id=7)
    np.testing.assert_array_equal(np.asarray(out), [[7, 7, 9]])


def test_batch_lengths_sources_and_clamp():
    batch = {"tokens": _toks([[1, 2, 3], [4, 5, 6]])}
    assert batch_lengths(batch) is None  # no lengths/mask: unpadded
    np.testing.assert_array_equal(
        np.asarray(batch_lengths(
            {**batch, "mask": jnp.asarray([[1, 1, 1], [1, 0, 0]])})),
        [3, 1])
    # explicit lengths win over the mask; zero clamps to one slot
    np.testing.assert_array_equal(
        np.asarray(batch_lengths(
            {**batch, "mask": jnp.ones((2, 3)),
             "lengths": jnp.asarray([2, 0])})),
        [2, 1])


# ---------------------------------------------------------------------------
# generate: n_tokens edges
# ---------------------------------------------------------------------------

def test_generate_zero_and_one_tokens(engine):
    batch = {"tokens": _toks([[3, 1, 4, 1, 5], [9, 2, 6, 5, 3]])}
    out0 = engine.generate(batch, 0)
    assert out0.shape == (2, 0) and out0.dtype == jnp.int32
    out1 = engine.generate(batch, 1)  # exactly one prefill, no decode
    assert out1.shape == (2, 1)
    out3 = engine.generate(batch, 3)
    assert out3.shape == (2, 3)
    # greedy decode is deterministic: shorter runs are prefixes
    np.testing.assert_array_equal(np.asarray(out3[:, :1]), np.asarray(out1))


# ---------------------------------------------------------------------------
# generate: ragged-batch contract
# ---------------------------------------------------------------------------

def test_full_width_row_matches_unpadded_run(engine):
    row = [3, 1, 4, 1, 5, 9]
    ragged = {"tokens": _toks([row, [2, 7, 0, 0, 0, 0]]),
              "lengths": jnp.asarray([6, 2], jnp.int32)}
    got = engine.generate(ragged, 4)
    solo = engine.generate({"tokens": _toks([row])}, 4)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(solo[0]))


def test_ragged_batch_matches_single_row_runs(engine):
    rows = [[3, 1, 4, 1, 5, 9], [2, 7, 1, 0, 0, 0], [8, 0, 0, 0, 0, 0]]
    lens = [6, 3, 1]
    got = engine.generate({"tokens": _toks(rows),
                           "lengths": jnp.asarray(lens, jnp.int32)}, 4)
    for i, (row, n) in enumerate(zip(rows, lens)):
        solo = engine.generate({"tokens": _toks([row]),
                                "lengths": jnp.asarray([n], jnp.int32)}, 4)
        np.testing.assert_array_equal(np.asarray(got[i]),
                                      np.asarray(solo[0]), err_msg=f"row {i}")


def test_mask_and_lengths_agree(engine):
    rows = [[5, 6, 7, 8], [1, 2, 0, 0]]
    a = engine.generate({"tokens": _toks(rows),
                         "lengths": jnp.asarray([4, 2], jnp.int32)}, 3)
    b = engine.generate({"tokens": _toks(rows),
                         "mask": jnp.asarray([[1, 1, 1, 1], [1, 1, 0, 0]])},
                        3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
