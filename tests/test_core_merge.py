"""merge primitive (§5, Figure 2 decision tree)."""

import numpy as np
import pytest

from repro.core import (CONFLICT, NO_CONFLICT, POSSIBLE_CONFLICT, LayerGraph,
                        LayerNode, LineageGraph, ModelArtifact, merge,
                        merge_artifacts)
from repro.core.lineage import RegisteredTest

from helpers import l2_test, make_chain_model


def _branch_model(seed=0, d=8):
    """Two parallel branches (b1, b2) joining at a head — enables NO_CONFLICT."""
    g = LayerGraph()
    for name in ("stem", "b1", "b2", "head"):
        g.add_node(LayerNode(name, "linear", params={"w": ((d, d), "float32")}))
    g.add_edge("stem", "b1")
    g.add_edge("stem", "b2")
    g.add_edge("b1", "head")
    g.add_edge("b2", "head")
    rng = np.random.default_rng(seed)
    params = {f"{n}/w": rng.normal(size=(d, d)).astype(np.float32)
              for n in g.nodes}
    return ModelArtifact(g, params, model_type="toy")


def _edit(m, layer, delta=0.1):
    return m.replace_params({f"{layer}/w": m.params[f"{layer}/w"] + delta})


def test_conflict_same_layer():
    m = _branch_model()
    r = merge_artifacts(m, _edit(m, "b1", 0.1), _edit(m, "b1", -0.1))
    assert r.status == CONFLICT
    assert r.conflicting_layers == ["b1"]
    assert r.merged is None


def test_possible_conflict_dependent_layers():
    m = make_chain_model(seed=0)  # chain: everything reaches the head
    r = merge_artifacts(m, _edit(m, "L0"), _edit(m, "L2"))
    assert r.status == POSSIBLE_CONFLICT
    assert r.merged is not None
    # both edits present in the merged model
    np.testing.assert_allclose(r.merged.params["L0/w"],
                               m.params["L0/w"] + 0.1, rtol=1e-6)
    np.testing.assert_allclose(r.merged.params["L2/w"],
                               m.params["L2/w"] + 0.1, rtol=1e-6)


def test_no_conflict_parallel_branches():
    m = _branch_model()
    r = merge_artifacts(m, _edit(m, "b1"), _edit(m, "b2"))
    # b1 and b2 are siblings but share the downstream head consumer ->
    # paper's tree says dependent (possible conflict), not auto-merge
    assert r.status == POSSIBLE_CONFLICT


def test_no_conflict_truly_independent():
    """Two disjoint output branches with no common consumer."""
    g = LayerGraph()
    for name in ("stem", "head_a", "head_b"):
        g.add_node(LayerNode(name, "linear", params={"w": ((8, 8), "float32")}))
    g.add_edge("stem", "head_a")
    g.add_edge("stem", "head_b")
    rng = np.random.default_rng(0)
    params = {f"{n}/w": rng.normal(size=(8, 8)).astype(np.float32) for n in g.nodes}
    m = ModelArtifact(g, params, model_type="toy")
    r = merge_artifacts(m, _edit(m, "head_a"), _edit(m, "head_b"))
    assert r.status == NO_CONFLICT
    assert r.merged is not None


def test_dependent_changes_resolved_by_tests():
    m = make_chain_model(seed=0)
    tests = [RegisteredTest(name="l2", fn=l2_test, model_type="toy")]
    r = merge_artifacts(m, _edit(m, "L0", 1e-6), _edit(m, "L2", 1e-6),
                        tests=tests, test_threshold=-1e9)
    assert r.status == NO_CONFLICT  # tests ran and passed
    assert "l2" in r.test_results


def test_conflict_both_add_same_layer():
    """Concurrently added layers with the same name collide (Figure 2)."""
    m = _branch_model()

    def with_extra(model, seed):
        from repro.core.graphir import LayerGraph, LayerNode
        g = LayerGraph()
        for n in model.graph.nodes.values():
            g.add_node(LayerNode(n.name, n.op_type, params=dict(n.params)))
        for s, d in model.graph.edges:
            g.add_edge(s, d)
        g.add_node(LayerNode("extra", "linear",
                             params={"w": ((4, 4), "float32")}))
        g.add_edge("head", "extra")
        rng = np.random.default_rng(seed)
        params = dict(model.params)
        params["extra/w"] = rng.normal(size=(4, 4)).astype(np.float32)
        return type(model)(g, params, model_type=model.model_type)

    r = merge_artifacts(m, with_extra(m, 1), with_extra(m, 2))
    assert r.status == CONFLICT
    assert "extra" in r.conflicting_layers


def test_conflict_removed_vs_changed_layer():
    """One side removes a layer the other side retrained -> conflict."""
    m = _branch_model()

    def without(model, layer):
        from repro.core.graphir import LayerGraph, LayerNode
        g = LayerGraph()
        for n in model.graph.nodes.values():
            if n.name != layer:
                g.add_node(LayerNode(n.name, n.op_type, params=dict(n.params)))
        for s, d in model.graph.edges:
            if layer not in (s, d) and s in g.nodes and d in g.nodes:
                g.add_edge(s, d)
        params = {k: v for k, v in model.params.items()
                  if not k.startswith(layer + "/")}
        return type(model)(g, params, model_type=model.model_type)

    r = merge_artifacts(m, without(m, "b1"), _edit(m, "b1"))
    assert r.status == CONFLICT
    assert "b1" in r.conflicting_layers


def test_dependent_changes_failing_tests_conflict():
    """Tests below threshold flip a dependent merge to CONFLICT (Figure 2)."""
    m = make_chain_model(seed=0)
    tests = [RegisteredTest(name="l2", fn=l2_test, model_type="toy")]
    r = merge_artifacts(m, _edit(m, "L0", 1e-6), _edit(m, "L2", 1e-6),
                        tests=tests, test_threshold=1e9)  # unreachable bar
    assert r.status == CONFLICT
    assert r.merged is None
    assert "l2" in r.test_results  # results reported even on failure
    assert sorted(r.conflicting_layers) == ["L0", "L2"]


def test_structural_add_merges_cleanly():
    """One side adds a layer, the other edits an independent head."""
    g = LayerGraph()
    for name in ("stem", "head_a", "head_b"):
        g.add_node(LayerNode(name, "linear", params={"w": ((8, 8), "float32")}))
    g.add_edge("stem", "head_a")
    g.add_edge("stem", "head_b")
    rng = np.random.default_rng(0)
    m = ModelArtifact(g, {f"{n}/w": rng.normal(size=(8, 8)).astype(np.float32)
                          for n in g.nodes}, model_type="toy")

    from repro.core.graphir import LayerGraph as LG, LayerNode as LN
    g2 = LG()
    for n in m.graph.nodes.values():
        g2.add_node(LN(n.name, n.op_type, params=dict(n.params)))
    for s, d in m.graph.edges:
        g2.add_edge(s, d)
    g2.add_node(LN("adapter", "linear", params={"w": ((8, 8), "float32")}))
    g2.add_edge("head_a", "adapter")
    params = dict(m.params)
    params["adapter/w"] = rng.normal(size=(8, 8)).astype(np.float32)
    with_adapter = ModelArtifact(g2, params, model_type="toy")

    r = merge_artifacts(m, with_adapter, _edit(m, "head_b"))
    assert r.status in (NO_CONFLICT, POSSIBLE_CONFLICT)
    assert r.merged is not None
    assert "adapter" in r.merged.graph.nodes
    np.testing.assert_allclose(r.merged.params["head_b/w"],
                               m.params["head_b/w"] + 0.1, rtol=1e-6)


def test_merge_no_common_ancestor_is_conflict(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    g.add_node(_branch_model(seed=0), "island1")
    g.add_node(_branch_model(seed=1), "island2")
    r = merge(g, "island1", "island2")
    assert r.status == CONFLICT
    assert "no common ancestor" in r.detail
    assert "merge(island1,island2)" not in g


def test_merge_explicit_ancestor_overrides_search(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    base = _branch_model()
    g.add_node(base, "base")
    for name, layer in (("u1", "b1"), ("u2", "b2")):
        g.add_node(_edit(base, layer), name)
        g.add_edge("base", name)
    r = g.merge("u1", "u2", ancestor="base")
    assert r.status in (NO_CONFLICT, POSSIBLE_CONFLICT)
    assert r.merged is not None


def test_graph_level_merge_inserts_node(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    base = _branch_model()
    g.add_node(base, "base")
    m1, m2 = _edit(base, "head_a" if "head_a" in base.graph.nodes else "b1"), \
        _edit(base, "b2")
    g.add_node(m1, "user1")
    g.add_edge("base", "user1")
    g.add_node(m2, "user2")
    g.add_edge("base", "user2")
    r = merge(g, "user1", "user2")
    assert r.status in (NO_CONFLICT, POSSIBLE_CONFLICT)
    assert "merge(user1,user2)" in g
    assert set(g.nodes["merge(user1,user2)"].parents) == {"user1", "user2"}
