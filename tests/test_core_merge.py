"""merge primitive (§5, Figure 2 decision tree)."""

import numpy as np
import pytest

from repro.core import (CONFLICT, NO_CONFLICT, POSSIBLE_CONFLICT, LayerGraph,
                        LayerNode, LineageGraph, ModelArtifact, merge,
                        merge_artifacts)
from repro.core.lineage import RegisteredTest

from helpers import l2_test, make_chain_model


def _branch_model(seed=0, d=8):
    """Two parallel branches (b1, b2) joining at a head — enables NO_CONFLICT."""
    g = LayerGraph()
    for name in ("stem", "b1", "b2", "head"):
        g.add_node(LayerNode(name, "linear", params={"w": ((d, d), "float32")}))
    g.add_edge("stem", "b1")
    g.add_edge("stem", "b2")
    g.add_edge("b1", "head")
    g.add_edge("b2", "head")
    rng = np.random.default_rng(seed)
    params = {f"{n}/w": rng.normal(size=(d, d)).astype(np.float32)
              for n in g.nodes}
    return ModelArtifact(g, params, model_type="toy")


def _edit(m, layer, delta=0.1):
    return m.replace_params({f"{layer}/w": m.params[f"{layer}/w"] + delta})


def test_conflict_same_layer():
    m = _branch_model()
    r = merge_artifacts(m, _edit(m, "b1", 0.1), _edit(m, "b1", -0.1))
    assert r.status == CONFLICT
    assert r.conflicting_layers == ["b1"]
    assert r.merged is None


def test_possible_conflict_dependent_layers():
    m = make_chain_model(seed=0)  # chain: everything reaches the head
    r = merge_artifacts(m, _edit(m, "L0"), _edit(m, "L2"))
    assert r.status == POSSIBLE_CONFLICT
    assert r.merged is not None
    # both edits present in the merged model
    np.testing.assert_allclose(r.merged.params["L0/w"],
                               m.params["L0/w"] + 0.1, rtol=1e-6)
    np.testing.assert_allclose(r.merged.params["L2/w"],
                               m.params["L2/w"] + 0.1, rtol=1e-6)


def test_no_conflict_parallel_branches():
    m = _branch_model()
    r = merge_artifacts(m, _edit(m, "b1"), _edit(m, "b2"))
    # b1 and b2 are siblings but share the downstream head consumer ->
    # paper's tree says dependent (possible conflict), not auto-merge
    assert r.status == POSSIBLE_CONFLICT


def test_no_conflict_truly_independent():
    """Two disjoint output branches with no common consumer."""
    g = LayerGraph()
    for name in ("stem", "head_a", "head_b"):
        g.add_node(LayerNode(name, "linear", params={"w": ((8, 8), "float32")}))
    g.add_edge("stem", "head_a")
    g.add_edge("stem", "head_b")
    rng = np.random.default_rng(0)
    params = {f"{n}/w": rng.normal(size=(8, 8)).astype(np.float32) for n in g.nodes}
    m = ModelArtifact(g, params, model_type="toy")
    r = merge_artifacts(m, _edit(m, "head_a"), _edit(m, "head_b"))
    assert r.status == NO_CONFLICT
    assert r.merged is not None


def test_dependent_changes_resolved_by_tests():
    m = make_chain_model(seed=0)
    tests = [RegisteredTest(name="l2", fn=l2_test, model_type="toy")]
    r = merge_artifacts(m, _edit(m, "L0", 1e-6), _edit(m, "L2", 1e-6),
                        tests=tests, test_threshold=-1e9)
    assert r.status == NO_CONFLICT  # tests ran and passed
    assert "l2" in r.test_results


def test_graph_level_merge_inserts_node(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    base = _branch_model()
    g.add_node(base, "base")
    m1, m2 = _edit(base, "head_a" if "head_a" in base.graph.nodes else "b1"), \
        _edit(base, "b2")
    g.add_node(m1, "user1")
    g.add_edge("base", "user1")
    g.add_node(m2, "user2")
    g.add_edge("base", "user2")
    r = merge(g, "user1", "user2")
    assert r.status in (NO_CONFLICT, POSSIBLE_CONFLICT)
    assert "merge(user1,user2)" in g
    assert set(g.nodes["merge(user1,user2)"].parents) == {"user1", "user2"}
