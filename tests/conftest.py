"""Shared fixtures: kill-point hygiene for the fault-injection harness."""

import pytest

from repro.common import faults


@pytest.fixture(autouse=True)
def _disarm_kill_points():
    """No kill-point armed by one test may survive into the next — a leaked
    arm turns an unrelated later test into a heisenbug."""
    yield
    faults.disarm_all()
