"""The mgit command-line interface (paper §3.1: CLI + Python dual interface)."""

import json

import pytest

from repro.cli import main as cli
from repro.core import LineageGraph
from repro.store import ArtifactStore

from helpers import finetune_like, make_chain_model


@pytest.fixture
def repo(tmp_path):
    path = str(tmp_path)
    g = LineageGraph(path=path, store=ArtifactStore(root=path))
    base = make_chain_model(seed=0, d=32)
    g.add_node(base, "base")
    g.add_edge("base", "ft")
    g.add_node(finetune_like(base, seed=1), "ft")
    return path


def test_cli_log(repo, capsys):
    assert cli(["-C", repo, "log"]) == 0
    out = capsys.readouterr().out
    assert "base" in out and "ft" in out


def test_cli_show(repo, capsys):
    cli(["-C", repo, "show", "ft"])
    info = json.loads(capsys.readouterr().out)
    assert info["parents"] == ["base"]
    assert info["storage"]["depth"] >= 1  # delta-compressed against base


def test_cli_diff(repo, capsys):
    cli(["-C", repo, "diff", "base", "ft", "--mode", "structural"])
    d = json.loads(capsys.readouterr().out)
    assert d["divergence"] == 0.0


def test_cli_stats_and_gc(repo, capsys):
    cli(["-C", repo, "stats"])
    stats = json.loads(capsys.readouterr().out)
    assert stats["compression_ratio"] > 1.0
    cli(["-C", repo, "remove-node", "ft"])
    cli(["-C", repo, "gc"])
    out = capsys.readouterr().out
    assert "reclaimed" in out


def test_cli_diag_run_memoizes_across_invocations(repo, capsys):
    # cold: executes the builtin probe; warm (separate CLI invocation, new
    # process-equivalent objects): answers entirely from the ledger
    assert cli(["-C", repo, "diag", "run", "--builtin"]) == 0
    cold = json.loads(capsys.readouterr().out)
    assert cold["executed"] > 0 and cold["memo_hits"] == 0
    assert cli(["-C", repo, "diag", "run", "--builtin"]) == 0
    warm = json.loads(capsys.readouterr().out)
    assert warm["executed"] == 0 and warm["cache_hit_ratio"] == 1.0


def test_cli_diag_history_and_gate_report(repo, capsys):
    cli(["-C", repo, "diag", "run", "--builtin"])
    capsys.readouterr()
    assert cli(["-C", repo, "diag", "history", "ft"]) == 0
    entries = json.loads(capsys.readouterr().out)
    assert entries and entries[0]["test"] == "builtin/param_rms"
    assert cli(["-C", repo, "diag", "gate-report"]) == 0
    assert json.loads(capsys.readouterr().out) == []


def test_cli_diag_blame(repo, capsys):
    assert cli(["-C", repo, "diag", "blame", "ft", "builtin/param_rms",
                "--builtin"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["status"] == "pass" and report["frontier"] == []


def test_cli_diag_run_without_tests_errors(repo, capsys):
    assert cli(["-C", repo, "diag", "run"]) == 1
    assert "no registered tests" in capsys.readouterr().out


def test_cli_test_pattern_modes_are_exclusive(repo):
    with pytest.raises(SystemExit):
        cli(["-C", repo, "test", "--re", "a", "--glob", "b"])


def test_cli_version_edge(repo, capsys):
    g = LineageGraph(path=repo, store=ArtifactStore(root=repo))
    base = g.get_model("base")
    g.add_node(finetune_like(base, seed=9), "base2", model_type="toy")
    cli(["-C", repo, "add-version-edge", "base", "base2"])
    capsys.readouterr()
    # reload from disk to confirm the CLI persisted the edge
    g2 = LineageGraph(path=repo)
    assert g2.nodes["base"].version_children == ["base2"]
