"""The mgit command-line interface (paper §3.1: CLI + Python dual interface)."""

import json

import pytest

from repro.cli import main as cli
from repro.core import LineageGraph
from repro.store import ArtifactStore

from helpers import finetune_like, make_chain_model


@pytest.fixture
def repo(tmp_path):
    path = str(tmp_path)
    g = LineageGraph(path=path, store=ArtifactStore(root=path))
    base = make_chain_model(seed=0, d=32)
    g.add_node(base, "base")
    g.add_edge("base", "ft")
    g.add_node(finetune_like(base, seed=1), "ft")
    return path


def test_cli_log(repo, capsys):
    assert cli(["-C", repo, "log"]) == 0
    out = capsys.readouterr().out
    assert "base" in out and "ft" in out


def test_cli_show(repo, capsys):
    cli(["-C", repo, "show", "ft"])
    info = json.loads(capsys.readouterr().out)
    assert info["parents"] == ["base"]
    assert info["storage"]["depth"] >= 1  # delta-compressed against base


def test_cli_diff(repo, capsys):
    cli(["-C", repo, "diff", "base", "ft", "--mode", "structural"])
    d = json.loads(capsys.readouterr().out)
    assert d["divergence"] == 0.0


def test_cli_stats_and_gc(repo, capsys):
    cli(["-C", repo, "stats"])
    stats = json.loads(capsys.readouterr().out)
    assert stats["compression_ratio"] > 1.0
    cli(["-C", repo, "remove-node", "ft"])
    cli(["-C", repo, "gc"])
    out = capsys.readouterr().out
    assert "reclaimed" in out


def test_cli_version_edge(repo, capsys):
    g = LineageGraph(path=repo, store=ArtifactStore(root=repo))
    base = g.get_model("base")
    g.add_node(finetune_like(base, seed=9), "base2", model_type="toy")
    cli(["-C", repo, "add-version-edge", "base", "base2"])
    capsys.readouterr()
    # reload from disk to confirm the CLI persisted the edge
    g2 = LineageGraph(path=repo)
    assert g2.nodes["base"].version_children == ["base2"]
