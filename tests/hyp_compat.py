"""Hypothesis compatibility shim for environments without the package.

Exposes ``given``/``settings``/``st`` backed by the real hypothesis when
installed; otherwise property tests are collected but skipped, and the rest
of the module still runs. Install dev requirements (``requirements-dev.txt``)
to run the property tests for real.
"""

from __future__ import annotations

import inspect

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*g_args, **g_kwargs):
        def deco(fn):
            # Strip the strategy-bound params from the visible signature (or
            # pytest treats them as fixtures) but keep the rest so the test
            # still composes with @pytest.mark.parametrize.
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in g_kwargs]
            if g_args:  # positional strategies bind rightmost params
                keep = keep[:len(keep) - len(g_args)]

            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__module__ = fn.__module__
            skipper.__signature__ = sig.replace(parameters=keep)
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategy:
        """Stand-in so strategy-building expressions at module scope parse."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()
