"""HLO cost model (launch/hlo_cost.py) vs XLA cost_analysis ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze, xla_cost_analysis


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_matches_xla_on_loop_free_matmul():
    f = lambda a, b: jnp.tanh(a @ b)
    c = _compile(f, jax.ShapeDtypeStruct((128, 256), np.float32),
                 jax.ShapeDtypeStruct((256, 64), np.float32))
    mine = analyze(c.as_text())
    xla = xla_cost_analysis(c)
    assert mine["flops"] == pytest.approx(xla["flops"], rel=0.02)
    assert mine["bytes"] == pytest.approx(xla["bytes accessed"], rel=0.05)


def test_scan_trip_count_multiplies():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=7)[0]

    single = _compile(lambda x, w: jnp.tanh(x @ w),
                      jax.ShapeDtypeStruct((64, 64), np.float32),
                      jax.ShapeDtypeStruct((64, 64), np.float32))
    looped = _compile(f, jax.ShapeDtypeStruct((64, 64), np.float32),
                      jax.ShapeDtypeStruct((64, 64), np.float32))
    f1 = analyze(single.as_text())["flops"]
    f7 = analyze(looped.as_text())["flops"]
    assert f7 == pytest.approx(7 * f1, rel=0.05)


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), np.float32),
                 jax.ShapeDtypeStruct((64, 64), np.float32))
    mine = analyze(c.as_text())
    # 15 matmuls of 2*64^3
    assert mine["flops"] == pytest.approx(15 * 2 * 64**3, rel=0.1)


def test_no_unknown_ops_on_model_program():
    from repro.models.config import ModelConfig
    from repro.train.step import init_state, make_train_step
    from repro.data import SyntheticPipeline
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      head_dim=16, dtype="float32", attn_chunk=16,
                      remat="dots")
    state = jax.eval_shape(lambda: init_state(cfg, 0))
    batch = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        SyntheticPipeline(cfg, batch=4, seq=16).host_batch(0))
    c = jax.jit(make_train_step(cfg)).lower(state, batch).compile()
    res = analyze(c.as_text())
    assert res["flops"] > 0 and res["bytes"] > 0
    assert not res["unknown_ops"], res["unknown_ops"]
