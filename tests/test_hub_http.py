"""Hub daemon + HttpTransport: wire parity with LocalTransport, optimistic
swap (409), journalled resume over HTTP, concurrent multi-client pushes,
server-side quarantine, auth (DESIGN.md §11)."""

import json
import threading

import numpy as np
import pytest

from repro.core import CONFLICT, NO_CONFLICT, LineageGraph
from repro.hub import HubApp, start_in_thread
from repro.remote import (HttpTransport, LocalTransport, PublishConflict,
                          RemoteState, clone, lineage_etag, pull, push,
                          remote_add, remote_list, resolve_transport)
from repro.store import ArtifactStore

from harness import FlakyHttpTransport, RacingTransport
from helpers import finetune_like, make_chain_model


def _repo(path, **store_kw):
    path = str(path)
    return LineageGraph(path=path, store=ArtifactStore(root=path, **store_kw))


def _seed_repo(path):
    g = _repo(path)
    base = make_chain_model(seed=0, d=32)
    g.add_node(base, "m@v1")
    g.add_edge("m@v1", "m@v2")
    g.add_node(finetune_like(base, seed=1), "m@v2")
    g.add_version_edge("m@v1", "m@v2")
    return g


def _stored(g, name):
    return g.store.load_artifact(g.nodes[name].artifact_ref)


def _assert_bit_identical(g1, g2, names=None):
    for name in names or g1.nodes:
        a, b = _stored(g1, name), _stored(g2, name)
        assert set(a.params) == set(b.params)
        for k in a.params:
            np.testing.assert_array_equal(np.asarray(a.params[k]),
                                          np.asarray(b.params[k]))


def _roots(g):
    return [n.artifact_ref for n in g.nodes.values() if n.artifact_ref]


@pytest.fixture
def hub(tmp_path):
    """A live hub daemon on a loopback ephemeral port."""
    app = HubApp(str(tmp_path / "hubrepo"))
    server, _ = start_in_thread(app)
    yield app, server.url
    server.shutdown()
    server.server_close()


def _transport(url, **kw):
    kw.setdefault("retries", 1)
    kw.setdefault("backoff", 0.01)
    return HttpTransport(url, **kw)


# ---------------------------------------------------------------------------
# Wire parity: HTTP round trips are bit-identical to LocalTransport's
# ---------------------------------------------------------------------------


def test_http_push_clone_matches_local_transport(tmp_path, hub):
    app, url = hub
    g = _seed_repo(tmp_path / "src")

    rep = push(g, _transport(url), state=RemoteState(g.path, "origin"))
    assert rep.published and rep.objects_transferred == rep.objects_total > 0

    # the same push through LocalTransport produces the same remote state:
    # identical lineage document, identical object keys
    local_dir = str(tmp_path / "localremote")
    push(g, LocalTransport(local_dir), state=RemoteState(g.path, "o2"))
    local_doc = json.load(open(f"{local_dir}/lineage.json"))
    hub_doc, _ = app.lineage()
    assert lineage_etag(hub_doc) == lineage_etag(local_doc)
    assert sorted(app.store.cas.keys()) == \
        sorted(ArtifactStore(root=local_dir).cas.keys())

    clone(url, str(tmp_path / "dst"))
    g2 = _repo(tmp_path / "dst")
    assert sorted(g2.nodes) == sorted(g.nodes)
    for name in g.nodes:
        assert g2.nodes[name].artifact_ref == g.nodes[name].artifact_ref
    _assert_bit_identical(g, g2)
    assert app.fsck()["ok"]
    assert g2.store.fsck(_roots(g2))["ok"]
    assert remote_list(g2.path)["origin"] == url  # url survived remote_add


def test_second_http_push_transfers_zero_objects(tmp_path, hub):
    _, url = hub
    g = _seed_repo(tmp_path / "src")
    push(g, _transport(url), state=RemoteState(g.path, "origin"))
    rep = push(g, _transport(url), state=RemoteState(g.path, "origin"))
    assert rep.objects_transferred == 0
    assert rep.bytes_transferred == 0
    assert rep.dedup_ratio == 1.0


def test_http_pull_merges_concurrent_growth(tmp_path, hub):
    _, url = hub
    g = _seed_repo(tmp_path / "src")
    push(g, _transport(url), state=RemoteState(g.path, "origin"))
    clone(url, str(tmp_path / "dst"))
    g2 = _repo(tmp_path / "dst")

    g.add_edge("m@v2", "m@v3")
    g.add_node(finetune_like(_stored(g, "m@v2"), seed=7), "m@v3")
    push(g, _transport(url), state=RemoteState(g.path, "origin"))

    g2.add_edge("m@v1", "side")
    g2.add_node(finetune_like(_stored(g2, "m@v1"), seed=8), "side")
    rep = pull(g2, _transport(url), state=RemoteState(g2.path, "origin"))
    assert rep.merge.status == NO_CONFLICT
    assert sorted(g2.nodes) == ["m@v1", "m@v2", "m@v3", "side"]
    _assert_bit_identical(g, g2, names=["m@v3"])


def test_ranged_reads_and_transport_extras(tmp_path, hub):
    app, url = hub
    g = _seed_repo(tmp_path / "src")
    t = _transport(url)
    push(g, t, state=RemoteState(g.path, "origin"))

    key = max(app.store.cas.keys(), key=app.store.cas.size)
    whole = bytes(app.store.cas.get_bytes(key))
    assert t.read_objects([key])[key] == whole
    # ranged reads slice the same bytes (zero-copy mmap path server-side)
    assert t.read_object_range(key, 0, 10) == whole[:10]
    assert t.read_object_range(key, 5, 7) == whole[5:12]
    assert t.read_object_range(key, len(whole) - 3) == whole[-3:]
    # resume positioned exactly at EOF is "done", not an error (416 -> b"")
    assert t.read_object_range(key, len(whole)) == b""
    with pytest.raises(KeyError):
        t.read_objects([key, "nope_" + "0" * 32])
    with pytest.raises(KeyError):
        t.read_object_range("nope_" + "0" * 32, 0, 4)
    stats = t.server_stats()
    assert stats["publishes"] >= 1 and stats["objects_received"] > 0


def test_path_traversal_rejected(tmp_path, hub):
    """Object keys / journal ids with path separators or dot-segments must
    404 before any filesystem join — never escape the served repo."""
    import http.client
    from urllib.parse import urlsplit
    app, url = hub
    secret = tmp_path / "secret.txt"
    secret.write_text("not yours")
    host = urlsplit(url)
    for quoted in ("..%2F..%2Fsecret.txt", "..%2f..%2f..%2fetc%2fpasswd",
                   "..", "."):
        for method, path in (("GET", f"/api/objects/{quoted}"),
                             ("GET", f"/api/journal/{quoted}"),
                             ("PUT", f"/api/journal/{quoted}"),
                             ("DELETE", f"/api/journal/{quoted}")):
            conn = http.client.HTTPConnection(host.hostname, host.port)
            conn.request(method, path, body=b"{}" if method == "PUT" else None)
            resp = conn.getresponse()
            body = resp.read()
            conn.close()
            assert resp.status == 404, (method, path, resp.status, body)
    assert secret.read_text() == "not yours"


# ---------------------------------------------------------------------------
# Optimistic lineage swap: 409 absorbed by the push retry loop
# ---------------------------------------------------------------------------


def test_publish_conflict_409_retries_and_merges(tmp_path, hub):
    app, url = hub
    g = _seed_repo(tmp_path / "src")
    racer = {"nodes": [{"name": "racer@v1", "parents": [], "children": [],
                        "version_parents": [], "version_children": [],
                        "model_type": "toy", "creation_fn": None,
                        "artifact_ref": None, "metadata": {}}]}
    t = RacingTransport(url, app, racer, retries=1, backoff=0.01)
    rep = push(g, t, state=RemoteState(g.path, "origin"))
    assert rep.published
    assert rep.publish_retries == 1          # exactly one 409 absorbed
    doc, _ = app.lineage()
    names = {n["name"] for n in doc["nodes"]}
    assert names == {"m@v1", "m@v2", "racer@v1"}  # nobody clobbered
    assert app.stats["conflicts_409"] == 1
    assert app.fsck()["ok"]


def test_stale_etag_publish_raises_409(tmp_path, hub):
    app, url = hub
    t = _transport(url)
    t.publish_lineage({"nodes": []}, expected=None)
    _, etag = t.fetch_lineage_versioned()
    t.publish_lineage({"nodes": []}, expected=etag)  # same etag: fine
    with pytest.raises(PublishConflict):
        t.publish_lineage({"nodes": []}, expected="bogus-etag")


def test_concurrent_pushes_from_two_clients_both_land(tmp_path, hub):
    app, url = hub
    ga = _repo(tmp_path / "a")
    ga.add_node(make_chain_model(seed=0, d=32, prefix="A"), "a@v1")
    gb = _repo(tmp_path / "b")
    gb.add_node(make_chain_model(seed=5, d=32, prefix="B"), "b@v1")

    reports, errors = {}, []

    def worker(name, g):
        try:
            reports[name] = push(g, _transport(url, retries=2),
                                 state=RemoteState(g.path, "origin"))
        except BaseException as exc:  # pragma: no cover - diagnostic aid
            errors.append((name, exc))

    threads = [threading.Thread(target=worker, args=("a", ga)),
               threading.Thread(target=worker, args=("b", gb))]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors
    assert reports["a"].published and reports["b"].published

    doc, _ = app.lineage()
    assert {n["name"] for n in doc["nodes"]} == {"a@v1", "b@v1"}
    # refcounts converged exactly despite racing publish/finalize pairs
    report = app.fsck()
    assert report["ok"] and not report["refcount_drift"]

    # both clients can pull the union and materialize each other's model
    pull(ga, _transport(url), state=RemoteState(ga.path, "origin"))
    pull(gb, _transport(url), state=RemoteState(gb.path, "origin"))
    _assert_bit_identical(ga, gb)


def test_same_node_divergence_converges_via_pull_merge_retry(tmp_path, hub):
    """The acceptance path: conflicting push -> pull (auto-merge) -> push."""
    app, url = hub
    g = _seed_repo(tmp_path / "src")
    push(g, _transport(url), state=RemoteState(g.path, "origin"))
    clone(url, str(tmp_path / "dst"))
    g2 = _repo(tmp_path / "dst")

    # both sides re-commit m@v2 divergently — on DISJOINT layers, so the
    # paper-§5 decision tree can auto-merge instead of conflicting
    a = _stored(g, "m@v2")
    g.add_node(a.replace_params(
        {"L0/w": np.asarray(a.params["L0/w"]) + 1.0}), "m@v2")
    push(g, _transport(url), state=RemoteState(g.path, "origin"), force=True)
    b = _stored(g2, "m@v2")
    g2.add_node(b.replace_params(
        {"L1/w": np.asarray(b.params["L1/w"]) + 2.0}), "m@v2")

    rep = push(g2, _transport(url), state=RemoteState(g2.path, "origin"))
    assert not rep.published and rep.merge.status == CONFLICT

    rep = pull(g2, _transport(url), state=RemoteState(g2.path, "origin"))
    assert rep.merge.status != CONFLICT     # paper-§5 auto-merge applied

    rep = push(g2, _transport(url), state=RemoteState(g2.path, "origin"))
    assert rep.published
    doc, _ = app.lineage()
    ref = next(n["artifact_ref"] for n in doc["nodes"]
               if n["name"] == "m@v2")
    assert ref == g2.nodes["m@v2"].artifact_ref  # merged version landed
    assert app.fsck()["ok"]


# ---------------------------------------------------------------------------
# Interrupted HTTP push: journalled resume over the network
# ---------------------------------------------------------------------------


def test_interrupted_http_push_resumes_via_server_journal(tmp_path, hub):
    app, url = hub
    g = _repo(tmp_path / "src")
    g.add_node(make_chain_model(seed=0, d=48, n_layers=6), "m@v1")

    flaky = FlakyHttpTransport(url, fail_after=2, retries=0, backoff=0.0)
    with pytest.raises(ConnectionError):
        push(g, flaky, chunk_size=3, state=RemoteState(g.path, "origin"))
    # the hub never published a lineage document...
    payload, _ = app.lineage()
    assert payload is None
    # ...but holds the landed objects plus exactly one in-flight journal
    t = _transport(url)
    tids = list(t.journal_list())
    assert len(tids) == 1
    done_before = set(t.journal_load(tids[0])["done"])
    assert done_before

    rep = push(g, t, chunk_size=3, state=RemoteState(g.path, "origin"))
    assert rep.published
    assert rep.chunks_resumed == len(done_before)  # journal honored
    assert rep.objects_transferred < rep.objects_total  # have() dedup
    assert list(t.journal_list()) == []            # journal retired
    assert app.fsck()["ok"]
    g2 = _repo(tmp_path / "dst")
    pull(g2, _transport(url))
    _assert_bit_identical(g, g2)


# ---------------------------------------------------------------------------
# Server-side policy: quarantine filtering + auth stub
# ---------------------------------------------------------------------------


def _quarantine(g, name):
    from repro.diag.gate import QUARANTINE_FLAG
    g.nodes[name].metadata[QUARANTINE_FLAG] = True
    g._commit()


def test_hub_rejects_pushed_quarantined_nodes(tmp_path, hub):
    app, url = hub
    g = _seed_repo(tmp_path / "src")
    _quarantine(g, "m@v2")
    rep = push(g, _transport(url), state=RemoteState(g.path, "origin"),
               include_quarantined=True)  # client opts in; server refuses
    assert rep.published
    assert rep.quarantine_rejected_by_remote == ["m@v2"]
    doc, _ = app.lineage()
    assert {n["name"] for n in doc["nodes"]} == {"m@v1"}
    assert app.stats["quarantine_rejected"] == 1
    # no dangling adjacency survived the drop
    v1 = next(n for n in doc["nodes"] if n["name"] == "m@v1")
    assert v1["children"] == [] and v1["version_children"] == []
    assert app.fsck()["ok"]
    # a rejected node must NOT have entered the merge base: the next pull
    # would otherwise read its absence on the hub as a remote deletion and
    # silently delete the local copy
    pull(g, _transport(url), state=RemoteState(g.path, "origin"))
    assert "m@v2" in g.nodes


def test_hub_allow_quarantined_opt_in(tmp_path):
    app = HubApp(str(tmp_path / "hubrepo"), allow_quarantined=True)
    server, _ = start_in_thread(app)
    try:
        g = _seed_repo(tmp_path / "src")
        _quarantine(g, "m@v2")
        push(g, _transport(server.url), state=RemoteState(g.path, "origin"),
             include_quarantined=True)
        doc, _ = app.lineage()
        assert {n["name"] for n in doc["nodes"]} == {"m@v1", "m@v2"}
    finally:
        server.shutdown()
        server.server_close()


def test_auth_token_enforced(tmp_path):
    app = HubApp(str(tmp_path / "hubrepo"), token="sekrit")
    server, _ = start_in_thread(app)
    try:
        bad = HttpTransport(server.url, token=None, retries=0)
        bad.ensure_repo()  # ping stays open for health checks
        with pytest.raises(PermissionError):
            bad.have(["k"])
        with pytest.raises(PermissionError):
            HttpTransport(server.url, token="wrong", retries=0).have(["k"])
        good = HttpTransport(server.url, token="sekrit", retries=0)
        assert good.have(["k"]) == set()
        assert app.stats["auth_failures"] == 2
    finally:
        server.shutdown()
        server.server_close()


# ---------------------------------------------------------------------------
# Plumbing: scheme dispatch, etag parity, local optimistic swap
# ---------------------------------------------------------------------------


def test_resolve_transport_scheme_dispatch(tmp_path):
    repo = str(tmp_path / "repo")
    remote_add(repo, "hubby", "http://127.0.0.1:1/x")
    t, name = resolve_transport(repo, "hubby")
    assert isinstance(t, HttpTransport) and name == "hubby"
    assert remote_list(repo)["hubby"] == "http://127.0.0.1:1/x"
    t, name = resolve_transport(repo, str(tmp_path / "peer"))
    assert isinstance(t, LocalTransport) and name is None


def test_local_transport_optimistic_swap(tmp_path):
    t = LocalTransport(str(tmp_path / "remote"))
    t.ensure_repo()
    t.publish_lineage({"nodes": []}, expected=None)
    payload, etag = t.fetch_lineage_versioned()
    assert payload == {"nodes": []} and etag == lineage_etag(payload)
    with pytest.raises(PublishConflict):
        t.publish_lineage({"nodes": []}, expected="stale")
    t.publish_lineage({"nodes": [{"name": "x"}]}, expected=etag)
    assert t.fetch_lineage() == {"nodes": [{"name": "x"}]}
