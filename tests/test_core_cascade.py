"""run_update_cascade (Algorithm 2), incl. MTL groups and creation functions."""

import numpy as np
import pytest

from repro.core import (CreationFunction, LineageGraph, ModelArtifact,
                        next_version_name, register_creation_type,
                        run_update_cascade)

from helpers import finetune_like, make_chain_model, reinit_head


@register_creation_type("test-finetune")
class FinetuneCr(CreationFunction):
    """Deterministic 'finetune': parent params + seed-derived perturbation."""

    def __call__(self, parents):
        parent = parents[0].get_model()
        return finetune_like(parent, seed=self.config["seed"], density=1.0,
                             scale=self.config.get("scale", 1e-4))


@register_creation_type("test-mtl")
class MTLCr(CreationFunction):
    def __call__(self, parents):
        return finetune_like(parents[0].get_model(), seed=self.config["seed"])

    def run_group(self, nodes):
        # shared trunk: all group members share the parent's trunk params and
        # get member-specific heads
        out = []
        for node in nodes:
            m = finetune_like(node.get_parents()[0].get_model(),
                              seed=node.creation_fn.config["seed"])
            shared = node.get_parents()[0].get_model()
            m = m.replace_params({k: v for k, v in shared.params.items()
                                  if not k.startswith("head")})
            out.append(m)
        return out


def _build(tmp_path, n_children=3):
    g = LineageGraph(path=str(tmp_path))
    root = make_chain_model(seed=0)
    g.add_node(root, "mlm")
    for i in range(n_children):
        cr = FinetuneCr(seed=100 + i)
        child = cr([g.nodes["mlm"]])
        g.add_node(child, f"task{i}", cr=cr)
        g.add_edge("mlm", f"task{i}")
    return g


def test_next_version_name():
    assert next_version_name("m") == "m@v2"
    assert next_version_name("m@v2") == "m@v3"
    assert next_version_name("m@v9") == "m@v10"


def test_next_version_name_edge_cases():
    # non-numeric suffix after @v: treated as part of the name, not a version
    assert next_version_name("exp@vfinal") == "exp@vfinal@v2"
    # bare trailing @v (empty suffix) likewise gets a fresh version tag
    assert next_version_name("m@v") == "m@v@v2"
    # only the LAST @v segment is the version; earlier ones are name text
    assert next_version_name("a@v1@v7") == "a@v1@v8"
    # large and zero-padded versions parse as integers
    assert next_version_name("m@v99") == "m@v100"
    assert next_version_name("m@v007") == "m@v8"
    # 'v2' without the @ separator is name text
    assert next_version_name("v2") == "v2@v2"
    # names containing '@' but not '@v' are untouched name text
    assert next_version_name("user@host") == "user@host@v2"
    # negative-looking suffix is not a digit sequence
    assert next_version_name("m@v-1") == "m@v-1@v2"


def test_cascade_creates_new_versions(tmp_path):
    g = _build(tmp_path)
    new_root = finetune_like(g.get_model("mlm"), seed=999, scale=1e-3)
    g.add_node(new_root, "mlm@v2")
    created = run_update_cascade(g, "mlm", "mlm@v2")
    assert sorted(created) == ["task0@v2", "task1@v2", "task2@v2"]
    for i in range(3):
        node = g.nodes[f"task{i}@v2"]
        assert node.parents == ["mlm@v2"]                 # provenance rewired
        assert g.nodes[f"task{i}"].version_children == [f"task{i}@v2"]
        # the new version was materialized via the creation function
        m_new = node.get_model()
        m_expected = FinetuneCr(seed=100 + i)([g.nodes["mlm@v2"]])
        np.testing.assert_allclose(m_new.params["L0/w"],
                                   m_expected.params["L0/w"], atol=1e-6)


def test_cascade_never_overwrites(tmp_path):
    g = _build(tmp_path)
    before = {k: v.copy() for k, v in g.get_model("task0").params.items()}
    g.add_node(finetune_like(g.get_model("mlm"), seed=5), "mlm@v2")
    run_update_cascade(g, "mlm", "mlm@v2")
    after = g.get_model("task0").params
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])


def test_cascade_skip_fn(tmp_path):
    g = _build(tmp_path)
    g.add_node(finetune_like(g.get_model("mlm"), seed=5), "mlm@v2")
    created = run_update_cascade(g, "mlm", "mlm@v2",
                                 skip_fn=lambda n: n.name == "task1")
    assert "task1@v2" not in created
    assert "task0@v2" in created


def test_cascade_multi_level(tmp_path):
    g = _build(tmp_path, n_children=1)
    # grandchild under task0
    cr = FinetuneCr(seed=500)
    gc = cr([g.nodes["task0"]])
    g.add_node(gc, "distilled", cr=cr)
    g.add_edge("task0", "distilled")
    g.add_node(finetune_like(g.get_model("mlm"), seed=5), "mlm@v2")
    created = run_update_cascade(g, "mlm", "mlm@v2")
    assert "task0@v2" in created and "distilled@v2" in created
    assert g.nodes["distilled@v2"].parents == ["task0@v2"]


def test_cascade_mtl_group(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    root = make_chain_model(seed=0)
    g.add_node(root, "mlm")
    for i in range(2):
        cr = MTLCr(seed=100 + i)
        cr.mtl_group = "glue"
        child = cr([g.nodes["mlm"]])
        g.add_node(child, f"mtl{i}", cr=cr)
        g.add_edge("mlm", f"mtl{i}")
    g.add_node(finetune_like(root, seed=9), "mlm@v2")
    created = run_update_cascade(g, "mlm", "mlm@v2")
    assert sorted(created) == ["mtl0@v2", "mtl1@v2"]
    # group members share trunk parameters exactly (MTL invariant)
    m0 = g.get_model("mtl0@v2")
    m1 = g.get_model("mtl1@v2")
    for k in m0.params:
        if not k.startswith("head"):
            np.testing.assert_array_equal(m0.params[k], m1.params[k])
