"""run_update_cascade (Algorithm 2), incl. MTL groups and creation functions."""

import numpy as np
import pytest

from repro.core import (CreationFunction, LineageGraph, ModelArtifact,
                        next_version_name, register_creation_type,
                        run_update_cascade)

from helpers import finetune_like, make_chain_model, reinit_head


@register_creation_type("test-finetune")
class FinetuneCr(CreationFunction):
    """Deterministic 'finetune': parent params + seed-derived perturbation."""

    def __call__(self, parents):
        parent = parents[0].get_model()
        return finetune_like(parent, seed=self.config["seed"], density=1.0,
                             scale=self.config.get("scale", 1e-4))


@register_creation_type("test-mtl")
class MTLCr(CreationFunction):
    def __call__(self, parents):
        return finetune_like(parents[0].get_model(), seed=self.config["seed"])

    def run_group(self, nodes):
        # shared trunk: all group members share the parent's trunk params and
        # get member-specific heads
        out = []
        for node in nodes:
            m = finetune_like(node.get_parents()[0].get_model(),
                              seed=node.creation_fn.config["seed"])
            shared = node.get_parents()[0].get_model()
            m = m.replace_params({k: v for k, v in shared.params.items()
                                  if not k.startswith("head")})
            out.append(m)
        return out


def _build(tmp_path, n_children=3):
    g = LineageGraph(path=str(tmp_path))
    root = make_chain_model(seed=0)
    g.add_node(root, "mlm")
    for i in range(n_children):
        cr = FinetuneCr(seed=100 + i)
        child = cr([g.nodes["mlm"]])
        g.add_node(child, f"task{i}", cr=cr)
        g.add_edge("mlm", f"task{i}")
    return g


def test_next_version_name():
    assert next_version_name("m") == "m@v2"
    assert next_version_name("m@v2") == "m@v3"
    assert next_version_name("m@v9") == "m@v10"


def test_next_version_name_edge_cases():
    # non-numeric suffix after @v: treated as part of the name, not a version
    assert next_version_name("exp@vfinal") == "exp@vfinal@v2"
    # bare trailing @v (empty suffix) likewise gets a fresh version tag
    assert next_version_name("m@v") == "m@v@v2"
    # only the LAST @v segment is the version; earlier ones are name text
    assert next_version_name("a@v1@v7") == "a@v1@v8"
    # large and zero-padded versions parse as integers
    assert next_version_name("m@v99") == "m@v100"
    assert next_version_name("m@v007") == "m@v8"
    # 'v2' without the @ separator is name text
    assert next_version_name("v2") == "v2@v2"
    # names containing '@' but not '@v' are untouched name text
    assert next_version_name("user@host") == "user@host@v2"
    # negative-looking suffix is not a digit sequence
    assert next_version_name("m@v-1") == "m@v-1@v2"


def test_cascade_creates_new_versions(tmp_path):
    g = _build(tmp_path)
    new_root = finetune_like(g.get_model("mlm"), seed=999, scale=1e-3)
    g.add_node(new_root, "mlm@v2")
    created = run_update_cascade(g, "mlm", "mlm@v2")
    assert sorted(created) == ["task0@v2", "task1@v2", "task2@v2"]
    for i in range(3):
        node = g.nodes[f"task{i}@v2"]
        assert node.parents == ["mlm@v2"]                 # provenance rewired
        assert g.nodes[f"task{i}"].version_children == [f"task{i}@v2"]
        # the new version was materialized via the creation function
        m_new = node.get_model()
        m_expected = FinetuneCr(seed=100 + i)([g.nodes["mlm@v2"]])
        np.testing.assert_allclose(m_new.params["L0/w"],
                                   m_expected.params["L0/w"], atol=1e-6)


def test_cascade_never_overwrites(tmp_path):
    g = _build(tmp_path)
    before = {k: v.copy() for k, v in g.get_model("task0").params.items()}
    g.add_node(finetune_like(g.get_model("mlm"), seed=5), "mlm@v2")
    run_update_cascade(g, "mlm", "mlm@v2")
    after = g.get_model("task0").params
    for k in before:
        np.testing.assert_array_equal(before[k], after[k])


def test_cascade_skip_fn(tmp_path):
    g = _build(tmp_path)
    g.add_node(finetune_like(g.get_model("mlm"), seed=5), "mlm@v2")
    created = run_update_cascade(g, "mlm", "mlm@v2",
                                 skip_fn=lambda n: n.name == "task1")
    assert "task1@v2" not in created
    assert "task0@v2" in created


def test_cascade_multi_level(tmp_path):
    g = _build(tmp_path, n_children=1)
    # grandchild under task0
    cr = FinetuneCr(seed=500)
    gc = cr([g.nodes["task0"]])
    g.add_node(gc, "distilled", cr=cr)
    g.add_edge("task0", "distilled")
    g.add_node(finetune_like(g.get_model("mlm"), seed=5), "mlm@v2")
    created = run_update_cascade(g, "mlm", "mlm@v2")
    assert "task0@v2" in created and "distilled@v2" in created
    assert g.nodes["distilled@v2"].parents == ["task0@v2"]


@register_creation_type("test-boom")
class BoomCr(CreationFunction):
    """Creation function that fails on demand (exception-safety tests)."""

    def __call__(self, parents):
        if self.config.get("boom"):
            raise RuntimeError("creation failed")
        return finetune_like(parents[0].get_model(), seed=self.config["seed"])


def test_cascade_rolls_back_unmaterialized_nodes(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    root = make_chain_model(seed=0)
    g.add_node(root, "mlm")
    for i, boom in enumerate([False, True, False]):
        cr = BoomCr(seed=100 + i, boom=boom)
        g.add_node(finetune_like(root, seed=50 + i), f"task{i}", cr=cr)
        g.add_edge("mlm", f"task{i}")
    g.add_node(finetune_like(root, seed=999), "mlm@v2")

    with pytest.raises(RuntimeError, match="creation failed"):
        run_update_cascade(g, "mlm", "mlm@v2")

    # the raising node's next version (and every other phase-1 empty node)
    # is gone; edges are detached; nothing dangles
    assert "task1@v2" not in g.nodes
    assert "task1@v2" not in g.nodes["task1"].version_children
    assert "task1@v2" not in g.nodes["mlm@v2"].children
    for node in g.nodes.values():
        for ref in node.children + node.version_children + node.parents:
            assert ref in g.nodes, f"dangling edge {node.name} -> {ref}"
    # the persisted document matches (no half-built graph was committed)
    g2 = LineageGraph(path=str(tmp_path))
    assert set(g2.nodes) == set(g.nodes)

    # materialized siblings survive with their artifacts
    done = [n for n in ("task0@v2", "task2@v2") if n in g.nodes]
    for name in done:
        assert g.nodes[name].artifact is not None

    # re-running after fixing the creation function resumes idempotently
    g.nodes["task1"].creation_fn = BoomCr(seed=101, boom=False)
    created = run_update_cascade(g, "mlm", "mlm@v2")
    assert "task1@v2" in g.nodes
    assert set(created) | set(done) >= {"task0@v2", "task1@v2", "task2@v2"}


def test_cascade_resume_rewires_to_new_parent_versions(tmp_path):
    """Resuming after a mid-cascade failure must derive the retried child
    from the parent's NEW version, not the stale one (the idempotence skip
    still records the old->new mapping)."""
    g = LineageGraph(path=str(tmp_path))
    root = make_chain_model(seed=0)
    g.add_node(root, "mlm")
    a_cr = BoomCr(seed=1, boom=False)
    g.add_node(a_cr([g.nodes["mlm"]]), "a", cr=a_cr)
    g.add_edge("mlm", "a")
    b_cr = BoomCr(seed=2, boom=True)
    g.add_node(finetune_like(g.get_model("a"), seed=3), "b", cr=b_cr)
    g.add_edge("a", "b")
    g.add_node(finetune_like(root, seed=999), "mlm@v2")

    with pytest.raises(RuntimeError):
        run_update_cascade(g, "mlm", "mlm@v2")
    assert "a@v2" in g.nodes and "b@v2" not in g.nodes

    g.nodes["b"].creation_fn = BoomCr(seed=2, boom=False)
    created = run_update_cascade(g, "mlm", "mlm@v2")
    assert "b@v2" in created
    assert g.nodes["b@v2"].parents == ["a@v2"]   # NOT the stale "a"
    expected = BoomCr(seed=2)([g.nodes["a@v2"]])
    np.testing.assert_array_equal(g.get_model("b@v2").params["L0/w"],
                                  expected.params["L0/w"])


def test_cascade_rollback_with_store_keeps_store_consistent(tmp_path):
    from repro.store import ArtifactStore
    g = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    root = make_chain_model(seed=0)
    g.add_node(root, "mlm")
    g.add_node(finetune_like(root, seed=50), "task0", cr=BoomCr(seed=1, boom=True))
    g.add_edge("mlm", "task0")
    g.add_node(finetune_like(root, seed=999), "mlm@v2")
    with pytest.raises(RuntimeError):
        run_update_cascade(g, "mlm", "mlm@v2")
    assert "task0@v2" not in g.nodes
    roots = [n.artifact_ref for n in g.nodes.values() if n.artifact_ref]
    assert g.store.fsck(roots)["ok"]


def test_cascade_gate_quarantines_regressions(tmp_path):
    """End-to-end: gated cascade quarantines the regressing rebuild but
    keeps the version edge + artifact (DESIGN.md §9.4)."""
    from repro.diag import TestGate, gate_report, is_quarantined

    @register_creation_type("test-regress")
    class RegressCr(CreationFunction):
        def __call__(self, parents):
            m = finetune_like(parents[0].get_model(), seed=self.config["seed"])
            if self.config.get("regress"):
                m.metadata["broken"] = True
            return m

    def flag_test(model):
        return float("nan") if model.metadata.get("broken") else 1.0

    g = LineageGraph(path=str(tmp_path))
    root = make_chain_model(seed=0)
    g.add_node(root, "mlm")
    for i, regress in enumerate([False, True]):
        # the ORIGINAL task models are clean; only the regressing creation
        # function poisons its rebuild (a true new failure, not inherited)
        cr = RegressCr(seed=100 + i, regress=regress)
        g.add_node(finetune_like(root, seed=50 + i), f"task{i}", cr=cr)
        g.add_edge("mlm", f"task{i}")
    g.register_test_function(flag_test, "probe/flag", mt="toy")
    g.add_node(finetune_like(root, seed=999), "mlm@v2")

    gate = TestGate(graph=g)
    created = run_update_cascade(g, "mlm", "mlm@v2", gate=gate)
    assert sorted(created) == ["task0@v2", "task1@v2"]
    assert not is_quarantined(g.nodes["task0@v2"])
    assert is_quarantined(g.nodes["task1@v2"])
    assert g.nodes["task1"].version_children == ["task1@v2"]   # edge kept
    assert g.nodes["task1@v2"].artifact is not None            # model kept
    assert [r["node"] for r in gate_report(g)] == ["task1@v2"]
    assert len(gate.decisions) == 2


def test_cascade_mtl_group(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    root = make_chain_model(seed=0)
    g.add_node(root, "mlm")
    for i in range(2):
        cr = MTLCr(seed=100 + i)
        cr.mtl_group = "glue"
        child = cr([g.nodes["mlm"]])
        g.add_node(child, f"mtl{i}", cr=cr)
        g.add_edge("mlm", f"mtl{i}")
    g.add_node(finetune_like(root, seed=9), "mlm@v2")
    created = run_update_cascade(g, "mlm", "mlm@v2")
    assert sorted(created) == ["mtl0@v2", "mtl1@v2"]
    # group members share trunk parameters exactly (MTL invariant)
    m0 = g.get_model("mtl0@v2")
    m1 = g.get_model("mtl1@v2")
    for k in m0.params:
        if not k.startswith("head"):
            np.testing.assert_array_equal(m0.params[k], m1.params[k])
