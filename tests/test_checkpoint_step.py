"""Step-delta commit engine + continuous-checkpointing manager (DESIGN.md §15).

Covers the four layers of the engine: lossless xdelta storage (bit-identical
resume), the lossy int8 tier with exact keyframes and nearest-exact restore,
the fingerprint skip path, async double-buffering (coalesce, error
propagation, crash atomicity), and elastic restore over chunked manifests.
"""

import json
import os

import jax
import numpy as np
import pytest

from repro.store import ArtifactStore
from repro.store.checkpoint import CKPT_STATS, CheckpointManager
from repro.store.codecs import (bitpattern_apply, bitpattern_delta,
                                get_codec)
from repro.store.manifest_walk import parse_manifest


def _state(seed=0, n=64, step=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.standard_normal((n, n)).astype(np.float32)},
        "opt": {
            "mu": {"w": rng.standard_normal((n, n)).astype(np.float32) * 1e-3},
            "nu": {"w": (rng.random((n, n)).astype(np.float32) * 1e-2)},
            "count": np.asarray(step, np.int32),
        },
        "step": np.asarray(step, np.int32),
    }


def _perturb(state, scale=1e-4, seed=1):
    rng = np.random.default_rng(seed)

    def bump(x):
        if x.dtype == np.float32:
            return x + rng.normal(scale=scale, size=x.shape).astype(np.float32)
        return x + 1
    return jax.tree_util.tree_map(bump, state)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(state)]


# ---------------------------------------------------------------------------
# codecs: byte-plane codec + bitpattern arithmetic
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "float16", "int32", "int8"])
def test_bitpattern_delta_roundtrip_bit_exact(dtype):
    rng = np.random.default_rng(0)
    parent = rng.standard_normal((37, 11)).astype(dtype) \
        if dtype.startswith("float") else \
        rng.integers(-100, 100, (37, 11)).astype(dtype)
    child = parent.copy()
    child.flat[::7] += np.asarray(3, dtype)
    d = bitpattern_delta(child, parent)
    back = bitpattern_apply(parent, d, dtype, child.shape)
    assert back.tobytes() == child.tobytes()  # bit-exact, not just close


def test_byteplane_codec_roundtrip_and_ratio():
    cod = get_codec("xd")
    rng = np.random.default_rng(1)
    base = rng.standard_normal(4096).astype(np.float32)
    child = base + np.float32(1e-6)
    d = bitpattern_delta(child, base)
    blob = cod.encode(d)
    out = cod.decode(blob, d.size, dtype=str(d.dtype))
    assert out.tobytes() == d.tobytes()
    # near-identical steps: exponent/high-mantissa planes are ~constant
    assert len(blob) < d.nbytes


# ---------------------------------------------------------------------------
# storage: commit_step manifests
# ---------------------------------------------------------------------------


def test_commit_step_exact_bit_identity(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    s = _state(0)
    states = []
    for i in range(5):  # deeper than one hop: chained xdelta entries
        states.append(s)
        cm.save(i, s, blocking=True)
        s = _perturb(s, seed=i + 1)
    for i, si in enumerate(states):
        restored, step = cm.restore(step=i, template=si)
        assert step == i
        for a, b in zip(_leaves(si), _leaves(restored)):
            assert a.tobytes() == b.tobytes()  # bit-identical resume


def test_commit_step_manifest_kinds_and_parents(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    cm.save(0, _state(0), blocking=True)
    cm.save(1, _perturb(_state(0)), blocking=True)
    ref0 = cm.lineage.nodes["m/step0"].artifact_ref
    ref1 = cm.lineage.nodes["m/step1"].artifact_ref
    m1 = cm.store.get_manifest(ref1)
    kinds = {e["kind"] for e in m1["params"].values()}
    assert "xdelta" in kinds
    # manifest_walk sees xdelta parent edges (sync/fsck closure correctness)
    info = parse_manifest(json.dumps(m1).encode())
    assert ref0 in info.parents
    xe = next(e for e in m1["params"].values() if e["kind"] == "xdelta")
    assert xe["parent_ref"] == ref0 and xe["d"] >= 1


def test_commit_step_chain_gate_resets_to_full(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                           max_chain_depth=2)
    s = _state(0)
    for i in range(6):
        cm.save(i, s, blocking=True)
        s = _perturb(s, seed=i + 1)
    for i in range(6):
        ref = cm.lineage.nodes[f"m/step{i}"].artifact_ref
        m = cm.store.get_manifest(ref)
        assert all(e.get("d", 0) <= 2 for e in m["params"].values())
        restored, _ = cm.restore(step=i, template=_state())


def test_fingerprint_skip_reuses_parent_entries(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                           fingerprint_min_bytes=0, fingerprint_device=False)
    s = _state(0)
    cm.save(0, s, blocking=True)
    before = int(CKPT_STATS["leaves_skipped"])
    cm.save(1, s, blocking=True)  # identical state: every leaf skips
    assert int(CKPT_STATS["leaves_skipped"]) - before == len(_leaves(s))
    m0 = cm.store.get_manifest(cm.lineage.nodes["m/step0"].artifact_ref)
    m1 = cm.store.get_manifest(cm.lineage.nodes["m/step1"].artifact_ref)
    for k, e in m1["params"].items():
        assert e["kind"] == m0["params"][k]["kind"]
        assert e.get("tensor") == m0["params"][k].get("tensor")
    restored, _ = cm.restore(step=1, template=s)
    for a, b in zip(_leaves(s), _leaves(restored)):
        assert a.tobytes() == b.tobytes()


def test_fingerprint_partial_skip_only_changed_leaves_ship(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                           fingerprint_min_bytes=0, fingerprint_device=False)
    s = _state(0)
    cm.save(0, s, blocking=True)
    s2 = {**s, "params": {"w": s["params"]["w"] + np.float32(1e-4)},
          "step": np.asarray(1, np.int32)}
    cm.save(1, s2, blocking=True)
    m1 = cm.store.get_manifest(cm.lineage.nodes["m/step1"].artifact_ref)
    assert m1["params"]["params/w"]["kind"] == "xdelta"
    m0 = cm.store.get_manifest(cm.lineage.nodes["m/step0"].artifact_ref)
    # untouched optimizer leaves re-reference the parent's objects verbatim
    assert (m1["params"]["opt/nu/w"].get("tensor")
            == m0["params"]["opt/nu/w"].get("tensor"))
    restored, _ = cm.restore(step=1, template=s2)
    for a, b in zip(_leaves(s2), _leaves(restored)):
        assert a.tobytes() == b.tobytes()


# ---------------------------------------------------------------------------
# lossy tier: keyframes, nearest-exact restore, nu log-domain
# ---------------------------------------------------------------------------


def test_lossy_tier_keyframes_and_nearest_exact_restore(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                           tier="lossy", keyframe_every=3)
    s = _state(0)
    live = {}
    for i in range(6):
        live[i] = s
        cm.save(i, s, blocking=True)
        s = _perturb(s, scale=1e-3, seed=i + 1)
    lossy_flags = {}
    for i in range(6):
        ref = cm.lineage.nodes[f"m/step{i}"].artifact_ref
        md = cm.store.get_manifest(ref).get("metadata") or {}
        lossy_flags[i] = bool(md.get("lossy"))
    # commit 0 is a full base, every keyframe_every-th commit is exact
    assert lossy_flags == {0: False, 1: True, 2: True, 3: False,
                           4: True, 5: True}
    # default restore at a lossy step resolves to the nearest exact ancestor
    _, step = cm.restore(step=5)
    assert step == 3
    _, step = cm.restore(step=4)
    assert step == 3
    _, step = cm.restore(step=3)
    assert step == 3
    # keyframes are unquantized: bit-identical except nu, which lives in
    # the log domain and roundtrips through log1p/expm1 (~1 ulp)
    flat, _ = cm.restore(step=3)
    from repro.store.checkpoint import flatten_state
    live_flat = flatten_state(live[3])
    for k, a in live_flat.items():
        if k == "opt/nu/w":
            np.testing.assert_allclose(flat[k], a, rtol=3e-7, atol=0)
        else:
            assert flat[k].tobytes() == a.tobytes(), k


def test_lossy_tier_allow_lossy_within_ef_bound(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                           tier="lossy", keyframe_every=4)
    s = _state(0)
    live = {}
    for i in range(4):
        live[i] = s
        cm.save(i, s, blocking=True)
        s = _perturb(s, scale=1e-3, seed=i + 1)
    restored, step = cm.restore(step=2, template=live[2], allow_lossy=True)
    assert step == 2
    for a, b in zip(_leaves(live[2]), _leaves(restored)):
        if a.dtype != np.float32:
            assert a.tobytes() == b.tobytes()
            continue
        # int8 grid over the per-leaf diff range; error feedback keeps the
        # committed truth within one quantization cell of the live value
        err = np.abs(a.astype(np.float64) - b.astype(np.float64))
        amax = float(np.abs(a).max())
        assert float(err.max()) <= max(amax / 32.0, 1e-6)


def test_lossy_tier_nu_log_domain_transform(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                           tier="lossy", keyframe_every=4)
    s = _state(0)
    cm.save(0, s, blocking=True)
    s2 = _perturb(s, scale=1e-3, seed=1)
    cm.save(1, s2, blocking=True)
    ref = cm.lineage.nodes["m/step1"].artifact_ref
    md = cm.store.get_manifest(ref).get("metadata") or {}
    assert md.get("transforms", {}).get("opt/nu/w") == "log1p"
    # raw stored value is in the log domain; restore() inverts it
    raw = cm.lineage.nodes["m/step1"].get_model().params["opt/nu/w"]
    restored, _ = cm.restore(step=1, allow_lossy=True)
    nu_live = np.asarray(s2["opt"]["nu"]["w"], np.float64)
    assert np.allclose(np.expm1(np.asarray(raw, np.float64)),
                       restored["opt/nu/w"], rtol=1e-6, atol=1e-9)
    # absolute bound: the int8 grid spans the per-leaf diff range, so the
    # cell size is ~amax(diff)/127 regardless of the value's own magnitude
    assert np.allclose(restored["opt/nu/w"], nu_live,
                       rtol=5e-2, atol=1e-4)


# ---------------------------------------------------------------------------
# async engine: coalesce, error propagation, crash atomicity
# ---------------------------------------------------------------------------


def test_merge_coalesce_keeps_changed_leaf_values():
    old = (1, "m/step1", {"a": np.ones(4), "b": np.full(4, 2.0), "c": None},
           frozenset({"c"}))
    # leaf "b" changed between snapshots but fingerprint-matched the OLD
    # snapshot at enqueue time -> the merge must ship old's value for it
    new = (2, "m/step2", {"a": np.zeros(4), "b": None, "c": None},
           frozenset({"b", "c"}))
    step, name, flat, skip = CheckpointManager._merge(old, new)
    assert (step, name) == (2, "m/step2")
    assert skip == frozenset({"c"})  # only skipped-in-BOTH stays skipped
    assert np.array_equal(flat["a"], np.zeros(4))  # newest value wins
    assert np.array_equal(flat["b"], np.full(4, 2.0))  # backfilled from old
    assert flat["c"] is None


def test_async_coalesce_to_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=True)
    s = _state(0)
    for i in range(8):
        cm.save(i, s)
        s = _perturb(s, seed=i + 1)
    cm.wait()
    steps = sorted(cm._steps())
    assert steps[-1] == 7  # the latest save always lands, coalesced or not
    last = _state(0)
    for i in range(7):
        last = _perturb(last, seed=i + 1)
    restored, _ = cm.restore(step=7, template=last)
    for a, b in zip(_leaves(last), _leaves(restored)):
        assert a.tobytes() == b.tobytes()
    cm.close()


def test_async_error_surfaces_on_next_save(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=True)

    def boom(*a, **k):
        raise RuntimeError("injected commit failure")

    cm._commit = boom
    cm.save(0, _state(0))
    deadline = 100
    while cm._error is None and deadline:
        import time
        time.sleep(0.02)
        deadline -= 1
    assert cm._error is not None
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        cm.save(1, _state(1))
    # the failed baseline was dropped: the next save re-fingerprints fresh
    assert cm._last_fps == {} and cm._prev_flat is None


def test_async_error_surfaces_on_close(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=True)

    def boom(*a, **k):
        raise RuntimeError("injected commit failure")

    cm._commit = boom
    cm.save(0, _state(0))
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        cm.close()


def test_crash_between_manifest_and_lineage_rolls_back(tmp_path):
    """Kill between object land and the lineage pointer move: restart
    resumes the previous step and fsck is clean (satellite b)."""
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    cm.save(1, _state(1), blocking=True)

    real_save = cm.lineage.save

    def killed(*a, **k):
        raise OSError("simulated kill mid-commit")

    cm.lineage.save = killed
    with pytest.raises(OSError):
        cm.save(2, _state(2), blocking=True)
    cm.lineage.save = real_save
    assert os.path.exists(os.path.join(str(tmp_path), "ckpt_journal.json"))

    # "restart": a fresh manager over the same directory
    before = int(CKPT_STATS["journal_rollbacks"])
    cm2 = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    assert int(CKPT_STATS["journal_rollbacks"]) - before == 1
    assert not os.path.exists(os.path.join(str(tmp_path),
                                           "ckpt_journal.json"))
    assert cm2.latest_step() == 1
    restored, step = cm2.restore(template=_state())
    assert step == 1
    roots = [n.artifact_ref for n in cm2.lineage.nodes.values()
             if n.artifact_ref]
    report = cm2.store.fsck(roots)
    assert report["ok"], report
    # and the rolled-back step can be committed again cleanly
    cm2.save(2, _state(2), blocking=True)
    assert cm2.latest_step() == 2


def test_lossy_rollback_recommit_releases_superseded_manifests(tmp_path):
    """Lossy-tier crash/restart flow (review: re-commit ref leak): restore
    rolls the lossy head back to the keyframe, training re-runs forward,
    and the re-committed steps must release their superseded manifests —
    otherwise fsck reports refcount drift."""
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                           tier="lossy", keyframe_every=3)
    s = _state(0)
    states = {}
    for i in range(5):  # exact keyframes at steps 0 and 3; 4 is lossy
        states[i] = s
        cm.save(i, s, blocking=True)
        s = _perturb(s, scale=1e-3, seed=i + 1)

    # "restart": the lossy head resolves back to the step-3 keyframe
    cm2 = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                            tier="lossy", keyframe_every=3)
    _, start = cm2.restore(template=_state())
    assert start == 3
    old4 = cm2.lineage.nodes["m/step4"].artifact_ref
    s4 = _perturb(states[3], scale=1e-3, seed=41)
    cm2.save(4, s4, blocking=True)  # re-commit of an existing step
    cm2.save(5, _perturb(s4, scale=1e-3, seed=42), blocking=True)
    assert cm2.lineage.nodes["m/step4"].artifact_ref != old4

    roots = [n.artifact_ref for n in cm2.lineage.nodes.values()
             if n.artifact_ref]
    report = cm2.store.fsck(roots)
    assert report["ok"], report
    # the re-committed step 4 is this run's keyframe: the new lossy head
    # resolves to it, bit-identical except nu's log-domain roundtrip
    from repro.store.checkpoint import flatten_state
    flat4, st = cm2.restore()
    assert st == 4
    for k, a in flatten_state(s4).items():
        if k != "opt/nu/w":
            assert flat4[k].tobytes() == a.tobytes(), k


def test_recommit_crash_before_stale_release_recovers(tmp_path):
    """Kill after the lineage landed on a re-committed manifest but before
    the superseded one was released: the journal still names it, so a
    restart finishes the release and fsck stays clean."""
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    cm.save(0, _state(0), blocking=True)
    cm.save(1, _state(1), blocking=True)
    old1 = cm.lineage.nodes["m/step1"].artifact_ref

    def killed():
        raise OSError("simulated kill before stale release")

    cm._journal_clear = killed
    with pytest.raises(OSError):
        cm.save(1, _state(2), blocking=True)  # re-commit of step 1
    assert os.path.exists(os.path.join(str(tmp_path), "ckpt_journal.json"))

    before = int(CKPT_STATS["journal_rollbacks"])
    cm2 = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    assert int(CKPT_STATS["journal_rollbacks"]) - before == 1
    assert cm2.lineage.nodes["m/step1"].artifact_ref != old1
    restored, step = cm2.restore(template=_state())
    assert step == 1
    for a, b in zip(_leaves(_state(2)), _leaves(restored)):
        assert a.tobytes() == b.tobytes()
    roots = [n.artifact_ref for n in cm2.lineage.nodes.values()
             if n.artifact_ref]
    report = cm2.store.fsck(roots)
    assert report["ok"], report


def test_async_failure_drops_poisoned_pending(tmp_path):
    """A snapshot enqueued while a commit is failing skipped leaves against
    a baseline that never landed; committing it would silently re-reference
    stale parent values. The worker must drop it with the baseline."""
    import threading

    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=True,
                           fingerprint_min_bytes=0, fingerprint_device=False)
    real_commit_step = cm.store.commit_step
    entered, release = threading.Event(), threading.Event()

    def boom(*a, **k):
        entered.set()
        release.wait(5)
        raise RuntimeError("injected commit failure")

    cm.store.commit_step = boom
    s = _state(0)
    cm.save(0, s)
    assert entered.wait(5)
    # identical state: every leaf fingerprint-matches the in-flight
    # snapshot, so the pending item carries only skips (values are None)
    cm.save(1, s)
    assert cm._pending is not None
    release.set()
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        cm.wait()
    assert cm._pending is None  # poisoned snapshot dropped, not committed
    assert cm._last_fps == {} and cm._prev_flat is None
    assert cm._steps() == []

    # the engine heals: the next save re-fingerprints and commits fully
    cm.store.commit_step = real_commit_step
    s2 = _perturb(s, seed=3)
    cm.save(2, s2)
    cm.wait()
    restored, step = cm.restore(template=s2)
    assert step == 2
    for a, b in zip(_leaves(s2), _leaves(restored)):
        assert a.tobytes() == b.tobytes()
    roots = [n.artifact_ref for n in cm.lineage.nodes.values()
             if n.artifact_ref]
    assert cm.store.fsck(roots)["ok"]
    cm.close()


@pytest.mark.parametrize("dtype", ["complex64", "complex128"])
def test_commit_step_odd_itemsize_dtype_roundtrip(tmp_path, dtype):
    """complex128 (itemsize 16) has no native unsigned width: the
    bitpattern path deltas a byte-wise view with nbytes elements, and the
    decode side must size the blob by bytes, not element count (review:
    latent xdelta restore failure)."""
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    rng = np.random.default_rng(0)
    base = (rng.standard_normal((64, 8))
            + 1j * rng.standard_normal((64, 8))).astype(dtype)
    cm.save(0, {"w": base, "step": np.asarray(0, np.int32)}, blocking=True)
    child = base.copy()
    child.flat[::9] += np.asarray(3 + 1j, dtype)
    s1 = {"w": child, "step": np.asarray(1, np.int32)}
    cm.save(1, s1, blocking=True)
    m1 = cm.store.get_manifest(cm.lineage.nodes["m/step1"].artifact_ref)
    assert m1["params"]["w"]["kind"] == "xdelta"
    restored, step = cm.restore(step=1, template=s1)
    assert step == 1
    for a, b in zip(_leaves(s1), _leaves(restored)):
        assert a.tobytes() == b.tobytes()


def test_crash_before_manifest_lands_is_a_noop_recovery(tmp_path):
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    cm.save(1, _state(1), blocking=True)
    # journal with ref=None: crash mid-commit_step, nothing durable yet
    cm._journal_write({"name": "m/step2", "step": 2, "ref": None})
    cm2 = CheckpointManager(str(tmp_path), model_name="m", async_save=False)
    assert cm2.latest_step() == 1
    assert not os.path.exists(os.path.join(str(tmp_path),
                                           "ckpt_journal.json"))


# ---------------------------------------------------------------------------
# elastic restore over chunked manifests (satellite c)
# ---------------------------------------------------------------------------


def test_restore_sharded_chunked_manifest_new_mesh(tmp_path):
    """Large leaves chunk with grids aligned to the TARGET mesh's shard
    cuts, and restore_sharded lays them out per the new mesh's sharding."""
    from repro.dist.sharding import shard_cuts
    n_shards = 4
    store = ArtifactStore(root=str(tmp_path), t_thr=float("inf"),
                          chunk_threshold=64 * 1024, chunk_min=16 * 1024,
                          chunk_avg=32 * 1024, chunk_max=64 * 1024,
                          chunk_shards=n_shards)
    cm = CheckpointManager(str(tmp_path), model_name="m", async_save=False,
                           store=store)
    rng = np.random.default_rng(0)
    big = rng.standard_normal((256, 300)).astype(np.float32)  # ≥ threshold
    s = {"params": {"big": {"w": big}}, "step": np.asarray(0, np.int32)}
    cm.save(0, s, blocking=True)
    s2 = {"params": {"big": {"w": big + np.float32(1e-4)}},
          "step": np.asarray(1, np.int32)}
    cm.save(1, s2, blocking=True)

    for node in ("m/step0", "m/step1"):
        m = cm.store.get_manifest(cm.lineage.nodes[node].artifact_ref)
        e = m["params"]["params/big/w"]
        assert e["kind"] == "chunked" and len(e["chunks"]) > 1
        cuts = set(np.cumsum([int(it["n"]) for it in e["chunks"]]).tolist())
        expected = shard_cuts("params/big/w", big.shape, 4, n_shards)
        # no chunk straddles a boundary of the mesh the restore targets
        assert expected and set(expected) <= cuts

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))
    template = {
        "params": {"big": {"w": jax.ShapeDtypeStruct(
            big.shape, np.float32, sharding=sharding)}},
        "step": jax.ShapeDtypeStruct((), np.int32),
    }
    restored, step = cm.restore_sharded(template)
    assert step == 1
    w = restored["params"]["big"]["w"]
    assert w.sharding.is_equivalent_to(sharding, len(big.shape))
    assert np.asarray(w).tobytes() == s2["params"]["big"]["w"].tobytes()
    assert int(restored["step"]) == 1
