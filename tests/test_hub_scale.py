"""Production-scale hub (DESIGN.md §16): multi-tenant routing, live-traffic
GC with reader leases, read replicas with staleness fallback, worker-pool
backpressure — every fault scenario driven through the deterministic
kill-point harness and closed with the §16 invariant bundle."""

import collections
import http.client
import shutil
import tempfile
import threading
from urllib.parse import urlsplit

import pytest

from repro.core import LineageGraph
from repro.hub import HubService, start_in_thread
from repro.hub.replica import ReplicaHub, ReplicaSetTransport
from repro.remote import HttpTransport, RemoteState, clone, pull, push
from repro.store import ArtifactStore

from harness import (KillPointError, AppTransport, assert_bit_identical,
                     check_service, crash_at, fired)
from helpers import finetune_like, make_chain_model
from hyp_compat import given, settings, st


def _repo(path, **store_kw):
    path = str(path)
    return LineageGraph(path=path, store=ArtifactStore(root=path, **store_kw))


def _seed(path, seed=0, name="m@v1", d=32):
    g = _repo(path)
    g.add_node(make_chain_model(seed=seed, d=d), name)
    return g


@pytest.fixture
def service_hub(tmp_path):
    service = HubService(str(tmp_path / "hub"))
    server, _ = start_in_thread(service)
    yield service, server.url
    server.shutdown()
    server.server_close()


# ---------------------------------------------------------------------------
# Multi-tenant routing over one shared CAS
# ---------------------------------------------------------------------------


def test_multi_tenant_routing_shared_cas_dedup(tmp_path, service_hub):
    service, url = service_hub
    ga = _seed(tmp_path / "a", seed=0)
    rep_a = push(ga, HttpTransport(url + "/r/alpha"),
                 state=RemoteState(ga.path, "origin"))
    # same base bits into a second tenant: the shared CAS dedups the transfer
    gb = _seed(tmp_path / "b", seed=0)
    base = gb.store.load_artifact(gb.nodes["m@v1"].artifact_ref)
    gb.add_node(finetune_like(base, seed=7), "m@v2")
    rep_b = push(gb, HttpTransport(url + "/r/beta"),
                 state=RemoteState(gb.path, "origin"))
    assert rep_a.published and rep_b.published
    # beta re-sent only its finetuned half; the shared base deduped away
    assert rep_b.objects_transferred < rep_b.objects_total

    names = {r["name"] for r in HttpTransport(url).list_repos()}
    assert {"alpha", "beta"} <= names

    # tenants are isolated: alpha never sees beta's lineage
    doc_a = HttpTransport(url + "/r/alpha").fetch_lineage()
    assert {n["name"] for n in doc_a["nodes"]} == {"m@v1"}

    clone(url + "/r/alpha", str(tmp_path / "ca"))
    assert_bit_identical(ga, _repo(tmp_path / "ca"))
    cb = _repo(tmp_path / "cb")
    pull(cb, HttpTransport(url + "/r/beta"))
    assert_bit_identical(gb, cb)
    check_service(service)


def test_token_hub_never_creates_repos_for_bad_tokens(tmp_path):
    service = HubService(str(tmp_path / "hub"), token="sekrit")
    server, _ = start_in_thread(service)
    try:
        bad = HttpTransport(server.url + "/r/newrepo", token="wrong")
        with pytest.raises(PermissionError):
            bad.fetch_lineage_versioned()
        assert "newrepo" not in service.repo_names()
        ok = HttpTransport(server.url + "/r/newrepo", token="sekrit")
        ok.fetch_lineage_versioned()
        assert "newrepo" in service.repo_names()
    finally:
        server.shutdown()
        server.server_close()


def test_delete_repo_then_gc_reclaims_only_its_bytes(tmp_path):
    service = HubService(str(tmp_path / "hub"))
    ga = _seed(tmp_path / "a", seed=0)
    push(ga, AppTransport(service.repo("alpha")),
         state=RemoteState(ga.path, "origin"))
    gb = _seed(tmp_path / "b", seed=99)  # disjoint bits: all beta-private
    push(gb, AppTransport(service.repo("beta")),
         state=RemoteState(gb.path, "origin"))
    check_service(service)

    service.delete_repo("beta")
    assert "beta" not in service.repo_names()
    # published keys graduated out of import grace at finalize, so the
    # deleted repo's privates go candidate -> confirmed in two cycles
    reports = [service.run_gc() for _ in range(3)]
    assert sum(r["reclaimed_bytes"] for r in reports) > 0
    assert any(r["confirmed_orphans"] > 0 for r in reports)

    # alpha unscathed, bit-for-bit
    g2 = _repo(tmp_path / "chk")
    pull(g2, AppTransport(service.repo("alpha")))
    assert_bit_identical(ga, g2)
    # compaction then rewrites the dead pack payload away
    before = service.store.cas.pack_stats()["pack_dead_bytes"]
    report = service.compact()
    assert report["dead_bytes_after"] <= before
    check_service(service, converged=True)


# ---------------------------------------------------------------------------
# Kill-point fault injection: publish, mget, GC, replica sync
# ---------------------------------------------------------------------------


def test_publish_crash_before_commit_point_loses_nothing(tmp_path):
    service = HubService(str(tmp_path / "hub"))
    app = service.repo("alpha")
    g = _seed(tmp_path / "src", seed=3)
    with crash_at("hub.publish.pre_replace"):
        with pytest.raises(KillPointError):
            push(g, AppTransport(app), state=RemoteState(g.path, "origin"))
    payload, _ = app.lineage()
    assert payload is None          # the swap never happened
    rep = push(g, AppTransport(app), state=RemoteState(g.path, "origin"))
    assert rep.published            # resume lands cleanly
    check_service(service)


def test_publish_crash_after_commit_point_is_already_durable(tmp_path):
    service = HubService(str(tmp_path / "hub"))
    app = service.repo("alpha")
    g = _seed(tmp_path / "src", seed=4)
    with crash_at("hub.publish.post_replace"):
        with pytest.raises(KillPointError):
            push(g, AppTransport(app), state=RemoteState(g.path, "origin"))
    payload, _ = app.lineage()
    assert payload is not None      # os.replace is the commit point
    assert {n["name"] for n in payload["nodes"]} == {"m@v1"}
    # the client believed it failed; its retry must converge, not duplicate
    rep = push(g, AppTransport(app), state=RemoteState(g.path, "origin"))
    assert rep.published
    check_service(service)


def test_mget_mid_stream_abort_retried_to_bit_identity(tmp_path, service_hub):
    service, url = service_hub
    g = _repo(tmp_path / "src")
    g.add_node(make_chain_model(seed=0, d=48, n_layers=6), "m@v1")
    push(g, HttpTransport(url + "/r/alpha"),
         state=RemoteState(g.path, "origin"))
    g2 = _repo(tmp_path / "dst")
    with crash_at("hub.mget.record", after=2):
        # the hub aborts the connection mid-pack; the short read rides the
        # client's ordinary retry path and the second attempt is clean
        pull(g2, HttpTransport(url + "/r/alpha", retries=3, backoff=0.01))
    assert fired("hub.mget.record") == 1
    assert_bit_identical(g, g2)
    check_service(service)


def test_gc_crash_before_zeroing_never_loses_objects(tmp_path):
    service = HubService(str(tmp_path / "hub"))
    ga = _seed(tmp_path / "a", seed=0)
    push(ga, AppTransport(service.repo("alpha")),
         state=RemoteState(ga.path, "origin"))
    gb = _seed(tmp_path / "b", seed=99)
    push(gb, AppTransport(service.repo("beta")),
         state=RemoteState(gb.path, "origin"))
    service.delete_repo("beta")
    with crash_at("hub.gc.pre_zero"):
        with pytest.raises(KillPointError):
            service.run_gc()                    # dies holding nothing zeroed
    check_service(service)                      # crash was side-effect free
    total = sum(service.run_gc()["reclaimed_bytes"] for _ in range(4))
    assert total > 0                            # later cycles still converge
    g2 = _repo(tmp_path / "chk")
    pull(g2, AppTransport(service.repo("alpha")))
    assert_bit_identical(ga, g2)
    check_service(service, converged=True)


def test_reader_lease_defers_physical_reclaim(tmp_path):
    service = HubService(str(tmp_path / "hub"))
    ga = _seed(tmp_path / "a", seed=0)
    push(ga, AppTransport(service.repo("alpha")),
         state=RemoteState(ga.path, "origin"))
    gb = _seed(tmp_path / "b", seed=99)
    push(gb, AppTransport(service.repo("beta")),
         state=RemoteState(gb.path, "origin"))
    store = service.store
    beta_only = (set(store.expected_refcounts(service.repo("beta").roots()))
                 - set(store.expected_refcounts(service.repo("alpha").roots())))
    assert beta_only
    service.delete_repo("beta")
    with store.cas.pin():                       # an in-flight reader
        for _ in range(3):
            service.run_gc()
        assert store.cas.deferred_dead_bytes() > 0
        for k in beta_only:                     # logically dead, still readable
            assert store.cas.get_bytes(k)
    assert store.cas.deferred_dead_bytes() == 0  # reclaimed at lease release
    for k in beta_only:
        assert not store.cas.has(k)
    check_service(service, converged=True)


def test_replica_crash_stays_stale_and_clients_fall_back(tmp_path, service_hub):
    service, url = service_hub
    g = _seed(tmp_path / "src", seed=0)
    push(g, HttpTransport(url + "/r/alpha"),
         state=RemoteState(g.path, "origin"))

    replica = ReplicaHub(str(tmp_path / "rep"), url)
    with crash_at("replica.sync.pre_publish"):
        with pytest.raises(KillPointError):
            replica.sync_once()
    rserver, _ = start_in_thread(replica.service)
    try:
        # replica holds objects but no document: stale by etag, so every
        # read falls back to the primary — and stays bit-identical
        rs = ReplicaSetTransport(HttpTransport(url + "/r/alpha"),
                                 [HttpTransport(rserver.url + "/r/alpha")])
        g2 = _repo(tmp_path / "d1")
        pull(g2, rs)
        assert rs.fallbacks > 0 and rs.replica_reads == 0
        assert_bit_identical(g, g2)

        # after a clean sync the replica serves reads (same etag as primary)
        replica.sync_once()
        rs = ReplicaSetTransport(HttpTransport(url + "/r/alpha"),
                                 [HttpTransport(rserver.url + "/r/alpha")])
        g3 = _repo(tmp_path / "d2")
        pull(g3, rs)
        assert rs.replica_reads > 0
        assert_bit_identical(g, g3)
        check_service(replica.service)
        # a client mutation against the replica is refused, not mirrored
        with pytest.raises(Exception):
            HttpTransport(rserver.url + "/r/alpha").publish_lineage(
                {"nodes": []})
    finally:
        rserver.shutdown()
        rserver.server_close()
    check_service(service)


# ---------------------------------------------------------------------------
# Worker pool: bounded concurrency + load shedding
# ---------------------------------------------------------------------------


def test_backpressure_sheds_503_with_retry_after(tmp_path):
    service = HubService(str(tmp_path / "hub"))
    server, _ = start_in_thread(service, max_workers=2, queue_depth=1)
    server.delay_s = 0.2
    host = urlsplit(server.url)
    codes = collections.Counter()
    retry_after = []
    lock = threading.Lock()

    def hit():
        conn = http.client.HTTPConnection(host.hostname, host.port)
        try:
            conn.request("GET", "/api/ping")
            resp = conn.getresponse()
            resp.read()
            with lock:
                codes[resp.status] += 1
                if resp.status == 503:
                    retry_after.append(resp.getheader("Retry-After"))
        finally:
            conn.close()

    try:
        threads = [threading.Thread(target=hit) for _ in range(12)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        server.shutdown()
        server.server_close()
    # 3 slots (2 workers + 1 queued); the other 9 must shed, not queue
    assert codes[200] == 3 and codes[503] == 9, codes
    assert set(retry_after) == {"1"}
    assert service.default.stats["sheds_503"] == 9
    assert service.default.stats["errors_500"] == 0


# ---------------------------------------------------------------------------
# Property test: random op sequences preserve the §16 invariants
# ---------------------------------------------------------------------------

TENANTS = ("alpha", "beta", "gamma")


def _run_op_sequence(ops):
    """Interpret (op, tenant_idx) pairs against a fresh HubService and
    close with the full invariant bundle + per-tenant bit-identity."""
    root = tempfile.mkdtemp(prefix="mgit-hubprop-")
    try:
        service = HubService(root + "/hub")
        mirrors = {}
        version = 0
        for op, idx in ops:
            tenant = TENANTS[idx % len(TENANTS)]
            if op == "push":
                version += 1
                g = mirrors.get(tenant)
                if g is None:
                    g = _seed(f"{root}/{tenant}-{version}", seed=0,
                              name=f"{tenant}@v1")
                    mirrors[tenant] = g
                else:
                    head = sorted(g.nodes)[-1]
                    art = g.store.load_artifact(g.nodes[head].artifact_ref)
                    g.add_node(finetune_like(art, seed=version),
                               f"{tenant}@v{version}")
                push(g, AppTransport(service.repo(tenant)),
                     state=RemoteState(g.path, "origin"))
            elif op == "delete":
                if tenant in service.repo_names():
                    service.delete_repo(tenant)
                mirrors.pop(tenant, None)
            elif op == "gc":
                service.run_gc()
            elif op == "compact":
                service.compact()
        # drain: with no further traffic, a handful of quiescent cycles must
        # reclaim every orphan — check_service then proves full convergence
        for _ in range(4):
            service.run_gc()
        check_service(service, converged=True)
        for tenant, g in mirrors.items():
            g2 = _repo(f"{root}/verify-{tenant}")
            pull(g2, AppTransport(service.repo(tenant)))
            assert_bit_identical(g, g2)
    finally:
        shutil.rmtree(root, ignore_errors=True)


def test_scripted_hub_op_sequence():
    """Deterministic pass through the property interpreter (runs in tier-1
    even where hypothesis is absent): exercises push/delete/gc/compact
    interleavings including post-delete re-creation of a tenant."""
    _run_op_sequence([
        ("push", 0), ("push", 1), ("push", 0), ("gc", 0), ("delete", 1),
        ("gc", 0), ("compact", 0), ("gc", 0), ("gc", 0), ("push", 1),
        ("gc", 0), ("compact", 0), ("push", 2), ("delete", 0), ("gc", 0),
        ("gc", 0), ("gc", 0), ("compact", 0),
    ])


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(["push", "delete", "gc", "compact"]),
    st.integers(min_value=0, max_value=len(TENANTS) - 1)),
    min_size=1, max_size=12))
def test_random_hub_op_sequences_hold_invariants(ops):
    _run_op_sequence(ops)


# ---------------------------------------------------------------------------
# Stress: 64 threads racing GC/compaction over HTTP (tier-2, -m slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_stress_64_threads_racing_gc(tmp_path):
    service = HubService(str(tmp_path / "hub"))
    server, _ = start_in_thread(service, max_workers=16, queue_depth=64)
    stop = threading.Event()
    errors = []

    def maintenance():
        while not stop.is_set():
            try:
                service.run_gc()
                service.compact()
            except Exception as exc:  # pragma: no cover - diagnostic aid
                errors.append(("maintenance", exc))
            stop.wait(0.05)

    def worker(i):
        tenant = TENANTS[i % len(TENANTS)]
        try:
            g = _repo(tmp_path / f"w{i}")
            g.add_node(make_chain_model(seed=i, d=16, n_layers=2),
                       f"w{i}@v1")
            t = HttpTransport(f"{server.url}/r/{tenant}",
                              retries=6, backoff=0.05)
            push(g, t, state=RemoteState(g.path, "origin"))
            g2 = _repo(tmp_path / f"v{i}")
            pull(g2, HttpTransport(f"{server.url}/r/{tenant}",
                                   retries=6, backoff=0.05))
            assert_bit_identical(g, g2, names=[f"w{i}@v1"])
        except Exception as exc:
            errors.append((i, exc))

    gc_thread = threading.Thread(target=maintenance, daemon=True)
    gc_thread.start()
    try:
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(64)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        stop.set()
        gc_thread.join(10)
        server.shutdown()
        server.server_close()
    assert not errors, errors[:3]
    stats = service.default.stats
    assert stats["errors_500"] == 0          # 503s are fine; 500s are not
    for _ in range(4):                       # quiescent drain, then converge
        service.run_gc()
    check_service(service, converged=True)
