"""Storage layer: CAS dedup/refcount/GC, codecs, delta compression chains."""

import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.core import LineageGraph
from repro.store import (CAS, CODECS, ArtifactStore, delta_compression,
                         lcs_param_matching)
from repro.core.lineage import RegisteredTest

from helpers import finetune_like, l2_test, make_chain_model, prune_like


# ---------------------------------------------------------------------------
# CAS
# ---------------------------------------------------------------------------

def test_cas_dedup(tmp_path):
    cas = CAS(str(tmp_path))
    x = np.arange(1000, dtype=np.float32)
    k1 = cas.put_tensor(x)
    k2 = cas.put_tensor(x.copy())
    assert k1 == k2
    assert cas.stats["dedup_hits"] == 1
    assert cas.object_count() == 1
    np.testing.assert_array_equal(cas.get_tensor(k1), x)


def test_cas_refcount_gc(tmp_path):
    cas = CAS(str(tmp_path))
    x = np.ones(100, np.float32)
    k = cas.put_tensor(x)
    cas.put_tensor(x)          # refcount 2
    cas.decref(k)
    assert cas.gc() == 0       # still referenced
    cas.decref(k)
    assert cas.gc() > 0
    assert not cas.has(k)


def test_cas_memory_backend():
    cas = CAS(None)
    k = cas.put_bytes(b"hello")
    assert cas.get_bytes(k) == b"hello"
    assert cas.physical_bytes() == 5


# ---------------------------------------------------------------------------
# codecs (hypothesis roundtrips)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", sorted(CODECS))
@given(data=st.lists(st.integers(-2**31, 2**31 - 1), max_size=200),
       runs=st.lists(st.tuples(st.integers(-5, 5), st.integers(1, 50)),
                     max_size=20))
@settings(max_examples=25, deadline=None)
def test_codec_roundtrip(codec, data, runs):
    arr = np.array(data + [v for v, n in runs for _ in range(n)],
                   dtype=np.int32)
    c = CODECS[codec]
    out = c.decode(c.encode(arr), arr.size)
    np.testing.assert_array_equal(out, arr)


def test_codecs_compress_sparse_runs():
    arr = np.zeros(100000, np.int32)
    arr[::997] = 3
    for name in ("rle", "lzma", "zlib", "sparse"):
        assert len(CODECS[name].encode(arr)) < arr.nbytes / 5, name


# ---------------------------------------------------------------------------
# LCS parameter matching
# ---------------------------------------------------------------------------

def test_lcs_identical_architectures():
    a = make_chain_model(seed=0)
    b = make_chain_model(seed=1)
    pairs = lcs_param_matching(a, b)
    assert pairs == [(k, k) for k, _ in pairs]
    assert len(pairs) == len(a.params)


def test_lcs_differing_architectures():
    a = make_chain_model(seed=0, n_layers=4)
    b = make_chain_model(seed=1, n_layers=6)  # two extra layers
    pairs = lcs_param_matching(a, b)
    assert len(pairs) == len(a.params)  # all of a's params matched
    assert all(np.shape(a.params[p]) == np.shape(b.params[c])
               for p, c in pairs)


# ---------------------------------------------------------------------------
# delta compression (Algorithm 1)
# ---------------------------------------------------------------------------

@given(scale=st.floats(1e-6, 1e-4), density=st.floats(0.0, 0.5),
       seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_delta_error_bound_property(scale, density, seed):
    """Reconstruction error is bounded by the quantization step (~eps)."""
    parent = make_chain_model(seed=0)
    child = finetune_like(parent, seed=seed, scale=scale, density=density)
    res = delta_compression(child, parent, eps=1e-4, codec="zlib")
    for k in child.params:
        err = np.max(np.abs(res.reconstructed.params[k] - child.params[k]))
        assert err <= 2 * np.log1p(1e-4)  # one quantization step


def test_delta_rejected_for_unrelated():
    parent = make_chain_model(seed=0)
    child = make_chain_model(seed=99)  # totally different values
    res = delta_compression(child, parent, codec="lzma", per_param=True)
    # dense large deltas shouldn't beat raw storage meaningfully
    assert res.ratio < 2.0


def test_delta_accuracy_gate():
    parent = make_chain_model(seed=0)
    child = finetune_like(parent, seed=1)
    tests = [RegisteredTest(name="l2", fn=l2_test, model_type="toy")]
    res = delta_compression(child, parent, t_thr=0.0, eps=0.5,  # huge eps
                            codec="lzma", tests=tests)
    assert not res.accepted  # big eps wrecks the test score -> rejected


def test_delta_whole_model_mode():
    parent = make_chain_model(seed=0)
    child = finetune_like(parent, seed=1)
    res = delta_compression(child, parent, per_param=False, codec="lzma")
    assert res.accepted
    assert res.ratio > 3


# ---------------------------------------------------------------------------
# ArtifactStore: dedup + recursive chains + GC
# ---------------------------------------------------------------------------

def test_store_dedup_identical_models(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    m = make_chain_model(seed=0, d=128)
    store.commit_artifact("a", m)
    twin = make_chain_model(seed=0, d=128)
    store.commit_artifact("b", twin)
    assert store.compression_ratio() > 1.9  # second copy ~free


def test_store_delta_chain_roundtrip(tmp_path):
    store = ArtifactStore(root=str(tmp_path), codec="lzma")
    g = LineageGraph(path=str(tmp_path), store=store)
    m = make_chain_model(seed=0, d=64)
    g.add_node(m, "v1")
    cur = m
    prev = "v1"
    for v in range(2, 6):  # chain of 4 deltas
        cur = finetune_like(cur, seed=v)
        name = f"v{v}"
        g.add_node(None, name, model_type="toy")
        g.add_version_edge(prev, name)
        g._attach_artifact(g.nodes[name], cur)
        prev = name
    loaded = g.get_model("v5")
    for k in cur.params:
        assert np.max(np.abs(loaded.params[k] - cur.params[k])) < 5 * 1e-4
    assert store.compression_ratio() > 2.5


def test_store_chain_depth_cap(tmp_path):
    store = ArtifactStore(root=str(tmp_path), max_chain_depth=2)
    g = LineageGraph(path=str(tmp_path), store=store)
    m = make_chain_model(seed=0)
    g.add_node(m, "v1")
    prev, cur = "v1", m
    for v in range(2, 6):
        cur = finetune_like(cur, seed=v)
        name = f"v{v}"
        g.add_node(None, name, model_type="toy")
        g.add_version_edge(prev, name)
        g._attach_artifact(g.nodes[name], cur)
        prev = name
    depths = [store.get_manifest(g.nodes[f"v{v}"].artifact_ref)["depth"]
              for v in range(1, 6)]
    assert max(depths) <= 2


def test_store_release_and_gc(tmp_path):
    store = ArtifactStore(root=str(tmp_path))
    g = LineageGraph(path=str(tmp_path), store=store)
    g.add_node(make_chain_model(seed=0), "a")
    g.add_node(make_chain_model(seed=123), "b")
    before = store.cas.object_count()
    g.remove_node("b")
    store.gc()
    assert store.cas.object_count() < before
    # "a" still loads
    assert g.get_model("a").params["L0/w"].shape == (16, 16)


def test_pruned_models_preserve_sparsity(tmp_path):
    """G4 regime: quantize-then-delta must keep zeros exactly zero."""
    dense = make_chain_model(seed=0)
    pruned = prune_like(dense, sparsity=0.6)
    res = delta_compression(pruned, dense, codec="lzma", eps=1e-4)
    for k in pruned.params:
        rec = res.reconstructed.params[k]
        orig_zero = pruned.params[k] == 0
        # reconstruction of zeros stays within one quant step of zero
        assert np.max(np.abs(rec[orig_zero])) <= 2 * np.log1p(1e-4)
