"""Diagnostics engine (paper §4; DESIGN.md §9): memoized runner, result
ledger round-trips, blame attribution, test transfer, gate + quarantine."""

import json

import numpy as np
import pytest

from repro.core import LineageGraph
from repro.diag import (DiagnosticsRunner, TestGate, blame, gate_report,
                        is_quarantined, release_node, scoped_content_key,
                        transferable_tests)
from repro.diag import test_identity_hash as identity_hash_of
from repro.store import ArtifactStore
from repro.store.cas import ledger_key

from helpers import finetune_like, l2_test, make_chain_model


def broken_flag_test(model) -> float:
    """Metadata-driven verdict (round-trips storage bit-exactly, unlike a
    NaN poison, which delta quantization can smooth away)."""
    return float("nan") if model.metadata.get("broken") else 1.0


@pytest.fixture
def chain_repo(tmp_path):
    """3-level provenance chain base -> mid -> leaf, store-backed."""
    g = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    base = make_chain_model(seed=0)
    g.add_node(base, "base")
    g.add_edge("base", "mid")
    g.add_node(finetune_like(base, seed=1), "mid")
    g.add_edge("mid", "leaf")
    g.add_node(finetune_like(g.get_model("mid"), seed=2), "leaf")
    g.register_test_function(l2_test, "probe/l2", mt="toy")
    return g


# ---------------------------------------------------------------------------
# Memoized runner + ledger
# ---------------------------------------------------------------------------


def test_cold_run_executes_then_memoizes(chain_repo):
    g = chain_repo
    cold = DiagnosticsRunner(g).run()
    assert cold.executed == 3 and cold.memo_hits == 0
    assert set(cold.values()) == {"base", "mid", "leaf"}
    warm = DiagnosticsRunner(g).run()   # fresh runner: hits from the store
    assert warm.executed == 0 and warm.memo_hits == 3
    assert warm.cache_hit_ratio == 1.0
    assert cold.values() == warm.values()


def test_memo_hit_performs_zero_materializations(chain_repo):
    """Acceptance: re-testing an unchanged model touches no tensor data."""
    g = chain_repo
    DiagnosticsRunner(g).run()
    g.store.reset_io_stats()
    g.store.cache.clear()               # even a cold tensor cache stays cold
    report = DiagnosticsRunner(g).run()
    assert report.executed == 0
    assert g.store.io_stats["tensors_materialized"] == 0
    assert g.store.io_stats["plans_resolved"] == 0


def test_ledger_round_trips_through_store(chain_repo, tmp_path):
    """Acceptance: results persist in the CAS and survive a full reopen."""
    g = chain_repo
    first = DiagnosticsRunner(g).run()
    # a fresh graph + store object: only disk state is shared
    g2 = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    g2.register_test_function(l2_test, "probe/l2", mt="toy")
    g2.store.reset_io_stats()
    again = DiagnosticsRunner(g2).run()
    assert again.executed == 0 and again.memo_hits == 3
    assert g2.store.io_stats["tensors_materialized"] == 0
    assert again.values() == first.values()


def test_ledger_entries_survive_fsck(chain_repo):
    g = chain_repo
    DiagnosticsRunner(g).run()
    roots = [n.artifact_ref for n in g.nodes.values() if n.artifact_ref]
    report = g.store.fsck(roots)
    assert report["ok"], report
    t_keys = [k for k in g.store.cas.keys() if k.startswith("t_")]
    assert len(t_keys) == 3


def test_changing_the_test_invalidates_results(chain_repo):
    g = chain_repo
    DiagnosticsRunner(g).run()

    def l2_shifted(model):
        return l2_test(model) + 1.0

    g.tests[0].fn = l2_shifted          # same name, different behavior
    rerun = DiagnosticsRunner(g).run()
    assert rerun.executed == 3 and rerun.memo_hits == 0


def test_failures_are_memoized_too(chain_repo):
    g = chain_repo

    def boom(model):
        raise RuntimeError("bad probe")

    g.register_test_function(boom, "probe/boom", mt="toy")
    r1 = DiagnosticsRunner(g).run(pattern="boom")
    fails = r1.failures()
    assert len(fails) == 3 and all("bad probe" in f.error for f in fails)
    r2 = DiagnosticsRunner(g).run(pattern="boom")
    assert r2.executed == 0 and all(not f.passed for f in r2.failures())


def test_run_pattern_modes(chain_repo):
    g = chain_repo
    g.register_test_function(lambda m: 1.0, "acc/top1", mt="toy")
    glob_hits = DiagnosticsRunner(g).run(pattern="acc*", match="glob")
    assert all(set(v) == {"acc/top1"} for v in glob_hits.results.values())
    rx_hits = DiagnosticsRunner(g).run(pattern=r"probe/.*")
    assert all(set(v) == {"probe/l2"} for v in rx_hits.results.values())


def test_ledger_key_scheme_is_deterministic(chain_repo):
    g = chain_repo
    t = g.tests[0]
    th = identity_hash_of(t)
    node = g.nodes["base"]
    key = ledger_key(th, node.artifact_ref)
    DiagnosticsRunner(g).run()
    assert g.store.cas.has(key)
    record = json.loads(g.store.cas.get_bytes(key))
    assert record["node"] == "base" and record["passed"] is True


# ---------------------------------------------------------------------------
# Blame (DAG-wide regression attribution)
# ---------------------------------------------------------------------------


def _poisoned_repo(tmp_path, poison_at: str):
    """base -> mid -> leaf with metadata 'broken' injected at one level
    (inherited by derivation, like a real upstream bug)."""
    g = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    base = make_chain_model(seed=0)
    if poison_at == "base":
        base.metadata["broken"] = True
    g.add_node(base, "base")
    mid = finetune_like(base, seed=1)
    mid.metadata.update(base.metadata)
    if poison_at == "mid":
        mid.metadata["broken"] = True
    g.add_edge("base", "mid")
    g.add_node(mid, "mid")
    leaf = finetune_like(mid, seed=2)
    leaf.metadata.update(mid.metadata)
    g.add_edge("mid", "leaf")
    g.add_node(leaf, "leaf")
    g.register_test_function(broken_flag_test, "probe/flag", mt="toy")
    return g


def test_blame_attributes_upstream_regression_as_inherited(tmp_path):
    """Acceptance: injected upstream regression -> introduced at the
    ancestor, inherited in ALL descendants."""
    g = _poisoned_repo(tmp_path, poison_at="base")
    report = blame(g, "leaf", "probe/flag")
    assert report.entries["base"].status == "introduced"
    assert report.entries["mid"].status == "inherited"
    assert report.entries["mid"].inherited_from == ["base"]
    assert report.entries["leaf"].status == "inherited"
    assert report.entries["leaf"].inherited_from == ["mid"]
    assert report.frontier == ["base"]
    # blame of the middle node agrees
    assert blame(g, "mid", "probe/flag").entries["mid"].status == "inherited"


def test_blame_mid_chain_introduction(tmp_path):
    g = _poisoned_repo(tmp_path, poison_at="mid")
    report = blame(g, "leaf", "probe/flag")
    assert report.entries["base"].status == "pass"
    assert report.entries["mid"].status == "introduced"
    assert report.entries["leaf"].status == "inherited"
    assert report.frontier == ["mid"]


def test_blame_emergent_from_merge(tmp_path):
    g = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    p1 = make_chain_model(seed=3)
    p2 = finetune_like(p1, seed=4)
    g.add_node(p1, "p1")
    g.add_node(p2, "p2")
    merged = finetune_like(p1, seed=5)
    merged.metadata["broken"] = True    # the combination is at fault
    g.add_node(merged, "merged")
    g.add_edge("p1", "merged")
    g.add_edge("p2", "merged")
    g.register_test_function(broken_flag_test, "probe/flag", mt="toy")
    report = blame(g, "merged", "probe/flag")
    assert report.entries["merged"].status == "emergent"
    assert report.frontier == ["merged"]


def test_blame_walks_version_edges(tmp_path):
    g = _poisoned_repo(tmp_path, poison_at="base")
    v2 = finetune_like(g.get_model("leaf"), seed=9)
    v2.metadata["broken"] = True
    g.add_node(v2, "leaf@v2")
    g.add_version_edge("leaf", "leaf@v2")
    report = blame(g, "leaf@v2", "probe/flag")
    assert report.entries["leaf@v2"].status == "inherited"
    assert "leaf" in report.entries["leaf@v2"].inherited_from
    assert report.frontier == ["base"]


def test_blame_is_memoized(chain_repo):
    g = chain_repo
    runner = DiagnosticsRunner(g)
    runner.run()
    executed_before = runner.stats["executed"]
    report = blame(g, "leaf", "probe/l2", runner=runner)
    assert runner.stats["executed"] == executed_before  # zero new executions
    assert report.status == "pass"


# ---------------------------------------------------------------------------
# Diff-adapted transfer + scoped skipping
# ---------------------------------------------------------------------------


def test_scoped_test_skips_rerun_when_submodule_unchanged(tmp_path):
    g = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    base = make_chain_model(seed=0)
    g.add_node(base, "m@v1")
    # trunk-only update built FROM THE STORED truth: head bits unchanged
    stored = g.store.load_artifact(g.nodes["m@v1"].artifact_ref, lazy=False)
    v2 = finetune_like(stored, seed=1).replace_params(
        {"head/w": stored.params["head/w"]})
    g.add_node(v2, "m@v2")
    g.add_version_edge("m@v1", "m@v2")

    assert scoped_content_key(g.nodes["m@v1"], "head") == \
        scoped_content_key(g.nodes["m@v2"], "head")
    # boundary safety: "hea" is not a layer-path prefix of "head/w"
    assert scoped_content_key(g.nodes["m@v1"], "hea") is None

    g.register_test_function(
        lambda m: float(np.linalg.norm(np.asarray(m.params["head/w"]))),
        "probe/head", mt="toy", scope="head")
    report = DiagnosticsRunner(g).run()
    assert report.executed == 1 and report.memo_hits == 1  # one shared entry
    vals = report.values()
    assert vals["m@v1"]["probe/head"] == vals["m@v2"]["probe/head"]


def test_structural_transfer_runs_type_bound_test_on_matching_derivative(tmp_path):
    g = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    a = make_chain_model(seed=0, model_type="typeA")
    b = finetune_like(a, seed=1)
    b.model_type = "typeB"              # same structure, different family tag
    g.add_node(a, "a")
    g.add_node(b, "b", model_type="typeB")
    g.register_test_function(l2_test, "probe/l2", mt="typeA")

    assert [t.name for t in transferable_tests(g, g.nodes["b"])] == ["probe/l2"]

    plain = DiagnosticsRunner(g).run()
    assert set(plain.results) == {"a"}  # no transfer: typeB not covered
    xfer = DiagnosticsRunner(g, transfer=True).run()
    assert set(xfer.results) == {"a", "b"}
    assert xfer.results["b"]["probe/l2"].transferred


def test_structural_transfer_rejects_different_architecture(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    a = make_chain_model(seed=0, n_layers=4, model_type="typeA")
    c = make_chain_model(seed=2, n_layers=2, model_type="typeC")
    g.add_node(a, "a")
    g.add_node(c, "c", model_type="typeC")
    g.register_test_function(l2_test, "probe/l2", mt="typeA")
    assert transferable_tests(g, g.nodes["c"]) == []


# ---------------------------------------------------------------------------
# Gate + quarantine
# ---------------------------------------------------------------------------


def test_gate_quarantines_new_failure_and_report_lists_it(tmp_path):
    g = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    m1 = make_chain_model(seed=0)
    g.add_node(m1, "m@v1")
    bad = finetune_like(m1, seed=1)
    bad.metadata["broken"] = True
    g.add_node(bad, "m@v2")
    g.add_version_edge("m@v1", "m@v2")
    g.register_test_function(broken_flag_test, "probe/flag", mt="toy")

    gate = TestGate(graph=g)
    decision = gate.apply("m@v2")
    assert not decision.passed and decision.quarantined
    assert decision.regressions[0].kind == "new_failure"
    assert is_quarantined(g.nodes["m@v2"])
    assert g.nodes["m@v2"].artifact_ref is not None          # artifact kept
    assert g.nodes["m@v1"].version_children == ["m@v2"]      # edge kept
    report = gate_report(g)
    assert [r["node"] for r in report] == ["m@v2"]

    release_node(g, "m@v2")
    assert not is_quarantined(g.nodes["m@v2"]) and gate_report(g) == []


def test_gate_metric_drop_and_tolerance(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    m1 = make_chain_model(seed=0)
    m1.metadata["score"] = 0.9
    g.add_node(m1, "m@v1")
    m2 = finetune_like(m1, seed=1)
    m2.metadata["score"] = 0.85
    g.add_node(m2, "m@v2")
    g.add_version_edge("m@v1", "m@v2")
    g.register_test_function(lambda m: float(m.metadata["score"]),
                             "probe/score", mt="toy")

    strict = TestGate(graph=g, tol=0.0, quarantine=False)
    assert not strict.check("m@v2").passed
    assert strict.check("m@v2").regressions[0].kind == "metric_drop"
    lenient = TestGate(graph=g, tol=0.1, quarantine=False)
    assert lenient.check("m@v2").passed


def test_gate_inherited_failure_is_not_a_regression(tmp_path):
    g = LineageGraph(path=str(tmp_path))
    m1 = make_chain_model(seed=0)
    m1.metadata["broken"] = True
    g.add_node(m1, "m@v1")
    m2 = finetune_like(m1, seed=1)
    m2.metadata["broken"] = True        # still failing, but no worse
    g.add_node(m2, "m@v2")
    g.add_version_edge("m@v1", "m@v2")
    g.register_test_function(broken_flag_test, "probe/flag", mt="toy")
    assert TestGate(graph=g).check("m@v2").passed


def test_push_excludes_quarantined_nodes(tmp_path):
    from repro import remote as rm
    src = tmp_path / "src"
    g = LineageGraph(path=str(src), store=ArtifactStore(root=str(src)))
    base = make_chain_model(seed=0)
    g.add_node(base, "good")
    bad = finetune_like(base, seed=1)
    g.add_edge("good", "bad")
    g.add_node(bad, "bad")
    from repro.diag import quarantine_node
    quarantine_node(g, "bad", reason="manual")

    remote_dir = str(tmp_path / "remote")
    report = rm.push(g, rm.LocalTransport(remote_dir))
    assert report.quarantined_skipped == ["bad"]
    assert "bad" not in report.selected_nodes

    dest = str(tmp_path / "clone")
    rm.clone(remote_dir, dest)
    g2 = LineageGraph(path=dest, store=ArtifactStore(root=dest))
    assert "good" in g2.nodes and "bad" not in g2.nodes
    assert g2.store.fsck([n.artifact_ref for n in g2.nodes.values()
                          if n.artifact_ref])["ok"]

    report2 = rm.push(g, rm.LocalTransport(remote_dir),
                      include_quarantined=True)
    assert report2.quarantined_skipped == []
    assert "bad" in report2.selected_nodes


def test_quarantine_after_push_does_not_delete_from_remote(tmp_path):
    """A node pushed earlier then quarantined must read as out-of-scope on
    the next push, NOT as a local deletion of the remote's copy."""
    from repro import remote as rm
    from repro.diag import quarantine_node
    src = str(tmp_path / "src")
    g = LineageGraph(path=src, store=ArtifactStore(root=src))
    base = make_chain_model(seed=0)
    g.add_node(base, "good")
    g.add_edge("good", "bad")
    g.add_node(finetune_like(base, seed=1), "bad")

    remote_dir = str(tmp_path / "remote")
    transport = rm.LocalTransport(remote_dir)
    state = rm.RemoteState(src, "origin")
    rm.remote_add(src, "origin", remote_dir)
    first = rm.push(g, transport, state=state)
    assert set(first.selected_nodes) == {"good", "bad"}

    quarantine_node(g, "bad", reason="regression found post-push")
    second = rm.push(g, transport, state=state)
    assert second.quarantined_skipped == ["bad"]
    remote_nodes = {n["name"] for n in transport.fetch_lineage()["nodes"]}
    assert remote_nodes == {"good", "bad"}  # remote copy preserved
    # and a third push (base advanced) still preserves it
    third = rm.push(g, transport, state=state)
    assert {n["name"] for n in transport.fetch_lineage()["nodes"]} \
        == {"good", "bad"}
    assert third.published


def test_identity_hash_stable_across_recompilation():
    """Functions containing comprehensions/lambdas must hash identically
    when the same source is compiled twice (simulating a process restart) —
    repr of nested code objects embeds memory addresses."""
    from repro.core.lineage import RegisteredTest
    src = ("def probe(m):\n"
           "    return sum(v for v in [1.0, 2.0]) + (lambda x: x)(0.0)\n")
    ns1, ns2 = {}, {}
    exec(src, ns1)
    exec(src, ns2)
    h1 = identity_hash_of(RegisteredTest(name="p", fn=ns1["probe"]))
    h2 = identity_hash_of(RegisteredTest(name="p", fn=ns2["probe"]))
    assert ns1["probe"].__code__ is not ns2["probe"].__code__
    assert h1 == h2


def test_force_rerun_re_records_the_ledger(tmp_path):
    """--force semantics: a forced execution supersedes the stored entry,
    so later plain runs (fresh processes) see the new value."""
    g = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    g.add_node(make_chain_model(seed=0), "m")
    state = {"v": 1.0}
    g.register_test_function(lambda m: state["v"], "probe/ambient", mt="toy")
    first = DiagnosticsRunner(g).run()
    assert first.values()["m"]["probe/ambient"] == 1.0
    state["v"] = 2.0    # ambient change: same test hash, new behavior
    forced = DiagnosticsRunner(g).run(force=True)
    assert forced.values()["m"]["probe/ambient"] == 2.0
    # a completely fresh graph+store sees the superseded record
    g2 = LineageGraph(path=str(tmp_path), store=ArtifactStore(root=str(tmp_path)))
    g2.register_test_function(lambda m: state["v"], "probe/ambient", mt="toy")
    again = DiagnosticsRunner(g2).run()
    assert again.executed == 0
    assert again.values()["m"]["probe/ambient"] == 2.0
    roots = [n.artifact_ref for n in g2.nodes.values() if n.artifact_ref]
    assert g2.store.fsck(roots)["ok"]
