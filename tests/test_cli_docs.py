"""docs/cli.md is generated from the argparse tree and must not drift."""

import os
import subprocess
import sys

from repro.cli import build_parser, dump_docs

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS_PATH = os.path.join(REPO_ROOT, "docs", "cli.md")


def test_cli_docs_match_argparse_tree():
    generated = dump_docs(build_parser()) + "\n"
    with open(DOCS_PATH) as f:
        committed = f.read()
    assert committed == generated, (
        "docs/cli.md has drifted from the argparse tree — regenerate with:"
        "  PYTHONPATH=src python -m repro.cli --dump-docs > docs/cli.md")


def test_dump_docs_flag_prints_reference():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO_ROOT, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.cli", "--dump-docs"],
        capture_output=True, text=True, env=env, check=True)
    assert out.stdout.startswith("# mgit — CLI reference")
    # deterministic across invocations (no terminal-width dependence)
    assert out.stdout == dump_docs(build_parser()) + "\n"


def test_docs_reference_every_command():
    generated = dump_docs(build_parser())
    for command in ("log", "show", "diff", "test", "param", "checkout",
                    "stats", "gc", "remote", "push", "pull", "clone",
                    "fsck", "diag", "hub"):
        assert f"mgit {command}" in generated, f"{command} missing from docs"
