"""Training substrates: microbatch equivalence, grad compression, straggler
mitigation, heartbeats, sharding rules, data pipeline determinism."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.data import SyntheticPipeline
from repro.dist import compression, param_spec
from repro.ft import StepTimer, StragglerPolicy, Watchdog
from repro.models.config import ModelConfig
from repro.train.step import init_state, make_train_step

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                  head_dim=16, dtype="float32", attn_chunk=16, remat="none")


def test_microbatch_equivalence():
    state1 = init_state(CFG, 0)
    state2 = init_state(CFG, 0)
    batch = SyntheticPipeline(CFG, batch=8, seq=16).host_batch(0)
    s1, m1 = jax.jit(make_train_step(CFG))(state1, batch)
    s2, m2 = jax.jit(make_train_step(CFG, n_microbatches=4))(state2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_loss_decreases_over_steps():
    state = init_state(CFG, 0)
    step = jax.jit(make_train_step(CFG))
    pipe = SyntheticPipeline(CFG, batch=8, seq=16)
    batch = pipe.host_batch(0)  # overfit one batch
    losses = []
    # 30 steps: the default schedule is still in warmup, so the early lr is
    # tiny — 20 steps sits right on the 0.1 decision boundary.
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = compression.init_error_state(g_true)
    acc_deq = jnp.zeros_like(g_true)
    n = 50
    for _ in range(n):
        deq, err = compression.compress_gradients(g_true, err)
        acc_deq = acc_deq + deq
    # error feedback: the long-run average converges to the true gradient
    np.testing.assert_allclose(np.asarray(acc_deq / n), np.asarray(g_true),
                               atol=1e-3)


def test_compression_wire_bytes():
    g = {"a": jnp.zeros((1000,)), "b": jnp.zeros((10, 10))}
    assert compression.compressed_bytes(g) == 1000 + 100 + 8


def test_train_step_with_compression_runs():
    state = init_state(CFG, 0, compress_grads=True)
    batch = SyntheticPipeline(CFG, batch=4, seq=16).host_batch(0)
    step = jax.jit(make_train_step(CFG, compress_grads=True))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert "err" in state


# ---------------------------------------------------------------------------
# straggler mitigation + watchdog
# ---------------------------------------------------------------------------

def test_step_timer_flags_stragglers():
    timer = StepTimer(threshold=2.0, warmup=3)
    for i in range(10):
        assert timer.record(i, 0.1) is None
    ev = timer.record(11, 0.5)  # 5x slower
    assert ev is not None and ev.ratio > 2
    # anomaly must not pollute the mean
    assert timer.mean == pytest.approx(0.1, rel=0.2)


def test_straggler_policy_escalates():
    actions = {"rebalanced": 0, "evicted": 0}
    pol = StragglerPolicy(
        rebalance_fn=lambda e: actions.__setitem__("rebalanced", actions["rebalanced"] + 1),
        evict_fn=lambda e: actions.__setitem__("evicted", actions["evicted"] + 1),
        rebalance_after=2, evict_after=4)
    timer = StepTimer(threshold=1.5, warmup=0)
    timer.record(0, 0.1)
    seq = []
    for i in range(1, 6):
        ev = timer.record(i, 1.0)
        assert ev is not None
        seq.append(pol.on_event(ev))
    assert seq[0] == "log"
    assert "rebalance" in seq and seq[-1] == "evict"
    assert actions["evicted"] >= 1


def test_watchdog_detects_stale_peer(tmp_path):
    w1 = Watchdog(str(tmp_path), "host0", interval=0.05, stale_after=0.2)
    w2 = Watchdog(str(tmp_path), "host1", interval=0.05, stale_after=0.2)
    w1.start()
    w2.beat()          # host1 beats once, then "hangs"
    time.sleep(0.4)
    stale = w1.stale_peers()
    w1.stop()
    assert "host1" in stale


# ---------------------------------------------------------------------------
# sharding rules + data pipeline
# ---------------------------------------------------------------------------

def test_param_spec_rules():
    assert param_spec("layers/attn/wq", 3) == P(None, "data", "model")
    assert param_spec("layers/moe/w_in", 4) == P(None, "model", "data", None)
    assert param_spec("embed/tok", 2) == P("model", "data")
    assert param_spec("layers/ln1", 2) == P(None, None)
    assert param_spec("lm_head", 2) == P("data", "model")


def test_pipeline_determinism_and_resume():
    pipe1 = SyntheticPipeline(CFG, batch=4, seq=16, seed=7)
    b0 = pipe1.host_batch(0)
    b5 = pipe1.host_batch(5)
    pipe2 = SyntheticPipeline(CFG, batch=4, seq=16, seed=7)
    pipe2.restore({"step": 5, "seed": 7})
    np.testing.assert_array_equal(next(pipe2)["tokens"], b5["tokens"])
    np.testing.assert_array_equal(pipe1.host_batch(0)["tokens"], b0["tokens"])
    # different seeds differ
    pipe3 = SyntheticPipeline(CFG, batch=4, seq=16, seed=8)
    assert not np.array_equal(pipe3.host_batch(0)["tokens"], b0["tokens"])
