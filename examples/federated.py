"""Federated-learning controller using MGit lineage (paper §2, graph G3).

Each round: sample clients, train locally on disjoint data shards, average
into a new global model. Every client model and every global round is a
lineage node; the whole history is stored delta-compressed.

    PYTHONPATH=src python examples/federated.py [--rounds 3] [--clients 4]
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.core import LineageGraph, ModelArtifact
from repro.data import SyntheticPipeline
from repro.models import get_config, init_params
from repro.optim import adamw
from repro.store import ArtifactStore
from repro.store.checkpoint import flatten_state, state_graph, unflatten_state
from repro.train.step import make_train_step


def local_train(cfg, params, seed, steps=8):
    state = {"params": params, "opt": adamw.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(make_train_step(cfg))
    pipe = SyntheticPipeline(cfg, batch=4, seq=32, seed=seed)  # client shard
    for i in range(steps):
        state, metrics = step_fn(state, pipe.host_batch(i))
    return state["params"], float(metrics["loss"])


def fed_average(params_list):
    return jax.tree_util.tree_map(
        lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs), *params_list)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--sample", type=int, default=3, help="clients per round")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("paper-bert-small").reduced(),
                              remat="none")
    tmp = tempfile.mkdtemp(prefix="mgit-fl-")
    store = ArtifactStore(root=tmp, codec="lzma")
    g = LineageGraph(path=tmp, store=store)

    def to_artifact(params):
        flat = flatten_state(params)
        return ModelArtifact(state_graph(flat, cfg.name), flat,
                             model_type=cfg.name)

    global_params = init_params(cfg, 0)
    g.add_node(to_artifact(global_params), "global_r0")

    for r in range(1, args.rounds + 1):
        sampled = [(r * 7 + c) % args.clients for c in range(args.sample)]
        print(f"round {r}: clients {sorted(set(sampled))}")
        locals_ = []
        for c in sorted(set(sampled)):
            params, loss = local_train(cfg, global_params, seed=1000 * r + c)
            name = f"client{c}_r{r}"
            # controller registers each client model in the lineage graph
            g.add_edge(f"global_r{r - 1}", name)
            g.add_node(to_artifact(params), name)
            locals_.append(params)
            print(f"  {name}: loss={loss:.3f}")
        global_params = fed_average(locals_)
        gname = f"global_r{r}"
        for c in sorted(set(sampled)):
            g.add_edge(f"client{c}_r{r}", gname)
        g.add_node(to_artifact(global_params), gname)

    s = store.stats()
    print(f"\n{len(g)} models stored, ratio={s['compression_ratio']:.2f}x "
          f"({s['logical_bytes']/1e6:.0f}MB → {s['physical_bytes']/1e6:.0f}MB)")
    print("\nlineage graph:")
    print(g.log())


if __name__ == "__main__":
    main()
