"""Quickstart: build a lineage graph, store it compressed, diff/test/merge.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.core import (LayerGraph, LayerNode, LineageGraph, ModelArtifact,
                        bfs, divergence_scores, merge, module_diff)
from repro.store import ArtifactStore


def make_model(seed, d=128, n_layers=6):
    rng = np.random.default_rng(seed)
    layers, params = [], {}
    for i in range(n_layers):
        layers.append(LayerNode(f"block{i}", "linear",
                                params={"w": ((d, d), "float32")}))
        params[f"block{i}/w"] = rng.normal(size=(d, d)).astype(np.float32)
    layers.append(LayerNode("head", "linear", params={"w": ((d, 10), "float32")}))
    params["head/w"] = rng.normal(size=(d, 10)).astype(np.float32)
    return ModelArtifact(LayerGraph.chain(layers), params, model_type="demo")


def finetune(m, seed, scale=1e-4):
    rng = np.random.default_rng(seed)
    return m.map_params(lambda k, v: (v + rng.normal(scale=scale, size=v.shape)
                                      * (rng.random(v.shape) < 0.2)).astype(v.dtype))


def main():
    tmp = tempfile.mkdtemp(prefix="mgit-demo-")
    store = ArtifactStore(root=tmp, codec="lzma")
    g = LineageGraph(path=tmp, store=store)

    # 1. a pretrained root and two finetuned children
    base = make_model(seed=0)
    g.add_node(base, "base")
    for i in range(2):
        g.add_edge("base", f"task{i}")          # provenance first…
        g.add_node(finetune(base, seed=10 + i), f"task{i}")  # …then content

    # 2. storage: children are delta-compressed against the root
    s = store.stats()
    print(f"storage: logical={s['logical_bytes']/1e6:.1f}MB "
          f"physical={s['physical_bytes']/1e6:.1f}MB "
          f"ratio={s['compression_ratio']:.2f}x")

    # 3. diff / divergence (structural: same architecture; contextual: every
    #    finetuned tensor differs)
    d = module_diff(g.get_model("base"), g.get_model("task0"), mode="structural")
    dc = module_diff(g.get_model("base"), g.get_model("task0"), mode="contextual")
    print(f"diff(base, task0): structural matched={len(d.matched_nodes)} "
          f"(div={d.divergence:.3f}); contextual div={dc.divergence:.3f}")
    print("divergence(task0, task1):",
          tuple(round(x, 3) for x in divergence_scores(
              g.get_model("task0"), g.get_model("task1"))))

    # 4. register a test + run it over the graph. scope="head" tells the
    #    diagnostics runner the test only reads the head submodule, so
    #    versions sharing a bit-identical head share one memoized result.
    g.register_test_function(
        lambda m: float(np.linalg.norm(m.params["head/w"])), "head_norm",
        mt="demo", scope="head")
    print("tests:", g.run_tests(bfs(g), pattern="head", match="regex"))

    # 4b. the memoized parallel runner: the second sweep answers entirely
    #     from the content-addressed result ledger (zero materializations)
    from repro.diag import DiagnosticsRunner
    runner = DiagnosticsRunner(g)
    cold = runner.run()
    warm = DiagnosticsRunner(g).run()   # fresh runner: hits come from the store
    print(f"diag: cold executed={cold.executed}, "
          f"warm cache-hit ratio={warm.cache_hit_ratio:.0%}")

    # 5. merge two concurrent edits
    u1 = g.get_model("task0").replace_params(
        {"block0/w": g.get_model("task0").params["block0/w"] + 0.01})
    u2 = g.get_model("task0").replace_params(
        {"head/w": g.get_model("task0").params["head/w"] * 1.01})
    g.add_edge("task0", "edit_a")
    g.add_node(u1, "edit_a")
    g.add_edge("task0", "edit_b")
    g.add_node(u2, "edit_b")
    result = merge(g, "edit_a", "edit_b")
    print(f"merge(edit_a, edit_b): {result.status} — {result.detail}")

    print("\nlineage graph:")
    print(g.log())
    print(f"\n(artifacts persisted under {tmp})")


if __name__ == "__main__":
    main()
