"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_batched.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.models import get_config, init_params
from repro.serve import ServeEngine

cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(), remat="none")
params = init_params(cfg, 0)
engine = ServeEngine(cfg, params, max_len=128)

batch = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (4, 32), 0,
                                      cfg.vocab_size)}
t0 = time.perf_counter()
out = engine.generate(batch, n_tokens=16)
dt = time.perf_counter() - t0
print(f"generated {out.shape} tokens for {out.shape[0]} requests "
      f"in {dt:.2f}s ({out.size / dt:.0f} tok/s on CPU)")
print(out)
