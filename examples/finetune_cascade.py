"""End-to-end driver: train a ~100M-class LM, finetune task derivatives,
version everything through MGit, then push an upstream update through the
lineage with run_update_cascade (paper Figure 4 workflow).

Runs on CPU in a few minutes with the default reduced size; pass --full for
the paper-bert (110M) config.

    PYTHONPATH=src python examples/finetune_cascade.py [--steps 50] [--full]
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.core import (CreationFunction, LineageGraph, ModelArtifact,
                        register_creation_type, run_update_cascade)
from repro.data import SyntheticPipeline
from repro.models import get_config, init_params
from repro.optim import adamw
from repro.store import ArtifactStore
from repro.store.checkpoint import flatten_state, state_graph, unflatten_state
from repro.train.step import make_train_step


def train(cfg, params, seed, steps, batch=8, seq=64):
    state = {"params": params, "opt": adamw.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(make_train_step(cfg), donate_argnums=(0,))
    pipe = SyntheticPipeline(cfg, batch=batch, seq=seq, seed=seed)
    loss = None
    for i in range(steps):
        state, metrics = step_fn(state, pipe.host_batch(i))
        loss = float(metrics["loss"])
    return state["params"], loss


def to_artifact(cfg, params):
    flat = flatten_state(params)
    return ModelArtifact(state_graph(flat, cfg.name), flat, model_type=cfg.name)


@register_creation_type("cascade-finetune")
class Finetune(CreationFunction):
    """cr: re-finetune from (new) parent with this task's data seed."""

    def __call__(self, parents):
        cfg = get_config(self.config["arch"])
        if self.config.get("reduced"):
            cfg = dataclasses.replace(cfg.reduced(), remat="none")
        params = unflatten_state(init_params(cfg, 0),
                                 parents[0].get_model().params)
        tuned, loss = train(cfg, params, seed=self.config["seed"],
                            steps=self.config["steps"])
        print(f"    [cr] finetuned task seed={self.config['seed']} "
              f"loss={loss:.3f}")
        return to_artifact(cfg, tuned)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--tasks", type=int, default=3)
    ap.add_argument("--full", action="store_true",
                    help="use paper-bert (110M params) instead of the reduced config")
    args = ap.parse_args()

    arch = "paper-bert" if args.full else "paper-bert-small"
    cfg = get_config(arch)
    if not args.full:
        cfg = dataclasses.replace(cfg.reduced(), remat="none")

    tmp = tempfile.mkdtemp(prefix="mgit-cascade-")
    store = ArtifactStore(root=tmp, codec="lzma")
    g = LineageGraph(path=tmp, store=store)

    print(f"[1/4] pretraining base ({arch})…")
    base, loss = train(cfg, init_params(cfg, 0), seed=1, steps=args.steps)
    print(f"      base loss={loss:.3f}")
    g.add_node(to_artifact(cfg, base), "base")

    print(f"[2/4] finetuning {args.tasks} task models…")
    for t in range(args.tasks):
        cr = Finetune(arch=arch, seed=100 + t, steps=max(args.steps // 3, 5),
                      reduced=not args.full)
        g.add_edge("base", f"task{t}")
        g.add_node(cr([g.nodes["base"]]), f"task{t}", cr=cr)

    s = store.stats()
    print(f"      storage ratio={s['compression_ratio']:.2f}x "
          f"({s['logical_bytes']/1e6:.0f}MB logical → "
          f"{s['physical_bytes']/1e6:.0f}MB physical)")

    print("[3/4] upstream update: continued-pretraining the base…")
    base2, loss2 = train(cfg, unflatten_state(init_params(cfg, 0), flatten_state(base)),
                         seed=2, steps=max(args.steps // 2, 5))
    g.add_node(to_artifact(cfg, base2), "base@v2", model_type=cfg.name)
    print(f"      base@v2 loss={loss2:.3f}")

    print("[4/4] run_update_cascade(base -> base@v2)…")
    created = run_update_cascade(g, "base", "base@v2")
    print(f"      rebuilt: {created}")
    print("\nlineage graph:")
    print(g.log())
    s = store.stats()
    print(f"\nfinal storage: ratio={s['compression_ratio']:.2f}x, "
          f"objects={s['objects']}, dedup_hits={s['dedup_hits']}")


if __name__ == "__main__":
    main()
