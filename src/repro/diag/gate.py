"""Test-gated cascades + quarantine (DESIGN.md §9.4).

Git-Theta-style behavioral gating: an update cascade
(``run_update_cascade(..., gate=TestGate(...))``) runs every registered test
on each newly materialized version through the memoized runner, compares
against the version parent's recorded results, and **quarantines** a
regressing node instead of silently committing it:

* the version edge stays recorded and the artifact is kept (nothing is
  destroyed — the regression is inspectable and blame-able);
* ``metadata["quarantined"] = True`` plus a ``metadata["quarantine"]``
  record (tests, values, baselines) mark the node;
* remote sync excludes quarantined nodes from push selection by default
  (``repro.remote.sync.push(include_quarantined=...)``), so a regression
  never propagates to collaborators unnoticed.

Regression semantics (metrics are higher-is-better, like the paper's test
accuracies): a node regresses when a test *newly fails* (the baseline
passed, or there is no baseline) or when its metric drops more than ``tol``
below the baseline value. A failure the version parent already had is
inherited, not a regression — the gate does not punish a node for upstream
history (that is ``blame``'s job).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Union

from repro.core.lineage import LineageGraph, LineageNode, RegisteredTest
# Flag names + predicate live in the dependency-light core module so the
# push/hub/serving seams can read them without importing the diag runner;
# re-exported here for compatibility with existing imports.
from repro.core.quarantine import (QUARANTINE_FLAG, QUARANTINE_RECORD,
                                   is_quarantined)
from repro.diag.runner import DiagnosticsRunner, TestResult


@dataclasses.dataclass
class Regression:
    test: str
    kind: str                      # "new_failure" | "metric_drop"
    value: Optional[float]
    baseline: Optional[float] = None
    baseline_node: Optional[str] = None
    error: Optional[str] = None

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class GateDecision:
    node: str
    passed: bool
    regressions: List[Regression]
    results: Dict[str, TestResult]
    quarantined: bool = False

    def to_json(self) -> Dict[str, Any]:
        return {
            "node": self.node,
            "passed": self.passed,
            "quarantined": self.quarantined,
            "regressions": [r.to_json() for r in self.regressions],
            "results": {t: r.to_json() for t, r in self.results.items()},
        }


class TestGate:
    """The ``gate=`` hook for update cascades (and standalone checks)."""

    __test__ = False    # "Test" prefix, but not a pytest class

    def __init__(self, graph: Optional[LineageGraph] = None,
                 runner: Optional[DiagnosticsRunner] = None,
                 tol: float = 0.0, quarantine: bool = True,
                 pattern: Optional[str] = None, match: str = "regex") -> None:
        if runner is None:
            if graph is None:
                raise ValueError("TestGate needs a graph or a runner")
            runner = DiagnosticsRunner(graph)
        self.runner = runner
        self.graph = graph or runner.graph
        self.tol = tol
        self.quarantine = quarantine
        self.pattern = pattern
        self.match = match
        self.decisions: List[GateDecision] = []

    # -- evaluation ------------------------------------------------------------
    def _baseline(self, node: LineageNode,
                  test: RegisteredTest) -> Optional[TestResult]:
        """The version parent's (memoized) result for ``test``, if any."""
        for pname in node.version_parents:
            parent = self.graph.nodes.get(pname)
            if parent is None:
                continue
            if any(t.name == test.name
                   for t in self.runner.tests_for(parent)):
                return self.runner.run_one(parent, test)
        return None

    def check(self, node: Union[str, LineageNode]) -> GateDecision:
        """Evaluate the gate for one node, without side effects."""
        if isinstance(node, str):
            node = self.graph.nodes[node]
        from repro.core.lineage import compile_test_pattern
        matcher = compile_test_pattern(self.pattern, self.match)
        regressions: List[Regression] = []
        results: Dict[str, TestResult] = {}
        for test in self.runner.tests_for(node):
            if not matcher(test.name):
                continue
            res = self.runner.run_one(node, test)
            results[test.name] = res
            base = self._baseline(node, test)
            if not res.passed:
                if base is None or base.passed:
                    regressions.append(Regression(
                        test=test.name, kind="new_failure", value=res.value,
                        baseline=base.value if base else None,
                        baseline_node=base.node if base else None,
                        error=res.error))
                # else: baseline failed too — inherited, not a regression
            elif (base is not None and base.passed
                  and base.value is not None and res.value is not None
                  and res.value < base.value - self.tol):
                regressions.append(Regression(
                    test=test.name, kind="metric_drop", value=res.value,
                    baseline=base.value, baseline_node=base.node))
        self.runner.ledger.flush()   # batch the check's ledger writes
        return GateDecision(node=node.name, passed=not regressions,
                            regressions=regressions, results=results)

    def apply(self, node: Union[str, LineageNode]) -> GateDecision:
        """Check + quarantine on failure; the cascade hook entry point."""
        decision = self.check(node)
        if not decision.passed and self.quarantine:
            name = node if isinstance(node, str) else node.name
            quarantine_node(self.graph, name, decision)
            decision.quarantined = True
        self.decisions.append(decision)
        return decision

    def report(self) -> List[Dict[str, Any]]:
        return [d.to_json() for d in self.decisions]


# ---------------------------------------------------------------------------
# Quarantine state (lives in node metadata => persists + syncs as metadata)
# ---------------------------------------------------------------------------


def quarantine_node(graph: LineageGraph, name: str,
                    decision: Optional[GateDecision] = None,
                    reason: Optional[str] = None) -> None:
    node = graph.nodes[name]
    node.metadata[QUARANTINE_FLAG] = True
    record: Dict[str, Any] = {"reason": reason or "gate regression"}
    if decision is not None:
        record["regressions"] = [r.to_json() for r in decision.regressions]
    node.metadata[QUARANTINE_RECORD] = record
    graph._commit()


def release_node(graph: LineageGraph, name: str) -> None:
    """Lift a quarantine (after a fix-forward or a human override)."""
    node = graph.nodes[name]
    node.metadata.pop(QUARANTINE_FLAG, None)
    node.metadata.pop(QUARANTINE_RECORD, None)
    graph._commit()


def gate_report(graph: LineageGraph) -> List[Dict[str, Any]]:
    """All currently quarantined nodes with their recorded regressions."""
    out = []
    for name in sorted(graph.nodes):
        node = graph.nodes[name]
        if is_quarantined(node):
            out.append({"node": name,
                        **node.metadata.get(QUARANTINE_RECORD,
                                            {"reason": "unknown"})})
    return out
