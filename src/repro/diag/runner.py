"""Memoized parallel test runner over the lineage graph (DESIGN.md §9.1).

The paper's test-reuse optimization (§4, Table 2): a test result is a pure
function of *(test identity, model content)*, so it is computed once and
persisted as a content-addressed **result ledger** entry in the store's CAS
(key scheme ``t_`` — see :func:`repro.store.cas.ledger_key`). Re-testing an
unchanged model is a single O(1) ledger probe: no manifest walk, no tensor
materialization, no model checkout.

Identity components:

* ``test_hash`` — SHA-256 over the test's name, declared scope, and its
  function's bytecode + constants, so editing a test invalidates its cached
  results while re-importing identical code does not;
* ``manifest_key`` — the node's ``artifact_ref`` (itself a content address
  of the stored model) for store-backed nodes, a hash of the per-parameter
  content hashes for in-memory ones, or — when the test declares a ``scope``
  (param-key prefix) — the hash of just the scoped parameter hashes
  (:func:`repro.diag.transfer.scoped_content_key`), which makes versions
  with a bit-identical tested submodule share one ledger entry (§9.3).

Execution fans out across nodes with a thread pool, and models are checked
out **lazily** (``ArtifactStore.load_artifact`` → :class:`ParamRef` handles):
a test only materializes the tensors it actually touches.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
import time
import types
from concurrent.futures import ThreadPoolExecutor
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.common.hashing import bytes_hash
from repro.core.artifact import ModelArtifact
from repro.core.lineage import (LineageGraph, LineageNode, RegisteredTest,
                                compile_test_pattern)
from repro.store.cas import ledger_key

SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# Identity hashing
# ---------------------------------------------------------------------------


def _code_fingerprint(code, parts: List[str]) -> None:
    """Append a process-stable fingerprint of ``code``: bytecode plus
    constants, recursing into nested code objects (comprehensions, lambdas,
    inner defs). ``repr`` of a nested code object embeds its memory address
    and must never reach the hash — that would silently defeat cross-process
    memoization for any test containing a comprehension."""
    parts.append(code.co_code.hex())
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            _code_fingerprint(const, parts)
        else:
            parts.append(repr(const))


def test_identity_hash(test: RegisteredTest) -> str:
    """Content identity of a test: name + scope + function code.

    Bytecode plus constants tracks the function's *behavior* across process
    restarts (same source compiles identically on one interpreter); callables
    without ``__code__`` fall back to ``repr`` — stable for named callables,
    conservatively unstable otherwise."""
    parts: List[str] = [test.name, test.scope or ""]
    code = getattr(test.fn, "__code__", None)
    if code is not None:
        _code_fingerprint(code, parts)
    else:
        parts.append(repr(test.fn))
    return bytes_hash("\x00".join(parts).encode())


def manifest_key_for(node: LineageNode, scope: Optional[str] = None) -> str:
    """Content key of the model a test would observe on ``node``.

    Prefers the stored ``artifact_ref`` — the delta-reconstructed model the
    store persists is the version's truth (the in-memory artifact can differ
    by quantization eps). ``scope`` narrows the key to the scoped submodule's
    parameter content (DESIGN.md §9.3)."""
    if scope is not None:
        from repro.diag.transfer import scoped_content_key
        key = scoped_content_key(node, scope)
        if key is not None:
            return key
    if node.artifact_ref is not None:
        return node.artifact_ref
    artifact = node.get_model()
    doc = {"model_type": artifact.model_type,
           "params": sorted(artifact.param_hashes().items())}
    return "mem_" + bytes_hash(json.dumps(doc, sort_keys=True).encode())


# ---------------------------------------------------------------------------
# Results + ledger
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TestResult:
    """One (test, model-content) evaluation — what the ledger stores."""

    test: str
    node: str
    value: Optional[float]
    passed: bool
    cached: bool
    duration_s: float
    error: Optional[str] = None
    transferred: bool = False      # ran via structural test transfer (§9.3)
    key: Optional[str] = None      # ledger key (None for unpersisted runs)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class ResultLedger:
    """Content-addressed, append-only store of test results.

    Backed by the repository CAS when the graph has a store (entries survive
    process restarts and ride along ``fsck``); an in-memory dict otherwise.
    Entries are write-once per (test_hash, manifest_key) — both are content
    addresses, so a recorded result can only be superseded by changing the
    test or the model, which changes the key."""

    def __init__(self, store: Any = None) -> None:
        self.store = store
        self._mem: Dict[str, Dict[str, Any]] = {}
        self._lock = threading.Lock()
        self._dirty = False

    def key(self, test_hash: str, manifest_key: str) -> str:
        return ledger_key(test_hash, manifest_key)

    def get(self, test_hash: str, manifest_key: str) -> Optional[Dict[str, Any]]:
        key = self.key(test_hash, manifest_key)
        with self._lock:
            if key in self._mem:
                return self._mem[key]
        if self.store is not None and self.store.cas.has(key):
            record = json.loads(self.store.cas.get_bytes(key))
            with self._lock:
                self._mem[key] = record
            return record
        return None

    def put(self, record: Dict[str, Any], force: bool = False) -> str:
        """Record a result. Write-once per key unless ``force`` (a forced
        re-execution supersedes the stored entry in place). Durability is
        batched: pack records hit disk immediately (and are recoverable by
        the tail scan), but the index/refcount flush is deferred to
        :meth:`flush` — one durable write per sweep, not per test."""
        key = self.key(record["test_hash"], record["manifest_key"])
        with self._lock:
            known = key in self._mem
            self._mem[key] = record
        if self.store is not None:
            fresh = not known and not self.store.cas.has(key)
            if fresh or force:
                payload = json.dumps(record, sort_keys=True).encode()
                self.store.cas.put_bytes(payload, key=key, overwrite=force)
                with self._lock:
                    self._dirty = True
        return key

    def flush(self) -> None:
        """Persist CAS index/refcount state for any puts since the last
        flush (called once per runner sweep / gate check)."""
        with self._lock:
            if not self._dirty:
                return
            self._dirty = False
        if self.store is not None:
            self.store.cas.flush()

    def entries(self) -> Iterator[Dict[str, Any]]:
        """Scan every persisted ledger entry (the ``diag history`` query)."""
        seen = set()
        if self.store is not None:
            for key in self.store.cas.keys():
                if not key.startswith("t_"):
                    continue
                seen.add(key)
                try:
                    yield json.loads(self.store.cas.get_bytes(key))
                except Exception:
                    continue  # corrupt entry: fsck's problem, not history's
        with self._lock:
            mem = [(k, r) for k, r in self._mem.items() if k not in seen]
        for _, record in mem:
            yield record


# ---------------------------------------------------------------------------
# The runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunReport:
    """Aggregate of one ``DiagnosticsRunner.run`` invocation."""

    results: Dict[str, Dict[str, TestResult]]
    executed: int
    memo_hits: int
    duration_s: float

    @property
    def total(self) -> int:
        return self.executed + self.memo_hits

    @property
    def cache_hit_ratio(self) -> float:
        return self.memo_hits / self.total if self.total else 0.0

    def values(self) -> Dict[str, Dict[str, float]]:
        """``run_tests``-shaped {node: {test: value}} view (failures omitted)."""
        return {
            node: {t: r.value for t, r in res.items() if r.value is not None}
            for node, res in self.results.items() if res
        }

    def failures(self) -> List[TestResult]:
        return [r for res in self.results.values() for r in res.values()
                if not r.passed]

    def to_json(self) -> Dict[str, Any]:
        return {
            "executed": self.executed,
            "memo_hits": self.memo_hits,
            "cache_hit_ratio": round(self.cache_hit_ratio, 4),
            "duration_s": self.duration_s,
            "results": {node: {t: r.to_json() for t, r in res.items()}
                        for node, res in self.results.items()},
        }


def _evaluate(fn: Callable[[ModelArtifact], Any], artifact: ModelArtifact):
    """Run one test fn; normalize to (value, passed).

    Convention: a bool return is its own verdict; a numeric return passes
    iff finite (NaN/inf = failure, e.g. a poisoned upstream); an exception
    fails with the error recorded."""
    value = fn(artifact)
    if isinstance(value, bool):
        return float(value), value
    v = float(value)
    return v, math.isfinite(v)


class DiagnosticsRunner:
    """Memoized, parallel, lazily-checked-out test execution (DESIGN.md §9.1).

    One runner serves ``run`` sweeps, ``blame`` attribution probes and
    ``TestGate`` checks; they all share the ledger, so e.g. a gate check
    after a sweep costs zero executions."""

    def __init__(self, graph: LineageGraph, max_workers: Optional[int] = None,
                 ledger: Optional[ResultLedger] = None,
                 transfer: bool = False,
                 max_transfer_divergence: float = 0.0,
                 prefetch: bool = False) -> None:
        self.graph = graph
        self.ledger = ledger or ResultLedger(graph.store)
        self.max_workers = max_workers or min(8, (os.cpu_count() or 2))
        self.transfer = transfer
        self.max_transfer_divergence = max_transfer_divergence
        # prefetch=True batch-materializes a node's stored artifact through
        # ArtifactStore.materialize_artifact (chain folding + threaded
        # decode; DESIGN.md §10.3) before its tests run — right for sweeps
        # whose tests read most parameters; leave False for scoped tests,
        # which should only materialize the submodule they touch
        self.prefetch = prefetch
        self.stats = {"executed": 0, "memo_hits": 0, "checkouts": 0,
                      "transferred_runs": 0}
        self._checkout_cache: Dict[str, ModelArtifact] = {}
        self._lock = threading.Lock()

    # -- applicability ---------------------------------------------------------
    def tests_for(self, node: LineageNode) -> List[RegisteredTest]:
        """Registered tests for ``node``, plus structurally transferred ones."""
        tests = list(self.graph.tests_for(node))
        if self.transfer:
            from repro.diag.transfer import transferable_tests
            have = {t.name for t in tests}
            tests += [t for t in transferable_tests(
                self.graph, node, self.max_transfer_divergence)
                if t.name not in have]
        return tests

    def _is_transferred(self, node: LineageNode, test: RegisteredTest) -> bool:
        return not test.applies_to(node)

    # -- checkout --------------------------------------------------------------
    def _checkout(self, node: LineageNode) -> ModelArtifact:
        """Lazy model view for testing: stored truth via ParamRef handles.

        Never caches onto the node (no cross-thread node mutation); repeat
        checkouts within one runner reuse a private per-runner cache, and
        tensor data is shared through the store's TensorCache anyway."""
        with self._lock:
            cached = self._checkout_cache.get(node.name)
        if cached is not None:
            return cached
        if node.artifact_ref is not None and self.graph.store is not None:
            if self.prefetch:
                # batched checkout: whole-model tests hit a warm tensor
                # cache instead of paying one chain walk per parameter
                # inside the test body (the fan-out threads then share it)
                self.graph.store.materialize_artifact(node.artifact_ref)
            artifact = self.graph.store.load_artifact(node.artifact_ref)
        else:
            artifact = node.get_model()
        with self._lock:
            self._checkout_cache[node.name] = artifact
            self.stats["checkouts"] += 1
        return artifact

    # -- execution -------------------------------------------------------------
    def run_one(self, node: LineageNode, test: RegisteredTest,
                force: bool = False,
                identity: Optional[Tuple[str, str]] = None) -> TestResult:
        """Evaluate one (node, test) pair, through the ledger.

        ``identity`` is an optional precomputed ``(test_hash,
        manifest_key)`` — ``run`` passes it so the grouping pass's hashing
        work is not repeated per representative."""
        if identity is not None:
            test_hash, manifest_key = identity
        else:
            test_hash = test_identity_hash(test)
            manifest_key = manifest_key_for(node, scope=test.scope)
        key = self.ledger.key(test_hash, manifest_key)
        if not force:
            record = self.ledger.get(test_hash, manifest_key)
            if record is not None:
                with self._lock:
                    self.stats["memo_hits"] += 1
                return TestResult(
                    test=test.name, node=node.name,
                    value=record.get("value"), passed=record.get("passed", False),
                    cached=True, duration_s=record.get("duration_s", 0.0),
                    error=record.get("error"),
                    transferred=self._is_transferred(node, test), key=key)

        artifact = self._checkout(node)
        t0 = time.perf_counter()
        error: Optional[str] = None
        try:
            value, passed = _evaluate(test.fn, artifact)
        except Exception as exc:
            value, passed, error = None, False, f"{type(exc).__name__}: {exc}"
        duration = time.perf_counter() - t0

        record = {
            "schema": SCHEMA_VERSION,
            "test": test.name, "test_hash": test_hash,
            "manifest_key": manifest_key, "scope": test.scope,
            "node": node.name, "artifact_ref": node.artifact_ref,
            "value": value, "passed": passed, "error": error,
            "duration_s": duration,
        }
        self.ledger.put(record, force=force)
        with self._lock:
            self.stats["executed"] += 1
        return TestResult(test=test.name, node=node.name, value=value,
                          passed=passed, cached=False, duration_s=duration,
                          error=error,
                          transferred=self._is_transferred(node, test),
                          key=key)

    def run(self, nodes: Optional[Sequence[LineageNode]] = None,
            pattern: Optional[str] = None, match: str = "regex",
            tests: Optional[Sequence[RegisteredTest]] = None,
            force: bool = False) -> RunReport:
        """Fan the (node, test) work list out across the thread pool.

        ``nodes`` defaults to the whole graph; ``tests`` overrides the
        registry (still filtered by per-node applicability + transfer);
        ``force`` bypasses ledger reads (results are still recorded)."""
        if nodes is None:
            nodes = list(self.graph.nodes.values())
        matcher = compile_test_pattern(pattern, match)
        work: List = []
        for node in nodes:
            if tests is not None:  # explicit list still honors applicability
                applicable = {t.name for t in self.tests_for(node)}
                cands = [t for t in tests if t.name in applicable]
            else:
                cands = self.tests_for(node)
            for t in cands:
                if matcher(t.name):
                    work.append((node, t))

        # Single-flight: (node, test) pairs that resolve to the same ledger
        # key — e.g. versions whose scoped submodule is bit-identical
        # (§9.3) — execute ONCE; the rest reuse the result as memo hits.
        # Without this a parallel cold sweep races duplicates past the
        # ledger probe and evaluates them redundantly. Identity hashes are
        # computed once here and handed to run_one, never re-derived.
        test_hashes: Dict[int, str] = {}
        keyed: Dict[str, List] = {}
        order: List[str] = []
        identities: Dict[str, Tuple[str, str]] = {}
        for node, t in work:
            th = test_hashes.get(id(t))
            if th is None:
                th = test_hashes[id(t)] = test_identity_hash(t)
            mk = manifest_key_for(node, scope=t.scope)
            k = self.ledger.key(th, mk)
            if k not in keyed:
                keyed[k] = []
                order.append(k)
                identities[k] = (th, mk)
            keyed[k].append((node, t))
        reps = [(keyed[k][0], identities[k]) for k in order]

        results: Dict[str, Dict[str, TestResult]] = {n.name: {} for n in nodes}
        executed_before = self.stats["executed"]
        hits_before = self.stats["memo_hits"]
        t0 = time.perf_counter()
        try:
            if len(reps) <= 1 or self.max_workers == 1:
                done = [self.run_one(n, t, force=force, identity=ident)
                        for (n, t), ident in reps]
            else:
                with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
                    done = list(pool.map(
                        lambda job: self.run_one(job[0][0], job[0][1],
                                                 force=force,
                                                 identity=job[1]),
                        reps))
        finally:
            self.ledger.flush()   # ONE durable index write for the sweep
        for k, res in zip(order, done):
            rep_node, rep_test = keyed[k][0]
            results[rep_node.name][rep_test.name] = res
            for node, test in keyed[k][1:]:
                with self._lock:
                    self.stats["memo_hits"] += 1
                results[node.name][test.name] = dataclasses.replace(
                    res, node=node.name, cached=True,
                    transferred=self._is_transferred(node, test))
        return RunReport(
            results={k: v for k, v in results.items() if v},
            executed=self.stats["executed"] - executed_before,
            memo_hits=self.stats["memo_hits"] - hits_before,
            duration_s=time.perf_counter() - t0)

    # -- history ---------------------------------------------------------------
    def history(self, node_name: str,
                test_name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Recorded results for every version of ``node_name`` (§9.1).

        A ModelHub-style ledger query: walks the node's version chain and
        returns every persisted entry whose node or manifest belongs to it,
        oldest version first."""
        from repro.core.traversal import version_chain
        if node_name in self.graph.nodes:
            chain = [n for n in version_chain(self.graph, node_name)]
        else:
            chain = []
        names = {n.name: i for i, n in enumerate(chain)}
        refs = {n.artifact_ref: i for i, n in enumerate(chain)
                if n.artifact_ref}
        out = []
        for record in self.ledger.entries():
            pos = names.get(record.get("node"),
                            refs.get(record.get("artifact_ref")))
            if pos is None and not chain and record.get("node") == node_name:
                pos = 0
            if pos is None:
                continue
            if test_name is not None and record.get("test") != test_name:
                continue
            out.append({**record, "chain_position": pos})
        out.sort(key=lambda r: (r["chain_position"], r.get("test") or ""))
        return out
