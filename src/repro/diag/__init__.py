"""Diagnostics engine over the lineage graph (paper §4; DESIGN.md §9).

Four layers:

* :mod:`repro.diag.runner` — memoized parallel test execution backed by a
  content-addressed result ledger in the store (§9.1);
* :mod:`repro.diag.blame` — DAG-wide regression attribution: introduced /
  inherited / merge-emergent, plus the earliest failing frontier (§9.2);
* :mod:`repro.diag.transfer` — diff-adapted test transfer and scoped
  re-run skipping from manifest metadata only (§9.3);
* :mod:`repro.diag.gate` — test-gated update cascades with quarantine,
  honored by remote sync (§9.4).
"""

from repro.diag.blame import (EMERGENT, INHERITED, INTRODUCED, NOT_RUN, PASS,
                              BlameEntry, BlameReport, blame)
from repro.diag.gate import (GateDecision, Regression, TestGate, gate_report,
                             is_quarantined, quarantine_node, release_node)
from repro.diag.runner import (DiagnosticsRunner, ResultLedger, RunReport,
                               TestResult, manifest_key_for,
                               test_identity_hash)
from repro.diag.transfer import (scoped_content_key, scoped_param_hashes,
                                 structurally_transferable, structure_of,
                                 transferable_tests)

__all__ = [
    "blame", "BlameEntry", "BlameReport",
    "PASS", "INTRODUCED", "INHERITED", "EMERGENT", "NOT_RUN",
    "TestGate", "GateDecision", "Regression", "gate_report",
    "is_quarantined", "quarantine_node", "release_node",
    "DiagnosticsRunner", "ResultLedger", "RunReport", "TestResult",
    "manifest_key_for", "test_identity_hash",
    "scoped_content_key", "scoped_param_hashes", "structure_of",
    "structurally_transferable", "transferable_tests",
]
