"""Regression attribution over the provenance DAG (DESIGN.md §9.2).

``bisect`` answers "which version of THIS model first failed"; ``blame``
answers the paper's harder question (§4): *is this bug inherited from an
upstream model?* Given a failing (node, test) it walks BOTH edge kinds —
version edges and provenance edges — up to the roots, evaluates the test on
every ancestor through the memoized runner (so repeated blames and
overlapping closures are nearly free), and classifies each failure:

* ``introduced`` — the node fails but every evaluated upstream passes (or
  nothing upstream runs the test): the regression originates here;
* ``inherited`` — at least one direct upstream (version parent or
  provenance parent) fails the same test: the bug flowed downstream;
* ``emergent`` — a merge-style node (>= 2 provenance parents) fails while
  all of its parents pass: the combination, not an input, is at fault.

The **frontier** is the earliest-ancestor set where the test first fails
(every failing node none of whose evaluated upstreams fails) — the DAG
generalization of bisect's single first-bad version.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.core.lineage import LineageGraph, LineageNode, RegisteredTest
from repro.diag.runner import DiagnosticsRunner, TestResult

PASS = "pass"
INTRODUCED = "introduced"
INHERITED = "inherited"
EMERGENT = "emergent"
NOT_RUN = "not_run"


@dataclasses.dataclass
class BlameEntry:
    node: str
    status: str
    value: Optional[float] = None
    passed: Optional[bool] = None
    cached: bool = False
    inherited_from: List[str] = dataclasses.field(default_factory=list)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class BlameReport:
    node: str
    test: str
    entries: Dict[str, BlameEntry]
    frontier: List[str]            # earliest failing ancestor set

    @property
    def status(self) -> str:
        """Classification of the queried node itself."""
        return self.entries[self.node].status

    def to_json(self) -> Dict[str, Any]:
        return {
            "node": self.node,
            "test": self.test,
            "status": self.status,
            "frontier": self.frontier,
            "entries": {k: v.to_json() for k, v in sorted(self.entries.items())},
        }


def _ancestor_closure(graph: LineageGraph, start: str) -> List[LineageNode]:
    """``start`` plus every ancestor reachable over version OR provenance
    edges, in deterministic BFS-from-start order."""
    order = [start]
    seen = {start}
    i = 0
    while i < len(order):
        node = graph.nodes[order[i]]
        i += 1
        for p in node.version_parents + node.parents:
            if p not in seen and p in graph.nodes:
                seen.add(p)
                order.append(p)
    return [graph.nodes[n] for n in order]


def _find_test(graph: LineageGraph, test_name: str) -> RegisteredTest:
    for t in graph.tests:
        if t.name == test_name:
            return t
    raise KeyError(f"no registered test named {test_name!r}")


def blame(graph: LineageGraph, node_name: str, test_name: str,
          runner: Optional[DiagnosticsRunner] = None,
          failing: Optional[Callable[[TestResult], bool]] = None
          ) -> BlameReport:
    """Attribute a test failure at ``node_name`` across the provenance DAG.

    ``failing`` overrides the pass/fail convention (default: the result's
    recorded ``passed`` flag — exceptions and non-finite metrics fail).
    Evaluation is parallel and memoized; a blame immediately after a
    ``DiagnosticsRunner.run`` sweep executes zero new tests."""
    if node_name not in graph.nodes:
        raise KeyError(f"unknown node {node_name!r}")
    runner = runner or DiagnosticsRunner(graph)
    test = _find_test(graph, test_name)
    failing = failing or (lambda r: not r.passed)

    closure = _ancestor_closure(graph, node_name)
    report = runner.run(nodes=closure, tests=[test])

    results: Dict[str, TestResult] = {}
    for name, res in report.results.items():
        if test.name in res:
            results[name] = res[test.name]

    failing_set = {n for n, r in results.items() if failing(r)}
    entries: Dict[str, BlameEntry] = {}
    for node in closure:
        r = results.get(node.name)
        if r is None:
            entries[node.name] = BlameEntry(node=node.name, status=NOT_RUN)
            continue
        if node.name not in failing_set:
            entries[node.name] = BlameEntry(
                node=node.name, status=PASS, value=r.value, passed=r.passed,
                cached=r.cached)
            continue
        upstream = [p for p in node.version_parents + node.parents
                    if p in results]
        failed_upstream = [p for p in upstream if p in failing_set]
        if failed_upstream:
            status = INHERITED
        elif len([p for p in node.parents if p in results]) >= 2:
            status = EMERGENT
        else:
            status = INTRODUCED
        entries[node.name] = BlameEntry(
            node=node.name, status=status, value=r.value, passed=r.passed,
            cached=r.cached, inherited_from=failed_upstream)

    frontier = sorted(n for n, e in entries.items()
                      if e.status in (INTRODUCED, EMERGENT))
    return BlameReport(node=node_name, test=test.name, entries=entries,
                       frontier=frontier)
