"""Diff-adapted test transfer (DESIGN.md §9.3).

Two reuse decisions, both made from *metadata only* (manifest graph JSON and
commit-time parameter hashes — no tensor ever materializes here):

1. **Scoped re-run skipping** — a test that declares a ``scope`` (param-key
   prefix) depends only on that submodule. Its memoization key is the hash
   of the scoped parameters' content hashes (:func:`scoped_content_key`), so
   two versions whose tested submodule is bit-identical (e.g. a finetune
   that froze the head a head-probe tests) resolve to the SAME ledger entry:
   the second version is never re-tested.

2. **Structural transfer** — a test registered for model type A may run
   against a node of type B when B's layer graph structurally matches A's
   (``core/diff.py`` contextual-matching machinery in structural mode, with
   a divergence budget). This is how a derivative that kept its parent's
   architecture inherits the parent type's behavioral checks.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.common.hashing import bytes_hash
from repro.core.diff import module_diff
from repro.core.graphir import LayerGraph
from repro.core.lineage import LineageGraph, LineageNode, RegisteredTest


def _in_scope(key: str, scope: str) -> bool:
    """Path-boundary prefix match over flat "layer/param" keys: scope
    "head" covers "head/w" but NOT "header/w"; an exact key is its own
    scope."""
    return key == scope or key.startswith(scope.rstrip("/") + "/")


def scoped_param_hashes(node: LineageNode,
                        scope: str) -> Optional[Dict[str, str]]:
    """Content hashes of the parameters under ``scope``, metadata-only.

    Store-backed nodes answer from the manifest; in-memory nodes from
    ``param_hashes()`` (cheap at test-model scale, cached after). Returns
    None when the scope matches nothing — callers fall back to whole-model
    keying rather than memoizing on an empty selection."""
    graph = node._graph
    store = graph.store if graph is not None else None
    if node.artifact_ref is not None and store is not None:
        manifest = store.get_manifest(node.artifact_ref)
        items = {k: e.get("hash") or e.get("tensor")
                 for k, e in manifest["params"].items() if _in_scope(k, scope)}
    else:
        hashes = node.get_model().param_hashes()
        items = {k: h for k, h in hashes.items() if _in_scope(k, scope)}
    return items or None


def scoped_content_key(node: LineageNode, scope: str) -> Optional[str]:
    """Ledger manifest-key for a scoped test: ``s_`` + hash of the scoped
    parameter-hash set. Identical submodule content => identical key,
    across versions AND across nodes (DESIGN.md §9.3)."""
    items = scoped_param_hashes(node, scope)
    if items is None:
        return None
    payload = json.dumps(sorted(items.items())).encode()
    return "s_" + bytes_hash(payload)


def structure_of(node: LineageNode) -> LayerGraph:
    """The node's LayerGraph without materializing any tensor."""
    if node.artifact is not None:
        return node.artifact.graph
    graph = node._graph
    store = graph.store if graph is not None else None
    if node.artifact_ref is not None and store is not None:
        return LayerGraph.from_json(
            store.get_manifest(node.artifact_ref)["graph"])
    return node.get_model().graph  # raises if no artifact anywhere


def structurally_transferable(a: LayerGraph, b: LayerGraph,
                              max_divergence: float = 0.0) -> bool:
    """True when structural diff divergence (paper §3.2) is within budget."""
    return module_diff(a, b, mode="structural").divergence <= max_divergence


def transferable_tests(graph: LineageGraph, node: LineageNode,
                       max_divergence: float = 0.0) -> List[RegisteredTest]:
    """Type-bound tests that transfer to ``node`` via structural matching.

    For each test registered on a *different* model type, pick that type's
    exemplar (first node by name with an available structure) and admit the
    test when the exemplar's layer graph matches the node's. Node-bound
    tests never transfer — binding to a name is an explicit pin."""
    out: List[RegisteredTest] = []
    node_structure: Optional[LayerGraph] = None
    exemplars: Dict[str, Optional[LayerGraph]] = {}
    for t in graph.tests:
        if t.model_type is None or t.applies_to(node):
            continue
        if t.model_type not in exemplars:
            exemplar = None
            for name in sorted(graph.nodes):
                cand = graph.nodes[name]
                if cand.name == node.name or cand.model_type != t.model_type:
                    continue
                try:
                    exemplar = structure_of(cand)
                    break
                except Exception:
                    continue
            exemplars[t.model_type] = exemplar
        exemplar = exemplars[t.model_type]
        if exemplar is None:
            continue
        if node_structure is None:
            try:
                node_structure = structure_of(node)
            except Exception:
                return out
        if structurally_transferable(exemplar, node_structure, max_divergence):
            out.append(t)
    return out
