"""Pallas TPU kernel: content fingerprint for on-device dedup candidate detection.

SHA-256 (the paper's durable key) is byte-serial — no TPU mapping. The TPU
adaptation (DESIGN.md §3) computes a position-mixed 2x32-bit hash whose
partial sums wrap mod 2^32, making it *tile-decomposable*: any tiling of the
tensor produces identical results, so the kernel parallelizes freely over
VMEM tiles and the host (or a final jnp sum) tree-combines per-tile partials.

Use: right after an optimizer step / checkpoint cut, fingerprint every
parameter on-device. Only tensors whose fingerprint is NOT already in the CAS
index need a host transfer + SHA-256; frozen/shared tensors (the paper's G1,
G5 regimes: up to 80% duplicates) never leave HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import FP_C1, FP_C2, FP_C3

BLOCK_ROWS = 256
LANE_COLS = 1024


def _fingerprint_kernel(bits_ref, out_ref, *, cols: int, block_rows: int):
    i = pl.program_id(0)
    bits = bits_ref[...]
    base = (i * block_rows * cols)
    row_idx = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 0)
    col_idx = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 1)
    idx = jnp.uint32(base) + row_idx * jnp.uint32(cols) + col_idx
    x = (bits * FP_C1) ^ (idx * FP_C2)
    x = x * FP_C3
    h1 = x ^ (x >> 15)
    y = (bits + idx) * FP_C2
    h2 = y ^ (y >> 13)
    out_ref[0, 0] = jnp.sum(h1, dtype=jnp.uint32)
    out_ref[0, 1] = jnp.sum(h2, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fingerprint_2d(bits: jnp.ndarray, block_rows: int = BLOCK_ROWS,
                   interpret: bool = False) -> jnp.ndarray:
    """bits: (rows, cols) uint32, rows % block_rows == 0. Returns (2,) uint32.

    Per-tile partials are written to a (grid, 2) buffer and wrap-summed — the
    combine is associative/commutative so the reduction order is free.
    """
    rows, cols = bits.shape
    grid = (rows // block_rows,)
    kernel = functools.partial(_fingerprint_kernel, cols=cols,
                               block_rows=block_rows)
    partials = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 2), jnp.uint32),
        interpret=interpret,
    )(bits)
    return jnp.sum(partials, axis=0, dtype=jnp.uint32)


__all__ = ["fingerprint_2d", "BLOCK_ROWS", "LANE_COLS"]
