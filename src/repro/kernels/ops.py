"""Jit'd public wrappers for the storage-path kernels.

Canonicalization: every tensor is flattened and zero-padded to a
(rows, LANE_COLS) layout with rows a multiple of 8 (TPU sublane), then
dispatched to the Pallas kernel (TPU), the interpret-mode kernel (tests), or
the pure-jnp oracle (CPU hosts — same semantics, no interpreter overhead).
Results are cropped back to the original shape, and zero counts are corrected
for padding, so callers never see the canonical layout.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.delta_quantize import (BLOCK_ROWS, LANE_COLS,
                                          delta_quantize_2d, dequant_apply_2d)
from repro.kernels.fingerprint import fingerprint_2d


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_rows(n_flat: int, cols: int) -> int:
    rows = -(-n_flat // cols)
    return -(-rows // 8) * 8  # sublane multiple


def _block_rows(rows: int) -> int:
    for candidate in (BLOCK_ROWS, 128, 64, 32, 16, 8):
        if rows % candidate == 0:
            return candidate
    return rows


def _to_2d(x: jnp.ndarray, cols: int = LANE_COLS) -> Tuple[jnp.ndarray, int]:
    """Flatten + zero-pad to (rows, cols); returns (array2d, n_real_elements)."""
    flat = jnp.ravel(x)
    n = flat.shape[0]
    rows = _pad_rows(n, cols)
    flat = jnp.pad(flat, (0, rows * cols - n))
    return flat.reshape(rows, cols), n


def _bits_2d(x: jnp.ndarray, cols: int = LANE_COLS) -> Tuple[jnp.ndarray, int]:
    """Canonical uint32 bit view, padded to (rows, cols)."""
    flat = jnp.ravel(x)
    if flat.dtype == jnp.float32:
        bits = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    elif flat.dtype in (jnp.bfloat16, jnp.float16):
        bits = jax.lax.bitcast_convert_type(flat, jnp.uint16).astype(jnp.uint32)
    elif flat.dtype == jnp.uint32:
        bits = flat
    elif flat.dtype == jnp.int32:
        bits = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    else:
        bits = jax.lax.bitcast_convert_type(flat.astype(jnp.float32), jnp.uint32)
    n = bits.shape[0]
    rows = _pad_rows(n, cols)
    bits = jnp.pad(bits, (0, rows * cols - n))
    return bits.reshape(rows, cols), n


# ---------------------------------------------------------------------------
# delta quantize / dequantize
# ---------------------------------------------------------------------------

def delta_quantize(p1, p2, eps: float = 1e-4, backend: Optional[str] = None,
                   return_block_zeros: bool = False):
    """Quantized delta q = floor((p1-p2)/scale + 0.5) (paper Algorithm 1).

    Returns (q int32 array shaped like p1, n_zero int) — optionally also the
    per-tile zero counts used by the compressibility pre-filter.
    """
    backend = backend or default_backend()
    p1 = jnp.asarray(p1)
    p2 = jnp.asarray(p2)
    orig_shape = p1.shape
    if backend == "ref":
        q, nz = _ref.delta_quantize_ref(p1, p2, eps)
        if return_block_zeros:
            return q, int(nz), None
        return q, int(nz)

    a, n = _to_2d(p1)
    b, _ = _to_2d(p2)
    q2d, block_zeros = delta_quantize_2d(a, b, eps=eps,
                                         block_rows=_block_rows(a.shape[0]),
                                         interpret=(backend == "interpret"))
    q = q2d.reshape(-1)[:n].reshape(orig_shape)
    n_pad = a.size - n  # padded elements are exact zeros and were counted
    nz = int(jnp.sum(block_zeros)) - n_pad
    if return_block_zeros:
        return q, nz, np.asarray(block_zeros)
    return q, nz


def dequant_apply(p1, q, eps: float = 1e-4, out_dtype=None,
                  backend: Optional[str] = None):
    """Reconstruct the child parameter: p2' = p1 - q*scale."""
    backend = backend or default_backend()
    p1 = jnp.asarray(p1)
    q = jnp.asarray(q, dtype=jnp.int32)
    if backend == "ref":
        return _ref.dequant_apply_ref(p1, q, eps, out_dtype=out_dtype)
    orig_shape = p1.shape
    a, n = _to_2d(p1)
    qq, _ = _to_2d(q)
    out2d = dequant_apply_2d(a, qq, eps=eps, block_rows=_block_rows(a.shape[0]),
                             interpret=(backend == "interpret"))
    out = out2d.reshape(-1)[:n].reshape(orig_shape)
    return out.astype(out_dtype or p1.dtype)


def chain_apply(base, qs, eps: float = 1e-4, out_dtype=None,
                backend: Optional[str] = None):
    """Fused delta-chain application: ``base - sum(qs) * scale`` (§10.2).

    ``qs`` is a sequence of quantized deltas (int8/int32) from one same-eps
    chain segment. One HBM pass on TPU (int32 reduction in VMEM); bit-
    identical to summing on the host and calling ``dequant_apply`` once —
    int32 sums are exact, and the final multiply+subtract is the same
    correctly-rounded f32 op either way."""
    backend = backend or default_backend()
    base = jnp.asarray(base)
    stack = jnp.stack([jnp.asarray(q, dtype=jnp.int32).reshape(base.shape)
                       for q in qs])
    if backend == "ref":
        from repro.kernels.chain_apply import chain_apply_ref
        out = chain_apply_ref(base, stack, eps)
        return out.astype(out_dtype or base.dtype)
    from repro.kernels.chain_apply import chain_apply_2d
    orig_shape = base.shape
    a, n = _to_2d(base.astype(jnp.float32))
    # pad each q independently to the canonical layout (zero padding is
    # exact: padded lanes contribute 0 to the int32 sum)
    q2d = jnp.stack([_to_2d(stack[i])[0].astype(jnp.int32)
                     for i in range(stack.shape[0])])
    out2d = chain_apply_2d(a, q2d, eps=eps,
                           block_rows=_block_rows(a.shape[0]),
                           interpret=(backend == "interpret"))
    out = out2d.reshape(-1)[:n].reshape(orig_shape)
    return out.astype(out_dtype or base.dtype)


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _fingerprint_ref_2d(bits: jnp.ndarray) -> jnp.ndarray:
    return _ref.fingerprint_ref(bits)


def snapshot_fused(p1, p2, eps: float = 1e-4, backend: Optional[str] = None,
                   with_fingerprint: bool = True):
    """One-pass checkpoint snapshot: (q int8|int32, n_zero, fingerprint, narrow).

    Fuses delta_quantize + fingerprint(p2) into a single HBM pass (9 bytes
    per fp32 param vs 16 unfused; §Perf-C) and narrows q to int8 when every
    value fits; tensors with overflow fall back to int32 (`narrow=False`).
    ``with_fingerprint=False`` elides the fingerprint (returned as None) —
    the commit pipeline keys objects by SHA-256 and never reads it, and on
    the ref backend the fingerprint is a separate full pass worth skipping.
    """
    backend = backend or default_backend()
    p1 = jnp.asarray(p1)
    p2 = jnp.asarray(p2)
    orig_shape = p1.shape
    fp = fingerprint(p2, backend=backend) if with_fingerprint else None
    if backend == "ref":
        from repro.kernels.snapshot_fused import snapshot_fused_ref
        q8, zeros, overflow = snapshot_fused_ref(jnp.ravel(p1), jnp.ravel(p2),
                                                 eps)
        if int(overflow) > 0:
            q, nz = delta_quantize(p1, p2, eps=eps, backend=backend)
            return q, nz, fp, False
        return (jnp.asarray(q8).reshape(orig_shape), int(zeros), fp, True)

    from repro.kernels.snapshot_fused import snapshot_fused_2d
    a, n = _to_2d(p1.astype(jnp.float32))
    b, _ = _to_2d(p2.astype(jnp.float32))
    q2d, zeros, overflow, _fp_part = snapshot_fused_2d(
        a, b, eps=eps, block_rows=_block_rows(a.shape[0]),
        interpret=(backend == "interpret"))
    if int(jnp.sum(overflow)) > 0:
        q, nz = delta_quantize(p1, p2, eps=eps, backend=backend)
        return q, nz, fp, False
    q = q2d.reshape(-1)[:n].reshape(orig_shape)
    n_pad = a.size - n
    nz = int(jnp.sum(zeros)) - n_pad
    return q, nz, fp, True


def fingerprint(x, backend: Optional[str] = None) -> int:
    """64-bit content fingerprint (python int). Includes shape/dtype salt so
    reshaped or recast tensors don't alias (mirrors SHA-256 keying in the CAS)."""
    backend = backend or default_backend()
    x = jnp.asarray(x)
    bits, _ = _bits_2d(x)
    if backend == "ref":
        pair = _fingerprint_ref_2d(bits)
    else:
        pair = fingerprint_2d(bits, block_rows=_block_rows(bits.shape[0]),
                              interpret=(backend == "interpret"))
    h1, h2 = int(pair[0]), int(pair[1])
    salt = hash((x.shape, str(x.dtype))) & 0xFFFFFFFF
    return ((h1 ^ salt) << 32) | h2


__all__ = ["delta_quantize", "dequant_apply", "chain_apply", "fingerprint",
           "default_backend"]
