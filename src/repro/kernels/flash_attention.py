"""Pallas TPU kernel: flash attention forward (§Perf iteration 3).

Under XLA, chunked attention materializes every (qc x kc) score/prob tile to
HBM between the two dots — measured as the dominant memory term on all dense
prefill/train cells (e.g. deepseek prefill: 62L x 1024 steps x 100s-of-MB
tiles). This kernel keeps the tiles in VMEM: HBM traffic collapses to
q + out + n_q·(k + v) reads — the flash contract.

Layout: q (B, Hq, Sq, hd), k/v (B, Hkv, Skv, hd). Grid (B, Hq, n_q, n_k); the
last grid dim is sequential on TPU, so the output block (indexed by (b,h,qi),
constant over ki) accumulates across kv steps with VMEM scratch carrying the
online-softmax statistics. GQA folds into the k/v index map (h -> h // G).
Causal / sliding-window / prefix-LM masking is computed in-kernel from block
positions; fully-masked (future) blocks are skipped with @pl.when.

Forward only: serving (prefill/decode) needs no gradient, which is exactly
where the 32k-context cells live. Training keeps the XLA chunked path (bf16
score tiles); a custom-vjp flash backward is future work (DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  qc: int, kc: int, n_k: int, causal: bool, window: int,
                  prefix_len: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * qc
    k_start = ki * kc
    # a block is live unless it is entirely in the causal future
    live = True
    if causal:
        live = k_start <= q_start + qc - 1

    @pl.when(live if isinstance(live, bool) else live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (qc, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (kc, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (qc, kc)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
        ok = jnp.ones((qc, kc), jnp.bool_)
        if causal:
            ok = k_pos <= q_pos
            if prefix_len > 0:
                ok = ok | (k_pos < prefix_len)
        if window > 0:
            ok = ok & (q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        m_scr[...] = m_new
        l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "prefix_len",
                                             "qc", "kc", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, prefix_len: int = 0,
                    qc: int = 512, kc: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Skv, hd) -> (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    qc = min(qc, Sq)
    while Sq % qc:
        qc -= 1
    kc = min(kc, Skv)
    while Skv % kc:
        kc -= 1
    n_q, n_k = Sq // qc, Skv // kc
    grid = (B, Hq, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, qc=qc, kc=kc, n_k=n_k, causal=causal, window=window,
        prefix_len=prefix_len, scale=hd ** -0.5)
    try:
        from jax.experimental.pallas import tpu as pltpu
        scratch = [pltpu.VMEM((qc,), jnp.float32),
                   pltpu.VMEM((qc,), jnp.float32),
                   pltpu.VMEM((qc, hd), jnp.float32)]
    except ImportError:  # pragma: no cover
        scratch = [pl.VMEM((qc,), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qc, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kc, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kc, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qc, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, *, causal=True, window=0, prefix_len=0):
    """Dense jnp oracle (small shapes only)."""
    B, Hq, Sq, hd = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * hd ** -0.5, kf)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok = k_pos <= q_pos
        if prefix_len > 0:
            ok = ok | (k_pos < prefix_len)
    if window > 0:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def hbm_bytes(B, Hq, Hkv, Sq, Skv, hd, dtype_bytes=2, qc=512):
    """The kernel's HBM traffic contract (per the BlockSpecs): q and out once,
    k and v once per q block."""
    n_q = max(Sq // min(qc, Sq), 1)
    q_out = 2 * B * Hq * Sq * hd * dtype_bytes
    kv = 2 * B * Hkv * Skv * hd * dtype_bytes * n_q
    return q_out + kv
