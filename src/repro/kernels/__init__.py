"""Pallas TPU kernels for MGit's storage hot path (+ jnp oracles).

- ``delta_quantize`` / ``dequant_apply``: Algorithm 1's lossy delta step, fused.
- ``fingerprint``: on-device content-hash candidate detection for CAS dedup.

``ops`` dispatches pallas (TPU) / interpret (tests) / ref (CPU oracle).
"""

from repro.kernels import ops, ref
from repro.kernels.ops import (default_backend, delta_quantize, dequant_apply,
                               fingerprint)

__all__ = ["ops", "ref", "default_backend", "delta_quantize", "dequant_apply",
           "fingerprint"]
