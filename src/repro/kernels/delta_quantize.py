"""Pallas TPU kernel: fused delta + quantize (Algorithm 1's lossy step).

The storage hot path runs `floor((p1 - p2)/scale + 0.5)` over every parameter
of every checkpoint. Arithmetic intensity is ~3 FLOPs / 12 bytes ≈ 0.25 —
firmly HBM-bandwidth bound — so the only thing that matters is touching each
byte exactly once: one fused pass, no intermediate Δp materialized in HBM.

The kernel additionally emits a per-tile zero count. The host uses these
counts to *pre-filter* tiles for lossless compression (predicted ratio <= 1 →
don't ship the tile to the host compressor), which is the paper's "reject if
no saving" check pushed down to tile granularity on-device.

Layout: inputs are flattened and padded to (rows, LANE_COLS) where LANE_COLS
is a multiple of 128 (TPU lane width). Grid is 1-D over row-blocks; each
program reads two (BLOCK_ROWS, LANE_COLS) VMEM tiles and writes one int32
tile + one zero-count scalar. ``eps`` (hence the scale) is a compile-time
constant — it is a per-lineage-graph config value, so specializing the kernel
on it costs one compile per distinct eps and saves a scalar operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import quant_scale

# 8 sublanes x 128 lanes is the float32 VREG tile; 256x1024 keeps VMEM use
# ~3 MB for (p1, p2, q) while giving the DMA engine long contiguous reads.
BLOCK_ROWS = 256
LANE_COLS = 1024


def _delta_quantize_kernel(p1_ref, p2_ref, q_ref, zeros_ref, *, inv_scale: float):
    d = p1_ref[...].astype(jnp.float32) - p2_ref[...].astype(jnp.float32)
    q = jnp.floor(d * inv_scale + 0.5).astype(jnp.int32)
    q_ref[...] = q
    zeros_ref[0] = jnp.sum(q == 0, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def delta_quantize_2d(p1: jnp.ndarray, p2: jnp.ndarray, eps: float = 1e-4,
                      block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """p1, p2: (rows, cols) with rows % block_rows == 0, cols % 128 == 0.

    Returns (q int32 (rows, cols), per-block zero counts (rows//block_rows,)).
    """
    rows, cols = p1.shape
    grid = (rows // block_rows,)
    kernel = functools.partial(_delta_quantize_kernel,
                               inv_scale=1.0 / quant_scale(eps))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.int32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(p1, p2)


def _dequant_apply_kernel(p1_ref, q_ref, out_ref, *, scale: float):
    out = p1_ref[...].astype(jnp.float32) - q_ref[...].astype(jnp.float32) * scale
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def dequant_apply_2d(p1: jnp.ndarray, q: jnp.ndarray, eps: float = 1e-4,
                     block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """Reconstruct child tile-wise: p2' = p1 - q * scale."""
    rows, cols = p1.shape
    grid = (rows // block_rows,)
    kernel = functools.partial(_dequant_apply_kernel, scale=quant_scale(eps))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), p1.dtype),
        interpret=interpret,
    )(p1, q)


__all__ = ["delta_quantize_2d", "dequant_apply_2d", "BLOCK_ROWS", "LANE_COLS",
           "quant_scale"]
