"""Pure-jnp oracles for the storage-path kernels.

These define the EXACT semantics the Pallas kernels must reproduce (tests
assert allclose/exact-equal across shape & dtype sweeps). They are also the
runtime implementation on CPU hosts, where Pallas would only run in interpret
mode (slow); ``ops.py`` dispatches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Quantization scale for error bound eps (paper §4): Δq = floor(Δp / (2·log1p(eps)) + 0.5)
def quant_scale(eps: float) -> float:
    return 2.0 * float(np.log1p(eps))


def delta_quantize_ref(p1: jnp.ndarray, p2: jnp.ndarray, eps: float = 1e-4):
    """Quantized delta between parent p1 and child p2 (paper Algorithm 1).

    Returns (q int32 array, zero count). Computation is in float32 regardless
    of input dtype so bf16 checkpoints quantize identically to f32 ones.
    """
    scale = quant_scale(eps)
    d = p1.astype(jnp.float32) - p2.astype(jnp.float32)
    q = jnp.floor(d / scale + 0.5).astype(jnp.int32)
    return q, jnp.sum(q == 0, dtype=jnp.int32)


def dequant_apply_ref(p1: jnp.ndarray, q: jnp.ndarray, eps: float = 1e-4,
                      out_dtype=None) -> jnp.ndarray:
    """Reconstruct the child: p2' = p1 - dequantize(q)."""
    scale = quant_scale(eps)
    out = p1.astype(jnp.float32) - q.astype(jnp.float32) * scale
    return out.astype(out_dtype or p1.dtype)


# -- fingerprint -------------------------------------------------------------
# Order-sensitive 2x32-bit mixing hash: each element is mixed with its global
# position, partial sums wrap mod 2^32. Sum-combining makes the hash
# tile-decomposable (any tiling yields the same result), which is what lets
# the Pallas kernel parallelize over VMEM tiles and tree-combine.
FP_C1 = np.uint32(0x9E3779B1)  # golden-ratio constant
FP_C2 = np.uint32(0x85EBCA77)
FP_C3 = np.uint32(0xC2B2AE3D)


def _mix(bits: jnp.ndarray, idx: jnp.ndarray):
    x = (bits * FP_C1) ^ (idx * FP_C2)
    x = x * FP_C3
    h1 = x ^ (x >> 15)
    y = (bits + idx) * FP_C2
    h2 = y ^ (y >> 13)
    return h1, h2


def fingerprint_ref(x: jnp.ndarray) -> jnp.ndarray:
    """64-bit content fingerprint as a (2,) uint32 array [h1, h2]."""
    flat = jnp.ravel(x)
    if flat.dtype == jnp.float32:
        bits = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    elif flat.dtype == jnp.bfloat16 or flat.dtype == jnp.float16:
        bits = jax.lax.bitcast_convert_type(flat, jnp.uint16).astype(jnp.uint32)
    elif flat.dtype in (jnp.int32, jnp.uint32):
        bits = flat.astype(jnp.uint32)
    else:
        bits = jax.lax.bitcast_convert_type(
            flat.astype(jnp.float32), jnp.uint32)
    idx = jnp.arange(flat.shape[0], dtype=jnp.uint32)
    h1, h2 = _mix(bits, idx)
    return jnp.stack([jnp.sum(h1, dtype=jnp.uint32), jnp.sum(h2, dtype=jnp.uint32)])
