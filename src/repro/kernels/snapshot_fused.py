"""Pallas TPU kernel: FUSED checkpoint snapshot pass (§Perf-C optimization).

The paper's storage pipeline runs, per checkpoint tensor:
    (1) delta_quantize(p_prev, p_new)   reads p_prev, p_new; writes q (int32)
    (2) fingerprint(p_new)              reads p_new again
i.e. 16 bytes of HBM traffic per fp32 parameter. This kernel fuses both into
ONE streaming pass and narrows q to int8 (training-step deltas quantize to
tiny integers; a per-tile overflow flag routes rare wide tiles to the int32
fallback):

    traffic per param: 4 (p_prev) + 4 (p_new) + 1 (q int8) = 9 bytes -> 1.78x
    less HBM time on the checkpoint hot path, plus a 4x smaller buffer for
    the host's lossless codec.

Outputs per tile: q (int8), zero count, overflow flag, fingerprint partial
(2 x uint32). All tile-decomposable; ops.py combines.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import FP_C1, FP_C2, FP_C3, quant_scale

BLOCK_ROWS = 256
LANE_COLS = 1024


def _snapshot_kernel(p1_ref, p2_ref, q_ref, zeros_ref, ovf_ref, fp_ref, *,
                     inv_scale: float, cols: int, block_rows: int):
    i = pl.program_id(0)
    p1 = p1_ref[...].astype(jnp.float32)
    p2 = p2_ref[...].astype(jnp.float32)

    # --- delta + quantize + int8 narrowing -------------------------------
    q32 = jnp.floor((p1 - p2) * inv_scale + 0.5).astype(jnp.int32)
    q8 = jnp.clip(q32, -127, 127)
    ovf_ref[0] = jnp.sum(q32 != q8, dtype=jnp.int32)   # wide tile -> fallback
    q_ref[...] = q8.astype(jnp.int8)
    zeros_ref[0] = jnp.sum(q32 == 0, dtype=jnp.int32)

    # --- fingerprint of p2 (the new params), same mix as fingerprint.py --
    bits = jax.lax.bitcast_convert_type(p2, jnp.uint32)
    base = i * block_rows * cols
    row = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 1)
    idx = jnp.uint32(base) + row * jnp.uint32(cols) + col
    x = (bits * FP_C1) ^ (idx * FP_C2)
    x = x * FP_C3
    h1 = x ^ (x >> 15)
    y = (bits + idx) * FP_C2
    h2 = y ^ (y >> 13)
    fp_ref[0, 0] = jnp.sum(h1, dtype=jnp.uint32)
    fp_ref[0, 1] = jnp.sum(h2, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def snapshot_fused_2d(p1: jnp.ndarray, p2: jnp.ndarray, eps: float = 1e-4,
                      block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """p1 (prev), p2 (new): (rows, cols) f32, rows % block_rows == 0.

    Returns (q int8, per-tile zeros, per-tile overflow counts, fp partials).
    """
    rows, cols = p1.shape
    grid = (rows // block_rows,)
    kernel = functools.partial(_snapshot_kernel,
                               inv_scale=1.0 / quant_scale(eps),
                               cols=cols, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.int8),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
            jax.ShapeDtypeStruct((grid[0], 2), jnp.uint32),
        ],
        interpret=interpret,
    )(p1, p2)


def snapshot_fused_ref(p1: jnp.ndarray, p2: jnp.ndarray, eps: float = 1e-4):
    """jnp oracle with identical semantics (flat tensors of any shape)."""
    from repro.kernels import ref as _ref
    q32, _ = _ref.delta_quantize_ref(p1, p2, eps)
    q8 = jnp.clip(q32, -127, 127).astype(jnp.int8)
    overflow = jnp.sum(q32 != q8.astype(jnp.int32), dtype=jnp.int32)
    zeros = jnp.sum(q32 == 0, dtype=jnp.int32)
    return q8, zeros, overflow


__all__ = ["snapshot_fused_2d", "snapshot_fused_ref", "BLOCK_ROWS", "LANE_COLS"]
