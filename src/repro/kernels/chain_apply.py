"""Pallas TPU kernel: fused delta-chain application (DESIGN.md §10.2).

Checkout of a depth-k delta chain reduces, for same-eps float32 segments, to

    out = base - (q_1 + q_2 + ... + q_k) * scale

because dequant is linear in q at fixed eps and int32 sums are exact. Done
hop-by-hop that is k full HBM round-trips of the (tensor-sized) intermediate
value: 12k bytes of traffic per fp32 param. This kernel fuses the whole
segment into ONE streaming pass — each program reads its base tile plus the
k stacked quantized-delta tiles, reduces them in VMEM (int32, exact), and
writes one output tile:

    traffic per param: 4 (base) + 4k (q stack) + 4 (out) vs 12k hop-by-hop
    -> ~3x less HBM time for deep chains, and no intermediate tensor ever
    exists in HBM.

The segment depth ``k`` is a compile-time constant (one specialization per
distinct chain depth — chains are bounded by ``max_chain_depth``, so the
compile cache stays small). ``eps`` is compile-time for the same reason as
``delta_quantize``.

Layout matches the other storage kernels: tensors are flattened and padded
to (rows, LANE_COLS); the q stack is (k, rows, cols). The grid is 1-D over
row blocks; every program sees the full k-extent of its tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import quant_scale

BLOCK_ROWS = 256
LANE_COLS = 1024


def _chain_apply_kernel(base_ref, qs_ref, out_ref, *, scale: float):
    total = jnp.sum(qs_ref[...].astype(jnp.int32), axis=0)  # exact int32
    out_ref[...] = (base_ref[...].astype(jnp.float32)
                    - total.astype(jnp.float32) * scale)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def chain_apply_2d(base: jnp.ndarray, qs: jnp.ndarray, eps: float = 1e-4,
                   block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """base: (rows, cols) f32; qs: (k, rows, cols) int32/int8.

    rows % block_rows == 0, cols % 128 == 0. Returns f32 (rows, cols):
    ``base - sum_k(qs) * scale`` in one fused pass.
    """
    rows, cols = base.shape
    k = qs.shape[0]
    grid = (rows // block_rows,)
    kernel = functools.partial(_chain_apply_kernel, scale=quant_scale(eps))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((k, block_rows, cols), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(base, qs)


def chain_apply_ref(base: jnp.ndarray, qs: jnp.ndarray,
                    eps: float = 1e-4) -> jnp.ndarray:
    """jnp oracle with identical semantics (any matching shapes)."""
    total = jnp.sum(jnp.asarray(qs, dtype=jnp.int32), axis=0)
    return (jnp.asarray(base, dtype=jnp.float32)
            - total.astype(jnp.float32) * quant_scale(eps))


__all__ = ["chain_apply_2d", "chain_apply_ref", "BLOCK_ROWS", "LANE_COLS"]
