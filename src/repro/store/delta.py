"""Delta compression between related models (paper §4, Algorithm 1).

Parent and child need not share an architecture: an LCS over the two models'
parameter sequences (in layer-graph topological order, items equal iff
shape+dtype match) yields the parameter mapping; matched pairs are quantized
(`repro.kernels.ops.delta_quantize`, the Pallas-accelerated hot path) and
losslessly compressed. Compression is *accepted* only if it actually saves
bytes AND, when tests are registered, the reconstructed model's scores stay
within ``t_thr`` of the original — otherwise the uncompressed tensor is kept.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.artifact import ModelArtifact
from repro.kernels import ops
from repro.store.codecs import get_codec


# ---------------------------------------------------------------------------
# LCS parameter matching
# ---------------------------------------------------------------------------

def _signature(arr: np.ndarray) -> Tuple:
    return (tuple(np.shape(arr)), str(np.asarray(arr).dtype))


def _signature_of(artifact: ModelArtifact, key: str) -> Tuple:
    """(shape, dtype) signature WITHOUT materializing lazy parameters."""
    params = artifact.params
    spec_of = getattr(params, "spec_of", None)
    if spec_of is not None:
        shape, dtype = spec_of(key)
        return (tuple(shape), str(dtype))
    return _signature(params[key])


def _ordered_keys(artifact: ModelArtifact) -> List[str]:
    """Param keys in layer-graph topological order (fallback: dict order)."""
    try:
        keys = [f"{l}/{p}" for (l, p) in artifact.graph.param_names()]
        missing = [k for k in artifact.params if k not in set(keys)]
        return [k for k in keys if k in artifact.params] + missing
    except Exception:
        return list(artifact.params)


def lcs_param_matching(parent: ModelArtifact, child: ModelArtifact
                       ) -> List[Tuple[str, str]]:
    """Longest common subsequence over (shape, dtype) signatures.

    Returns [(parent_key, child_key), ...]. For identical architectures this
    reduces to position-wise matching of corresponding layers (paper §4).
    """
    pk = _ordered_keys(parent)
    ck = _ordered_keys(child)
    ps = [_signature_of(parent, k) for k in pk]
    cs = [_signature_of(child, k) for k in ck]
    if ps == cs:  # common fast path: same architecture
        return list(zip(pk, ck))

    # integer-encode signatures, then numpy row-DP (O(n*m) cells)
    vocab: Dict[Tuple, int] = {}
    for s in ps + cs:
        vocab.setdefault(s, len(vocab))
    a = np.array([vocab[s] for s in ps], dtype=np.int32)
    b = np.array([vocab[s] for s in cs], dtype=np.int32)
    n, m = len(a), len(b)
    dp = np.zeros((n + 1, m + 1), dtype=np.int32)
    for i in range(1, n + 1):
        match = (b == a[i - 1])
        take = dp[i - 1, :-1] + match
        dp[i, 1:] = np.maximum(dp[i - 1, 1:], take)
        np.maximum.accumulate(dp[i], out=dp[i])
    # backtrack
    pairs: List[Tuple[str, str]] = []
    i, j = n, m
    while i > 0 and j > 0:
        if a[i - 1] == b[j - 1] and dp[i, j] == dp[i - 1, j - 1] + 1:
            pairs.append((pk[i - 1], ck[j - 1]))
            i -= 1
            j -= 1
        elif dp[i - 1, j] >= dp[i, j - 1]:
            i -= 1
        else:
            j -= 1
    pairs.reverse()
    return pairs


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ParamDelta:
    child_key: str
    parent_key: str
    blob: bytes
    codec: str
    eps: float
    shape: Tuple[int, ...]
    dtype: str
    raw_bytes: int          # uncompressed child tensor size
    qdtype: str = "int32"   # int8 when the fused kernel narrowed (§Perf-C)

    @property
    def saving(self) -> float:
        return self.raw_bytes / max(len(self.blob), 1)


@dataclasses.dataclass
class CompressResult:
    accepted: bool
    deltas: Dict[str, ParamDelta]          # child_key -> delta (accepted only)
    reconstructed: ModelArtifact           # m2' (== m2 when nothing accepted)
    test_deltas: Dict[str, float]
    raw_bytes: int
    compressed_bytes: int
    # content hashes of reconstructed delta params, precomputed on the
    # pipeline's worker threads (commit reuses them instead of re-hashing)
    param_hashes: Dict[str, str] = dataclasses.field(default_factory=dict)
    # open-segment fold states of the reconstructed params (opaque to this
    # module; the store installs them in its FoldCache at commit so the
    # NEXT commit's parent materialization is pure cache hits)
    fold_states: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def ratio(self) -> float:
        return self.raw_bytes / max(self.compressed_bytes, 1)


def delta_compression(m2: ModelArtifact, m1: ModelArtifact,
                      t_thr: float = 0.5, eps: float = 1e-4,
                      codec: str = "lzma", tests: Sequence = (),
                      per_param: bool = True,
                      zero_frac_prefilter: float = 0.0,
                      backend: Optional[str] = None) -> CompressResult:
    """Paper Algorithm 1 — compress m1 - m2 (m1 parent, m2 child).

    ``per_param=True`` accepts/rejects each tensor independently (beyond-paper
    refinement); ``False`` reproduces the paper's whole-model accept/reject.
    ``zero_frac_prefilter``: skip host compression when the on-device zero
    fraction predicts a ratio <= 1 (DESIGN.md §3 pre-filter).
    """
    cod = get_codec(codec)
    pairs = lcs_param_matching(m1, m2)
    candidates: Dict[str, ParamDelta] = {}
    recon_params: Dict[str, np.ndarray] = {}

    for pkey, ckey in pairs:
        p1 = np.asarray(m1.params[pkey])
        p2 = np.asarray(m2.params[ckey])
        if p1.size == 0:
            continue
        # fused snapshot pass: quantized delta (int8-narrowed when it fits)
        # + zero stats + fingerprint, one HBM read of each input (§Perf-C)
        q, nz, _fp, _narrow = ops.snapshot_fused(p1, p2, eps=eps,
                                                 backend=backend)
        q = np.asarray(q)
        zero_frac = nz / q.size
        if zero_frac < zero_frac_prefilter:
            continue  # on-device pre-filter says "won't compress" — skip host work
        blob = cod.encode(q)
        delta = ParamDelta(child_key=ckey, parent_key=pkey, blob=blob,
                           codec=codec, eps=eps, shape=tuple(p2.shape),
                           dtype=str(p2.dtype), raw_bytes=int(p2.nbytes),
                           qdtype=str(q.dtype))
        if per_param and len(blob) >= p2.nbytes:
            continue  # no saving for this tensor
        candidates[ckey] = delta
        recon = np.asarray(ops.dequant_apply(p1, q, eps=eps, backend=backend,
                                             out_dtype=p2.dtype))
        recon_params[ckey] = recon.reshape(p2.shape)

    total_raw = m2.nbytes()
    delta_raw = sum(d.raw_bytes for d in candidates.values())
    delta_compressed = sum(len(d.blob) for d in candidates.values())
    storage_saving = delta_raw / max(delta_compressed, 1)

    if not candidates or (not per_param and storage_saving < 1.0):
        return CompressResult(False, {}, m2, {}, total_raw, total_raw)

    # m2' = m2 with the compressed params replaced by their reconstructions
    m2_prime = m2.replace_params(recon_params)

    test_deltas: Dict[str, float] = {}
    for t in tests:
        before = float(t.fn(m2))
        after = float(t.fn(m2_prime))
        test_deltas[t.name] = after - before
        if abs(after - before) > t_thr:
            # accuracy drop beyond threshold — reject compression entirely
            return CompressResult(False, {}, m2, test_deltas, total_raw, total_raw)

    compressed_total = (total_raw - delta_raw) + delta_compressed
    return CompressResult(True, candidates, m2_prime, test_deltas,
                          total_raw, compressed_total)


def host_snapshot(p1: np.ndarray, p2: np.ndarray, eps: float
                  ) -> Tuple[np.ndarray, int, bool]:
    """Numpy twin of ``ops.snapshot_fused`` (sans fingerprint).

    Returns ``(q int8|int32, n_zero, narrow)``, bit-identical to the jax
    ref kernel (both compute ``floor(f32(p1-p2)/f32(scale) + 0.5)`` with
    correctly-rounded f32 ops; asserted in ``tests/test_pipeline.py``) but
    with zero dispatch overhead — on CPU hosts the per-call jit dispatch
    dominates the arithmetic for typical layer-sized tensors, so the commit
    pipeline uses this path when no accelerator backend is configured."""
    from repro.kernels.ref import quant_scale
    scale = np.float32(quant_scale(eps))
    d = np.asarray(p1, dtype=np.float32) - np.asarray(p2, dtype=np.float32)
    q32 = np.floor(d / scale + np.float32(0.5)).astype(np.int32)
    nz = int((q32 == 0).sum())
    q8 = np.clip(q32, -127, 127)
    if bool((q32 == q8).all()):
        return q8.astype(np.int8), nz, True
    return q32, nz, False


def host_dequant(parent_value: np.ndarray, q: np.ndarray, eps: float,
                 out_dtype=None) -> np.ndarray:
    """Host-side dequant-apply: ``p2' = f32(p1) - f32(q) * f32(scale)``.

    Bit-identical to ``ops.dequant_apply(..., backend="ref")`` — both are
    single correctly-rounded f32 multiply+subtract per element (JAX's weak
    typing rounds the python-float scale to f32 exactly like the explicit
    ``np.float32`` here; ``tests/test_pipeline.py`` asserts the identity) —
    but with zero dispatch overhead, which is what the checkout/commit hot
    loops need on CPU hosts. Non-f32 ``out_dtype`` casts go through jax
    (ml_dtypes coverage, e.g. bf16) to keep rounding identical to the
    device path."""
    from repro.kernels.ref import quant_scale
    scale = np.float32(quant_scale(eps))
    out = (np.asarray(parent_value, dtype=np.float32)
           - np.asarray(q, dtype=np.float32) * scale)
    dt = np.dtype(out_dtype) if out_dtype is not None else np.float32
    if dt == np.float32:
        return out
    try:
        return out.astype(dt)
    except TypeError:
        return np.asarray(ops.dequant_apply(parent_value, q, eps=eps,
                                            backend="ref",
                                            out_dtype=out_dtype))


def decode_q(delta_or_entry, blob) -> np.ndarray:
    """Decode one delta blob to its quantized array (reshaped).

    The stored dtype (int8 when the fused kernel narrowed) is preserved —
    int8→f32 and int8→int32-accum conversions are exact, so downstream
    dequant/fold never needs the 4x-larger int32 copy. ``blob`` may be any
    buffer (bytes or a zero-copy CAS view)."""
    codec = delta_or_entry.codec
    shape = tuple(delta_or_entry.shape)
    qdtype = getattr(delta_or_entry, "qdtype", "int32")
    n = int(np.prod(shape)) if shape else 1
    return get_codec(codec).decode(blob, n, dtype=qdtype).reshape(shape)


def decompress_param(parent_value: np.ndarray, delta: ParamDelta,
                     backend: Optional[str] = None) -> np.ndarray:
    """Invert one ParamDelta given the materialized parent tensor."""
    q = decode_q(delta, delta.blob)
    if backend is None or backend == "ref":
        return host_dequant(parent_value, q, eps=delta.eps,
                            out_dtype=delta.dtype).reshape(delta.shape)
    out = ops.dequant_apply(np.asarray(parent_value), q, eps=delta.eps,
                            backend=backend, out_dtype=delta.dtype)
    return np.asarray(out).reshape(delta.shape).astype(delta.dtype)
