"""Continuous checkpointing — MGit versioning at training speed (§15).

Every ``save(step, state)`` cut becomes a *version node* in a lineage graph
whose storage flows through the step-delta commit engine
(:meth:`ArtifactStore.commit_step`): consecutive training states differ by
one optimizer excursion, so each commit moves only the changed leaves and
stores them as deltas against the previous step's committed truth.

The manager layers four things over the store engine:

* **fingerprint short-circuit** — leaves above ``fingerprint_min_bytes``
  are fingerprinted before transfer (device-side via the fused kernel on
  accelerators — 8 bytes cross the link instead of the tensor — or a
  host CRC pair on CPU). A leaf whose fingerprint matches the last
  enqueued snapshot is *skipped*: no host copy, no encode, its manifest
  entry re-references the parent's.
* **tiers** — ``tier="exact"`` (default) stores lossless bitpattern
  deltas; resume is bit-identical. ``tier="lossy"`` stores int8
  error-feedback-grid deltas (``repro.dist.compression.ef_eps``) with an
  unquantized keyframe every ``keyframe_every`` commits (bit-exact up to
  the log-domain roundtrip on nu leaves, ~1 ulp); intermediate
  manifests carry ``lossy: true`` and ``restore`` resolves to the nearest
  exact ancestor unless ``allow_lossy``. In the lossy tier AdamW second
  moments (``state_regime == "moment2"``) are committed in the log domain
  (``log1p``/``expm1``), turning uniform quantization into relative
  precision for the all-positive, high-dynamic-range nu leaves.
* **double-buffered async commit** — ``save()`` never blocks on storage:
  one commit may be in flight while one snapshot waits; enqueueing onto
  an occupied slot *coalesces* (the waiting snapshot is replaced by the
  newer one, with skip-sets merged so no stale leaf survives). Training
  therefore never stalls more than one commit behind, and backpressure
  degrades commit *frequency*, not step time.
* **crash atomicity** — a journal records the in-flight commit; the
  lineage file is written once per commit (fsync'd, atomic), *after* the
  manifest is durable. Recovery on construction rolls back any orphaned
  manifest, so a kill at any point resumes from the previous committed
  step with a clean ``fsck``.

Fault tolerance beyond that is unchanged from the snapshot era:
``restore(verify=True)`` recomputes content hashes, and
``restore_sharded`` re-lays the checkpoint out on a *different* mesh.
"""

from __future__ import annotations

import json
import os
import threading
import time
import zlib
from typing import Any, Dict, FrozenSet, Optional, Tuple

import jax
import numpy as np

from repro.common.hashing import tensor_hash
from repro.core.graphir import LayerGraph, LayerNode
from repro.core.lineage import LineageGraph
from repro.obs import REGISTRY, span
from repro.optim.adamw import state_regime
from repro.store.artifact_store import ArtifactStore

#: Histogram buckets for save()-side blocking time: sub-ms (pure enqueue)
#: through seconds (blocking full snapshot).
_OVERHEAD_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
                     0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0)

#: save()-side blocking seconds per checkpoint cut, labeled by tier.
#: Module-level registration: ``repro.store`` imports this module, so the
#: family is visible to `cli obs metrics` and both daemons' /api/metrics
#: in any process that touches the store layer.
CKPT_OVERHEAD = {
    tier: REGISTRY.histogram(
        "checkpoint_overhead_seconds",
        help="training-loop blocking time spent in CheckpointManager.save",
        buckets=_OVERHEAD_BUCKETS, tier=tier)
    for tier in ("exact", "lossy")
}

#: Engine accounting, scrapeable as mgit_ckpt_* (DESIGN.md §15).
CKPT_STATS = REGISTRY.group(
    "mgit_ckpt",
    keys=("saves", "commits", "coalesced", "leaves_skipped",
          "leaves_transferred", "journal_rollbacks"),
    help="continuous checkpointing engine accounting")


def _keystr(path) -> str:
    """``jax.tree_util.keystr(path, simple=True, separator="/")`` compat.

    The ``simple``/``separator`` kwargs only exist on newer JAX; render the
    key path entries directly so any 0.4.x works."""
    parts = []
    for entry in path:
        for attr in ("key", "name", "idx"):
            v = getattr(entry, attr, None)
            if v is not None:
                parts.append(str(v))
                break
        else:
            parts.append(str(entry).strip("[].'\""))
    return "/".join(parts)


def flatten_state(state) -> Dict[str, np.ndarray]:
    """Pytree -> flat {path: host ndarray}. Gathers from device (blocking)."""
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    for path, leaf in leaves:
        key = _keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def unflatten_state(template, flat: Dict[str, np.ndarray]):
    """Inverse of flatten_state given a structure/ShapeDtypeStruct template."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _keystr(path)
        value = flat[key]
        dtype = getattr(leaf, "dtype", None)
        if dtype is not None and str(value.dtype) != str(dtype):
            value = value.astype(dtype)
        shape = getattr(leaf, "shape", None)
        if shape is not None and tuple(value.shape) != tuple(shape):
            value = np.asarray(value).reshape(shape)  # stored scalars are 1-D
        leaves.append(value)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def spec_graph(specs: Dict[str, Tuple[Tuple[int, ...], str]],
               model_type: str) -> LayerGraph:
    """Chain LayerGraph over (shape, dtype) specs keyed by state path."""
    g = LayerGraph()
    prev = None
    for key, (shape, dtype) in specs.items():
        layer, _, param = key.rpartition("/")
        layer, param = layer or key, param or "value"
        if layer not in g.nodes:
            g.add_node(LayerNode(layer, "state"))
            if prev is not None:
                g.add_edge(prev, layer)
            prev = layer
        g.nodes[layer].params[param] = (tuple(shape), str(dtype))
    return g


def state_graph(flat: Dict[str, np.ndarray], model_type: str) -> LayerGraph:
    """Chain LayerGraph over state entries (checkpoints are sequenced by path)."""
    return spec_graph(
        {k: (tuple(np.shape(v)), str(np.asarray(v).dtype))
         for k, v in flat.items()}, model_type)


class CheckpointManager:
    def __init__(self, directory: Optional[str], model_name: str = "model",
                 codec: str = "lzma", eps: float = 1e-4,
                 delta_enabled: bool = True, async_save: bool = True,
                 max_chain_depth: int = 8,
                 store: Optional[ArtifactStore] = None,
                 lineage: Optional[LineageGraph] = None,
                 tier: str = "exact", keyframe_every: int = 8,
                 fingerprint_min_bytes: int = 1 << 16,
                 fingerprint_device: Optional[bool] = None) -> None:
        if tier not in ("exact", "lossy"):
            raise ValueError(f"unknown checkpoint tier {tier!r}")
        self.model_name = model_name
        self.store = store or ArtifactStore(
            root=directory, codec=codec, eps=eps, t_thr=float("inf"),
            delta_enabled=delta_enabled, max_chain_depth=max_chain_depth)
        self.lineage = lineage or LineageGraph(path=directory,
                                               store=self.store)
        self.async_save = async_save
        self.tier = tier
        self.keyframe_every = max(1, int(keyframe_every))
        self.fingerprint_min_bytes = int(fingerprint_min_bytes)
        self.fingerprint_device = fingerprint_device
        self._journal_path = (os.path.join(directory, "ckpt_journal.json")
                              if directory else None)
        # double-buffer slots: at most one commit in flight, one pending
        self._cond = threading.Condition()
        self._pending: Optional[tuple] = None
        self._inflight = False
        self._worker: Optional[threading.Thread] = None
        self._worker_dead = True
        self._closed = False
        self._error: Optional[BaseException] = None
        # step-delta engine state (worker-thread owned after __init__)
        self._last_fps: Dict[str, int] = {}
        self._prev_flat: Optional[Dict[str, np.ndarray]] = None
        self._prev_flat_ref: Optional[str] = None
        self._commits = 0
        self._recover_journal()

    # -- naming ----------------------------------------------------------------
    def _node_name(self, step: int) -> str:
        return f"{self.model_name}/step{step}"

    def _steps(self):
        return [
            int(n.rsplit("step", 1)[1]) for n in self.lineage.nodes
            if n.startswith(self.model_name + "/step")
            and self.lineage.nodes[n].artifact_ref is not None
        ]

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return max(steps) if steps else None

    # -- crash recovery ----------------------------------------------------------
    def _journal_write(self, payload: Dict[str, Any]) -> None:
        if self._journal_path is None:
            return
        tmp = self._journal_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._journal_path)

    def _journal_clear(self) -> None:
        if self._journal_path and os.path.exists(self._journal_path):
            os.remove(self._journal_path)

    def _recover_journal(self) -> None:
        """Roll back a commit interrupted between manifest land and the
        lineage pointer move (DESIGN.md §15: the LATEST-equivalent here is
        the lineage file, written once per commit AFTER the manifest is
        durable)."""
        if not self._journal_path or not os.path.exists(self._journal_path):
            return
        try:
            with open(self._journal_path) as f:
                j = json.load(f)
        except Exception:
            j = {}
        ref = j.get("ref")
        stale = j.get("stale")
        referenced = {n.artifact_ref for n in self.lineage.nodes.values()}
        if ref is not None and ref not in referenced:
            # manifest (possibly partially) landed but lineage never saw
            # it: drop the orphan so refcounts match the reachable graph
            self.store.release(ref)
            self.store.cas.flush()
            CKPT_STATS["journal_rollbacks"] += 1
        elif (ref is not None and stale is not None
              and stale not in referenced):
            # re-commit of an existing step where the lineage DID land on
            # the new manifest: the superseded one is now orphaned, and the
            # journal's presence proves its release never ran (_commit
            # releases only after clearing the journal) — finish it here
            self.store.release(stale)
            self.store.cas.flush()
            CKPT_STATS["journal_rollbacks"] += 1
        self._journal_clear()

    # -- snapshot (fingerprint short-circuit) -------------------------------------
    def _use_device_fp(self) -> bool:
        if self.fingerprint_device is not None:
            return self.fingerprint_device
        return jax.default_backend() != "cpu"

    @staticmethod
    def _host_fp(arr: np.ndarray) -> int:
        """64-bit host fingerprint: CRC32/Adler32 pair over the raw bytes,
        salted with shape+dtype. No jit dispatch — on CPU hosts the device
        kernel's dispatch overhead would exceed the hash itself."""
        a = np.ascontiguousarray(arr)
        view = a.view(np.uint8).reshape(-1)
        salt = repr((a.shape, str(a.dtype))).encode()
        return (zlib.crc32(view, zlib.crc32(salt)) << 32) | zlib.adler32(view)

    def _snapshot(self, state) -> Tuple[Dict[str, Optional[np.ndarray]],
                                        FrozenSet[str]]:
        """Flatten ``state``, skipping leaves whose fingerprint matches the
        last enqueued snapshot. Device fingerprints are computed BEFORE the
        host transfer — an unchanged leaf moves 8 bytes, not the tensor."""
        flat: Dict[str, Optional[np.ndarray]] = {}
        fps: Dict[str, int] = {}
        skip = set()
        device_fp = self._use_device_fp()
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        for path, leaf in leaves:
            key = _keystr(path)
            shape = tuple(np.shape(leaf))
            dt = getattr(leaf, "dtype", None)
            nbytes = (int(np.prod(shape, dtype=np.int64))
                      * np.dtype(dt).itemsize) if dt is not None else 0
            if nbytes < self.fingerprint_min_bytes:
                flat[key] = np.asarray(jax.device_get(leaf))
                continue
            if device_fp:
                from repro.kernels import ops
                fp = int(ops.fingerprint(leaf))
                fps[key] = fp
                if self._last_fps.get(key) == fp:
                    flat[key] = None
                    skip.add(key)
                    continue
                flat[key] = np.asarray(jax.device_get(leaf))
            else:
                arr = np.asarray(jax.device_get(leaf))
                fp = self._host_fp(arr)
                fps[key] = fp
                if self._last_fps.get(key) == fp:
                    flat[key] = None
                    skip.add(key)
                    continue
                flat[key] = arr
        self._last_fps = fps
        return flat, frozenset(skip)

    # -- save ---------------------------------------------------------------------
    def save(self, step: int, state: Any,
             blocking: Optional[bool] = None) -> str:
        """Snapshot ``state`` (pytree) as version ``step``. Returns node name.

        The fingerprint pass + device->host gather of changed leaves happens
        synchronously (the snapshot is immutable after that point); encode +
        IO runs on the worker thread. Async saves never block here: if a
        commit is already in flight AND one is pending, the pending snapshot
        is replaced (coalesce-to-latest) — the training loop stalls at most
        one commit behind storage."""
        self._check_error()
        t0 = time.perf_counter()
        name = self._node_name(step)
        with span("ckpt.snapshot", cat="ckpt", step=step,
                  model=self.model_name):
            flat, skip = self._snapshot(state)
        if blocking is None:
            blocking = not self.async_save
        if blocking:
            self._commit(step, name, flat, skip)
        else:
            self._enqueue((step, name, flat, skip))
        CKPT_STATS["saves"] += 1
        CKPT_STATS["leaves_skipped"] += len(skip)
        CKPT_STATS["leaves_transferred"] += len(flat) - len(skip)
        CKPT_OVERHEAD[self.tier].observe(time.perf_counter() - t0)
        return name

    @staticmethod
    def _merge(old: tuple, new: tuple) -> tuple:
        """Coalesce a pending snapshot with a newer one.

        The merged commit keeps the NEW step/values but may only skip a
        leaf that BOTH snapshots skipped: the eventual delta parent is the
        one the old snapshot was fingerprinted against, so a leaf that
        changed in between must ship the old snapshot's value (present
        there by construction — it wasn't skipped)."""
        _, _, old_flat, old_skip = old
        step, name, flat, skip = new
        merged_skip = frozenset(skip & old_skip)
        merged = dict(flat)
        for k in skip - merged_skip:
            merged[k] = old_flat[k]
        return (step, name, merged, merged_skip)

    def _enqueue(self, item: tuple) -> None:
        start = False
        with self._cond:
            if self._pending is not None:
                self._pending = self._merge(self._pending, item)
                CKPT_STATS["coalesced"] += 1
            else:
                self._pending = item
            self._cond.notify_all()
            if (self._worker_dead or self._worker is None
                    or not self._worker.is_alive()):
                self._worker_dead = False
                self._worker = threading.Thread(target=self._drain,
                                                daemon=True)
                start = True
        if start:
            self._worker.start()

    def _drain(self) -> None:
        while True:
            with self._cond:
                while self._pending is None:
                    if self._closed or not self._cond.wait(timeout=0.2):
                        if self._pending is None:  # idle or closing: die
                            self._worker_dead = True
                            return
                item, self._pending = self._pending, None
                self._inflight = True
            try:
                self._commit(*item)
            except BaseException as e:  # surfaced on next save()/wait()
                self._error = e
                with self._cond:
                    # a snapshot enqueued while this commit was failing
                    # skipped leaves against a baseline that never landed;
                    # its None leaves are unrecoverable, so committing it
                    # would silently re-reference stale parent values —
                    # drop it along with the baseline
                    self._pending = None
                # the fingerprint baseline now references a commit that
                # never landed — next save must transfer everything
                self._last_fps = {}
                self._prev_flat = None
            finally:
                with self._cond:
                    self._inflight = False
                    self._cond.notify_all()

    def wait(self) -> None:
        with self._cond:
            while self._pending is not None or self._inflight:
                self._cond.wait(timeout=0.05)
        self._check_error()

    def close(self) -> None:
        """Drain pending commits and surface any async failure."""
        self.wait()
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def _check_error(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    # -- commit -------------------------------------------------------------------
    def _commit(self, step: int, name: str,
                flat: Dict[str, Optional[np.ndarray]],
                skip: FrozenSet[str] = frozenset()) -> None:
        commit_tier = "exact"
        prev_step = None
        for s in self._steps():
            if s < step and (prev_step is None or s > prev_step):
                prev_step = s
        parent_ref = (self.lineage.nodes[self._node_name(prev_step)]
                      .artifact_ref if prev_step is not None else None)
        if (self.tier == "lossy" and parent_ref is not None
                and self._commits % self.keyframe_every != 0):
            commit_tier = "lossy"
        # Re-commit of an already-committed step (restore rolled back to an
        # exact ancestor, then training re-ran forward past it): the node's
        # current manifest is superseded and must be released once the
        # lineage points at the new one, or its refs leak (fsck
        # refcount_drift). The journal carries it so a crash after the
        # lineage save still releases it on recovery.
        stale_node = self.lineage.nodes.get(name)
        stale_ref = (stale_node.artifact_ref if stale_node is not None
                     else None)
        with span("ckpt.commit", cat="ckpt", step=step, tier=commit_tier):
            work, transforms = self._apply_transforms(flat)
            metadata: Dict[str, Any] = {"step": step}
            if commit_tier == "lossy":
                metadata["lossy"] = True
            if transforms:
                metadata["transforms"] = transforms
            self._journal_write({"name": name, "step": step, "ref": None,
                                 "stale": stale_ref})
            parent_manifest = (self.store.get_manifest(parent_ref)
                               if parent_ref else None)
            graph_json = None
            if (parent_manifest is None
                    or set(work) != set(parent_manifest["params"])):
                graph_json = self._graph_json(work, parent_manifest)
            ref = self.store.commit_step(
                name, work, parent_ref, skip=skip, tier=commit_tier,
                model_type=self.model_name, metadata=metadata,
                graph_json=graph_json,
                # the live-flat shortcut is only the parent's committed
                # truth when the parent IS the commit it was captured from
                # (not after a rollback re-commit, where prev_step jumps
                # back past the step _prev_flat came from)
                parent_hint=(self._prev_flat
                             if (self.tier == "exact"
                                 and parent_ref is not None
                                 and self._prev_flat_ref == parent_ref)
                             else None),
                flush=False)
            # journal carries the ref BEFORE the durability point: a crash
            # on either side of the flush leaves either nothing visible or
            # an orphan the journal can roll back
            self._journal_write({"name": name, "step": step, "ref": ref,
                                 "stale": stale_ref})
            with span("commit.pack_fsync", cat="store"):
                self.store.cas.flush()
            # one lineage save per commit: batch the node + version edge +
            # artifact pointer, then write the (fsync'd, atomic) file once.
            # The artifact_ref lands AFTER the version edge so the edge
            # hook never re-compresses a node that is already step-encoded.
            prev_autosave = self.lineage.autosave
            self.lineage.autosave = False
            try:
                node = self.lineage.add_node(None, name,
                                             model_type=self.model_name)
                # detach the superseded ref first so the version-edge hook
                # can never re-compress the manifest we're about to replace
                node.artifact_ref = None
                if prev_step is not None:
                    self.lineage.add_version_edge(
                        self._node_name(prev_step), name)
                node.artifact_ref = ref
            finally:
                self.lineage.autosave = prev_autosave
            self.lineage.save()
            self._journal_clear()
            if stale_ref is not None:
                # only AFTER the (fsync'd) lineage points at the new
                # manifest — releasing earlier could leave the durable
                # lineage referencing a released ref after a crash. Holds
                # for stale_ref == ref too (bit-identical re-commit): the
                # commit re-increffed every object the manifest owns, and
                # this release undoes exactly that duplicate set.
                self.store.release(stale_ref)
                self.store.cas.flush()
        self._commits += 1
        CKPT_STATS["commits"] += 1
        if self.tier == "exact":
            base = (self._prev_flat
                    if self._prev_flat is not None
                    and self._prev_flat_ref == parent_ref else {})
            self._prev_flat = {k: (v if v is not None else base.get(k))
                               for k, v in flat.items()}
            self._prev_flat_ref = ref

    def _apply_transforms(self, flat: Dict[str, Optional[np.ndarray]]
                          ) -> Tuple[Dict[str, Optional[np.ndarray]],
                                     Dict[str, str]]:
        """Per-regime leaf transforms (lossy tier only): AdamW nu commits
        as log1p(v) so the uniform int8 grid quantizes *relative* error —
        exactly what a smooth nonnegative second moment wants. Applied to
        keyframes too: the whole lossy chain lives in one domain, so
        consecutive hops stay small. Exact tier stores raw bits."""
        if self.tier != "lossy":
            return flat, {}
        work: Dict[str, Optional[np.ndarray]] = {}
        transforms: Dict[str, str] = {}
        for k, v in flat.items():
            if state_regime(k) == "moment2" and (
                    v is None or v.dtype == np.float32):
                transforms[k] = "log1p"
                work[k] = None if v is None else np.log1p(v)
            else:
                work[k] = v
        return work, transforms

    def _graph_json(self, work: Dict[str, Optional[np.ndarray]],
                    parent_manifest: Optional[Dict[str, Any]]) -> str:
        specs: Dict[str, Tuple[Tuple[int, ...], str]] = {}
        for k, v in work.items():
            if v is not None:
                specs[k] = (tuple(v.shape), str(v.dtype))
            else:
                pe = parent_manifest["params"][k]
                specs[k] = (tuple(pe.get("shape", ())),
                            pe.get("dtype", "float32"))
        return spec_graph(specs, self.model_name).to_json()

    # -- restore ---------------------------------------------------------------------
    def restore(self, step: Optional[int] = None, template: Any = None,
                verify: bool = False, allow_lossy: bool = False):
        """Load flat state (or a full pytree if ``template`` given).

        Returns ``(state, step)``. When the resolved step is a lossy
        intermediate and ``allow_lossy`` is False (the default — and the
        only safe choice for resuming training), the restore walks back to
        the nearest bit-exact ancestor and returns THAT step."""
        self.wait()
        # a restore may rewind training: the fingerprint/skip baseline and
        # live-flat shortcut describe the pre-restore head, not whatever
        # the caller resumes from — drop them (next save transfers fully)
        self._last_fps = {}
        self._prev_flat = None
        self._prev_flat_ref = None
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoint found")
        steps = sorted(self._steps())
        if step not in steps:
            raise FileNotFoundError(f"no committed checkpoint at step {step}")
        while not allow_lossy:
            node = self.lineage.nodes[self._node_name(step)]
            manifest = self.store.get_manifest(node.artifact_ref)
            if not (manifest.get("metadata") or {}).get("lossy"):
                break
            prior = [s for s in steps if s < step]
            if not prior:
                break  # first commit is always exact; defensive
            step = max(prior)
        node = self.lineage.nodes[self._node_name(step)]
        artifact = node.get_model()
        manifest = self.store.get_manifest(node.artifact_ref)
        if verify:
            # Bit-rot check against commit-time content hashes. The lazy view
            # materializes one tensor at a time, so verification streams at
            # O(tensor) peak memory. Delta entries are covered too: plan
            # execution is bit-exact w.r.t. the commit-time reconstruction.
            for key, e in manifest["params"].items():
                expected = e.get("hash") or e.get("tensor")
                if expected is None:
                    continue  # pre-hash manifest (older store version)
                if tensor_hash(artifact.params[key]) != expected:
                    raise IOError(f"checkpoint corruption detected in {key!r}")
        transforms = (manifest.get("metadata") or {}).get("transforms") or {}
        if transforms:
            flat: Dict[str, np.ndarray] = {}
            for key in manifest["params"]:
                v = np.asarray(artifact.params[key])
                if transforms.get(key) == "log1p":
                    v = np.expm1(v)
                flat[key] = v
        else:
            flat = artifact.params
        if template is None:
            return flat, step
        return unflatten_state(template, flat), step

    def restore_sharded(self, template: Any, step: Optional[int] = None,
                        verify: bool = False, allow_lossy: bool = False):
        """Elastic restore: lay the checkpoint out per ``template``'s shardings.

        ``template`` leaves are jax.ShapeDtypeStruct with ``.sharding`` set for
        the TARGET mesh — which may differ from the mesh that wrote the
        checkpoint (scale-up/down after failure)."""
        state, step = self.restore(step=step, template=template,
                                   verify=verify, allow_lossy=allow_lossy)

        def _place(leaf, tmpl):
            sharding = getattr(tmpl, "sharding", None)
            if sharding is not None:
                return jax.device_put(leaf, sharding)
            return jax.numpy.asarray(leaf)

        return jax.tree_util.tree_map(_place, state, template), step
