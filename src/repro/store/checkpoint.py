"""Distributed checkpoint manager — MGit versioning as a training substrate.

Every ``save(step, state)`` cut becomes a *version node* in a lineage graph
whose storage flows through the CAS + delta compression: consecutive training
checkpoints differ by one optimizer excursion, which is exactly the
sparse-delta regime Algorithm 1 exploits, and frozen tensors (embeddings in
finetuning, shared MTL trunks) dedup to zero marginal bytes.

Fault tolerance:
* commits are atomic — the ``LATEST`` pointer moves only after the manifest
  and every object are durably written, so a crash mid-save is invisible;
* ``restore(verify=True)`` recomputes content hashes (bit-rot detection);
* ``restore_sharded`` re-lays the checkpoint out on a *different* mesh
  (elastic scaling after node loss — shardings come from the target, not the
  writer);
* saves run on a background thread against a host snapshot, overlapping the
  next training step (async checkpointing).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.common.hashing import tensor_hash
from repro.core.artifact import ModelArtifact
from repro.core.graphir import LayerGraph, LayerNode
from repro.core.lineage import LineageGraph
from repro.store.artifact_store import ArtifactStore


def _keystr(path) -> str:
    """``jax.tree_util.keystr(path, simple=True, separator="/")`` compat.

    The ``simple``/``separator`` kwargs only exist on newer JAX; render the
    key path entries directly so any 0.4.x works."""
    parts = []
    for entry in path:
        for attr in ("key", "name", "idx"):
            v = getattr(entry, attr, None)
            if v is not None:
                parts.append(str(v))
                break
        else:
            parts.append(str(entry).strip("[].'\""))
    return "/".join(parts)


def flatten_state(state) -> Dict[str, np.ndarray]:
    """Pytree -> flat {path: host ndarray}. Gathers from device (blocking)."""
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    for path, leaf in leaves:
        key = _keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def unflatten_state(template, flat: Dict[str, np.ndarray]):
    """Inverse of flatten_state given a structure/ShapeDtypeStruct template."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _keystr(path)
        value = flat[key]
        dtype = getattr(leaf, "dtype", None)
        if dtype is not None and str(value.dtype) != str(dtype):
            value = value.astype(dtype)
        leaves.append(value)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def state_graph(flat: Dict[str, np.ndarray], model_type: str) -> LayerGraph:
    """Chain LayerGraph over state entries (checkpoints are sequenced by path)."""
    nodes = []
    for key, value in flat.items():
        layer, _, param = key.rpartition("/")
        nodes.append((layer or key, param or "value", value))
    g = LayerGraph()
    prev = None
    for layer, param, value in nodes:
        if layer not in g.nodes:
            g.add_node(LayerNode(layer, "state"))
            if prev is not None:
                g.add_edge(prev, layer)
            prev = layer
        g.nodes[layer].params[param] = (tuple(np.shape(value)), str(np.asarray(value).dtype))
    return g


class CheckpointManager:
    def __init__(self, directory: Optional[str], model_name: str = "model",
                 codec: str = "lzma", eps: float = 1e-4,
                 delta_enabled: bool = True, async_save: bool = True,
                 max_chain_depth: int = 8, store: Optional[ArtifactStore] = None,
                 lineage: Optional[LineageGraph] = None) -> None:
        self.model_name = model_name
        self.store = store or ArtifactStore(
            root=directory, codec=codec, eps=eps, t_thr=float("inf"),
            delta_enabled=delta_enabled, max_chain_depth=max_chain_depth)
        self.lineage = lineage or LineageGraph(path=directory, store=self.store)
        self.async_save = async_save
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- naming ----------------------------------------------------------------
    def _node_name(self, step: int) -> str:
        return f"{self.model_name}/step{step}"

    def latest_step(self) -> Optional[int]:
        steps = [
            int(n.rsplit("step", 1)[1]) for n in self.lineage.nodes
            if n.startswith(self.model_name + "/step")
            and self.lineage.nodes[n].artifact_ref is not None
        ]
        return max(steps) if steps else None

    # -- save ---------------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: Optional[bool] = None) -> str:
        """Snapshot ``state`` (pytree) as version ``step``. Returns node name.

        The device->host gather happens synchronously (the state is immutable
        after that point); hashing/compression/IO run on the worker thread.
        """
        self._check_error()
        flat = flatten_state(state)
        name = self._node_name(step)
        if blocking is None:
            blocking = not self.async_save
        if blocking:
            self._commit(step, name, flat)
        else:
            self._start_worker()
            self._queue.put((step, name, flat))
        return name

    def _commit(self, step: int, name: str, flat: Dict[str, np.ndarray]) -> None:
        artifact = ModelArtifact(graph=state_graph(flat, self.model_name),
                                 params=flat, model_type=self.model_name,
                                 metadata={"step": step})
        prev_step = None
        for n in self.lineage.nodes:
            if n.startswith(self.model_name + "/step"):
                s = int(n.rsplit("step", 1)[1])
                if s < step and (prev_step is None or s > prev_step):
                    prev_step = s
        node = self.lineage.add_node(None, name, model_type=self.model_name)
        if prev_step is not None:
            # version edge first so the store picks the right delta parent
            self.lineage.add_version_edge(self._node_name(prev_step), name)
        self.lineage._attach_artifact(node, artifact)  # atomic manifest commit
        self.lineage._commit()

    def _start_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self) -> None:
        while True:
            try:
                item = self._queue.get(timeout=0.2)
            except queue.Empty:
                return
            try:
                self._commit(*item)
            except BaseException as e:  # surfaced on next save()/wait()
                self._error = e
            finally:
                self._queue.task_done()

    def wait(self) -> None:
        self._queue.join()
        self._check_error()

    def _check_error(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    # -- restore ---------------------------------------------------------------------
    def restore(self, step: Optional[int] = None, template: Any = None,
                verify: bool = False):
        """Load flat state (or a full pytree if ``template`` given)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoint found")
        node = self.lineage.nodes[self._node_name(step)]
        artifact = node.get_model()
        if verify:
            # Bit-rot check against commit-time content hashes. The lazy view
            # materializes one tensor at a time, so verification streams at
            # O(tensor) peak memory. Delta entries are covered too: plan
            # execution is bit-exact w.r.t. the commit-time reconstruction.
            manifest = self.store.get_manifest(node.artifact_ref)
            for key, e in manifest["params"].items():
                expected = e.get("hash") or e.get("tensor")
                if expected is None:
                    continue  # pre-hash manifest (older store version)
                if tensor_hash(artifact.params[key]) != expected:
                    raise IOError(f"checkpoint corruption detected in {key!r}")
        flat = artifact.params
        if template is None:
            return flat, step
        return unflatten_state(template, flat), step

    def restore_sharded(self, template: Any, step: Optional[int] = None,
                        verify: bool = False):
        """Elastic restore: lay the checkpoint out per ``template``'s shardings.

        ``template`` leaves are jax.ShapeDtypeStruct with ``.sharding`` set for
        the TARGET mesh — which may differ from the mesh that wrote the
        checkpoint (scale-up/down after failure)."""
        state, step = self.restore(step=step, template=template, verify=verify)

        def _place(leaf, tmpl):
            sharding = getattr(tmpl, "sharding", None)
            if sharding is not None:
                return jax.device_put(leaf, sharding)
            return jax.numpy.asarray(leaf)

        return jax.tree_util.tree_map(_place, state, template), step
