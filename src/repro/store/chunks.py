"""Content-defined chunking for large tensors (DESIGN.md §12).

Tensors at or above ``ArtifactStore.chunk_threshold`` are split into chunks
that become first-class CAS objects under the ``c_<sha256(bytes)>`` key
scheme. Boundaries come from a Gear-style rolling hash — a windowed hash of
the last ``WINDOW`` bytes, cut where ``hash & mask == 0`` — so a localized
edit only moves boundaries inside its own neighborhood and every other chunk
keeps its key (content-defined dedup, the XetHub/FastCDC idea). A fixed-grid
mode (``mode="fixed"``) exists as a deterministic fallback and as the shape
the RSS-budget CI smoke uses.

Two properties matter for the layers above:

* **Element alignment.** Every cut is snapped down to a multiple of the
  dtype itemsize, so each chunk decodes as a whole number of elements and
  per-chunk delta quantization (``store/delta.py``) never straddles a cut.
* **Segment confinement.** ``cut_points`` accepts hard segment boundaries
  (from ``dist/sharding.py`` shard splits); chunks never cross a segment,
  so each host of a sharded consumer can pull exactly its shard's chunks.

The pure-python byte loop of classic FastCDC is far too slow for GB-scale
tensors, so the rolling hash is vectorized: with window W=8 the Gear hash of
position ``i`` is ``G0[b[i]] ^ G1[b[i-1]] ^ ... ^ G7[b[i-7]]`` — eight
shifted table lookups XOR'd as numpy u64 arrays, processed in bounded
sub-blocks so the temporaries never exceed a few MB.
"""

from __future__ import annotations

import os
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

# Chunking defaults. Threshold chosen so ordinary layer tensors (a few MB)
# keep the PR-4 whole-tensor fold path; only genuinely large params pay the
# per-chunk manifest overhead.
DEFAULT_CHUNK_THRESHOLD = 8 * 2 ** 20    # params >= this are chunked
DEFAULT_MIN_CHUNK = 256 * 2 ** 10
DEFAULT_AVG_CHUNK = 1 * 2 ** 20          # must be a power of two (hash mask)
DEFAULT_MAX_CHUNK = 4 * 2 ** 20
DEFAULT_WINDOW_BYTES = 64 * 2 ** 20      # commit/checkout in-flight budget

WINDOW = 8                               # rolling-hash window, bytes
_SCAN_BLOCK = 4 * 2 ** 20                # sub-block for vectorized hashing

# 8 independent 256-entry u64 tables from a fixed-seed PRNG: boundary
# positions are a pure function of content, stable across processes/versions.
_GEAR = np.random.default_rng(0x4D476974).integers(
    0, 2 ** 64, size=(WINDOW, 256), dtype=np.uint64)


def _window_hash(block: np.ndarray) -> np.ndarray:
    """Gear window hash for each position i >= WINDOW-1 of a u8 block."""
    n = block.size
    h = _GEAR[0][block[WINDOW - 1:]]
    for j in range(1, WINDOW):
        h ^= _GEAR[j][block[WINDOW - 1 - j:n - j]]
    return h


def _candidates(data: memoryview, mask: int) -> np.ndarray:
    """Positions p where the windowed hash over bytes [p-7, p] hits the mask.

    A cut at p means "chunk ends after byte p" (exclusive offset p+1).
    Processes the buffer in sub-blocks with a WINDOW-1 byte overlap so the
    u64 temporaries stay bounded regardless of input size.
    """
    buf = np.frombuffer(data, dtype=np.uint8)
    n = buf.size
    if n < WINDOW:
        return np.empty(0, dtype=np.int64)
    out: List[np.ndarray] = []
    mask64 = np.uint64(mask)
    start = 0
    while start < n - WINDOW + 1:
        stop = min(n, start + _SCAN_BLOCK)
        block = buf[start:stop]
        if block.size < WINDOW:
            break
        hits = np.flatnonzero((_window_hash(block) & mask64) == 0)
        if hits.size:
            out.append(hits.astype(np.int64) + start + WINDOW - 1)
        start = stop - (WINDOW - 1)
    if not out:
        return np.empty(0, dtype=np.int64)
    return np.concatenate(out)


def _next_cut(data, min_size: int, max_size: int, itemsize: int,
              mask: int) -> int:
    """Length of the next chunk given a ``max_size``-byte lookahead window.

    FastCDC-style greedy selection: the first boundary candidate whose
    snapped offset lands in [min_size, max_size], else a forced cut at
    max_size. Offsets snap down to itemsize multiples so chunks hold whole
    elements.
    """
    def snap(off: int) -> int:
        return (off // itemsize) * itemsize

    for c in _candidates(memoryview(data), mask):
        cut = snap(int(c) + 1)
        if cut < min_size:
            continue
        if cut > max_size:
            break
        return cut
    return max(itemsize, snap(max_size))


def cut_points(read: Callable[[int, int], bytes], length: int, itemsize: int,
               *, min_size: int = DEFAULT_MIN_CHUNK,
               avg_size: int = DEFAULT_AVG_CHUNK,
               max_size: int = DEFAULT_MAX_CHUNK,
               mode: str = "cdc",
               segments: Optional[Sequence[int]] = None) -> List[int]:
    """Exclusive chunk-end offsets for a byte stream of ``length`` bytes.

    ``read(offset, size)`` supplies bytes on demand — the stream is scanned
    in bounded windows, never held whole. ``segments`` lists hard interior
    boundaries (ascending, itemsize-aligned); they are always cut points and
    chunking restarts at each, so no chunk crosses a shard boundary.
    Returns offsets ending with ``length``.
    """
    if itemsize <= 0:
        itemsize = 1
    min_size = max(itemsize, (min_size // itemsize) * itemsize or itemsize)
    max_size = max(min_size + itemsize, (max_size // itemsize) * itemsize)
    mask = max(1, int(avg_size)) - 1  # power-of-two avg → uniform hit rate

    bounds = [0]
    if segments:
        bounds.extend(int(s) for s in segments if 0 < int(s) < length)
    bounds.append(length)
    bounds = sorted(set(bounds))

    cuts: List[int] = []
    for seg_start, seg_end in zip(bounds[:-1], bounds[1:]):
        seg_len = seg_end - seg_start
        pos = 0
        # One lookahead window of at most max_size bytes per cut decision:
        # boundary selection never needs to see past pos+max_size, so the
        # stream is scanned in bounded pieces regardless of tensor size.
        while seg_len - pos > max_size:
            if mode == "fixed":
                # deterministic grid at the configured average size; the
                # tail chunk absorbs the remainder (up to max_size)
                cut = max(min_size, (avg_size // itemsize) * itemsize)
            else:
                data = read(seg_start + pos, max_size)
                cut = _next_cut(data, min_size, max_size, itemsize, mask)
            if seg_len - (pos + cut) < itemsize:
                break
            pos += cut
            cuts.append(seg_start + pos)
        cuts.append(seg_end)
    if not cuts or cuts[-1] != length:
        cuts.append(length)
    return sorted(set(c for c in cuts if 0 < c <= length))


def spans_of(cuts: Sequence[int]) -> List[Tuple[int, int]]:
    """(offset, length) pairs from exclusive cut offsets."""
    out = []
    prev = 0
    for c in cuts:
        out.append((prev, c - prev))
        prev = c
    return out


# ---------------------------------------------------------------------------
# Chunk sources: anything exposing shape/dtype plus random-access raw bytes.
# The commit engine never materializes more than its window of these.


class ArraySource:
    """Chunk-source view over an in-memory ndarray."""

    def __init__(self, arr: np.ndarray) -> None:
        self._arr = np.ascontiguousarray(arr)
        self._mv = memoryview(self._arr).cast("B")
        self.shape = tuple(int(d) for d in self._arr.shape)
        self.dtype = np.dtype(self._arr.dtype)
        self.nbytes = int(self._arr.nbytes)

    def read(self, offset: int, size: int) -> memoryview:
        return self._mv[offset:offset + size]


class FileSource:
    """Chunk source backed by raw little-endian bytes in a file (pread-based,
    no mmap — keeps page-cache pressure out of the process RSS budget)."""

    def __init__(self, path: str, shape: Sequence[int], dtype,
                 offset: int = 0) -> None:
        self.path = str(path)
        self.shape = tuple(int(d) for d in shape)
        self.dtype = np.dtype(dtype)
        self.nbytes = int(np.prod(self.shape, dtype=np.int64)
                          * self.dtype.itemsize) if self.shape else \
            self.dtype.itemsize
        self._base = int(offset)
        self._fd = os.open(self.path, os.O_RDONLY)

    def read(self, offset: int, size: int) -> bytes:
        parts = []
        pos = self._base + offset
        remaining = size
        while remaining > 0:
            b = os.pread(self._fd, remaining, pos)
            if not b:
                raise IOError(f"short read from {self.path} at {pos}")
            parts.append(b)
            pos += len(b)
            remaining -= len(b)
        return b"".join(parts) if len(parts) != 1 else parts[0]

    def close(self) -> None:
        if self._fd >= 0:
            os.close(self._fd)
            self._fd = -1

    def __del__(self):  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass


class FnSource:
    """Procedural chunk source: ``fn(offset, size) -> bytes``. Lets the CI
    smoke commit a ~1 GB-logical tensor that never exists in memory."""

    def __init__(self, fn: Callable[[int, int], bytes],
                 shape: Sequence[int], dtype) -> None:
        self._fn = fn
        self.shape = tuple(int(d) for d in shape)
        self.dtype = np.dtype(dtype)
        self.nbytes = int(np.prod(self.shape, dtype=np.int64)
                          * self.dtype.itemsize) if self.shape else \
            self.dtype.itemsize

    def read(self, offset: int, size: int) -> bytes:
        return self._fn(offset, size)


def as_source(value):
    """Normalize a param value into a chunk source, or None if it already
    is one (has shape/dtype/read)."""
    if hasattr(value, "read") and hasattr(value, "shape") \
            and hasattr(value, "dtype"):
        return value
    return ArraySource(np.asarray(value))


def is_chunk_source(value) -> bool:
    return hasattr(value, "read") and hasattr(value, "shape") \
        and hasattr(value, "dtype") and not isinstance(value, np.ndarray)
