"""Content-addressable store with refcounting + packfiles (paper §4; DESIGN.md §3.2).

Objects (tensors, delta blobs, manifests) are keyed by SHA-256 — writing the
same content twice costs nothing, which is exactly how parameters shared
across lineage-graph models are stored once.

Key schemes (DESIGN.md §3.2, §9.1, §9.3 — ``fsck`` verifies each):

* ``m_<bytes_hash>`` — manifests, hash of the JSON payload;
* ``<tensor_hash>`` — full tensors, hash over (shape, dtype, raw bytes),
  NOT over the serialized npy stream (re-deriving needs a decode);
* ``<bytes_hash>`` — delta blobs and raw objects, hash of the stored bytes;
* ``t_<bytes_hash(test_hash NUL manifest_key)>`` — diagnostics ledger
  entries, keyed by the *lookup pair* (embedded in the payload) so results
  probe in O(1); the only scheme where ``put_bytes(overwrite=True)`` may
  legally change bytes under a key;
* ``s_<bytes_hash>`` — scoped content keys (``diag/transfer.py``): the hash
  of a submodule's parameter *hashes*, used as the ledger's manifest_key
  for scope-declared tests. Derived, never stored as an object itself;
* ``c_<bytes_hash>`` — tensor chunks (DESIGN.md §12): raw little-endian
  element bytes of one content-defined chunk of a large tensor, hash of
  exactly the stored bytes. No container framing, so ranged/zero-copy
  reads serve chunk payloads directly.

The loose/packed placement split is keyed on one constant:
``DEFAULT_PACK_THRESHOLD`` (256 KiB). Objects at or above it get a loose
file (mmap-able, ranged-readable); smaller ones append into packs. Every
layer (bare ``CAS()``, ``ArtifactStore``) shares this default — it used to
drift (4096 here vs 256 KiB above), which silently changed placement for
anyone instantiating a bare CAS.

What is stored is always the *stored form* of an artifact: committing
delta-quantizes against the parent, so the persisted model differs from the
in-memory one that was committed by up to the quantization eps. Every
consumer that needs bit-level truth (sync bit-identity checks, fsck,
diagnostics memoization) must compare store-loaded artifacts, never the
live Python objects they came from.

Two placement tiers, mirroring git's loose-object/packfile split:

* **loose**: objects >= ``pack_threshold`` bytes get one file each under
  ``objects/`` (atomic tmp + rename);
* **packed**: small objects (delta blobs, manifests) append into
  ``packs/pack-<n>.pack`` as self-describing records
  ``[keylen u16][key][datalen u32][data]`` with an in-memory offset index.
  The index is persisted as JSON beside the refcounts, and because records
  are self-describing any appended-but-unindexed tail is recovered by a
  bounded scan on reopen — a crash can never orphan a packed object.

``physical_bytes()`` / ``object_count()`` are O(1) counters maintained on
every mutation (the directory scans they replaced were O(n) per call).
Refcounts persist on ``incref``/``decref`` so a crash between a decref and
the next ``gc()`` can neither leak nor double-free objects.

Throughput paths (DESIGN.md §10):

* writes inside a :meth:`batch` context share one append handle per pack
  and fsync once when the outermost batch exits (the commit point) instead
  of reopening the pack file per record;
* reads are backed by a pooled-``mmap`` view cache — ``get_view`` returns a
  zero-copy ``memoryview`` into the mapped pack/loose file and
  ``get_tensor`` decodes npy payloads with ``np.frombuffer`` straight off
  the map (no intermediate ``bytes``). Pack files are append-only and pack
  ids are never reused, so a view can only go stale by the file *growing*,
  which a remap-on-demand check handles; files unlinked by gc/compaction
  stay readable through any live mapping (POSIX semantics).
"""

from __future__ import annotations

import contextlib
import io
import json
import mmap
import os
import struct
import threading
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.common.faults import kill_point
from repro.common.hashing import bytes_hash, tensor_hash

_REC_HEAD = struct.Struct("<HI")  # (keylen, datalen)
_MMAP_POOL_MAX = 64  # mapped files kept open; evicted maps stay valid for
                     # outstanding views (the arrays keep the mmap alive)

# Loose/packed placement boundary, shared by CAS and ArtifactStore (see the
# key-scheme docstring above).
DEFAULT_PACK_THRESHOLD = 256 * 2 ** 10


def _tensor_from_npy_view(view: memoryview) -> Optional[np.ndarray]:
    """Decode an npy stream as a zero-copy array over ``view``.

    Returns a read-only array aliasing the view's buffer, or None when the
    payload needs the copying loader (Fortran order / unsupported header).
    Read-only is load-bearing: the buffer may be a shared mmap of a pack
    file — writes through an aliasing array would corrupt the store."""
    buf = io.BytesIO(bytes(view[:512]))  # header only; payload stays mapped
    try:
        version = np.lib.format.read_magic(buf)
        np.lib.format._check_version(version)
        shape, fortran, dtype = np.lib.format._read_array_header(buf, version)
    except Exception:
        return None
    if fortran or dtype.hasobject:
        return None
    offset = buf.tell()
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    if offset + count * dtype.itemsize > len(view):
        return None
    arr = np.frombuffer(view, dtype=dtype, count=count, offset=offset)
    arr = arr.reshape(shape)
    arr.flags.writeable = False
    return arr


def ledger_key(test_hash: str, manifest_key: str) -> str:
    """Key scheme for diagnostics result-ledger entries (DESIGN.md §9.1).

    ``"t_" + bytes_hash(test_hash NUL manifest_key)`` — derived from the
    *lookup pair*, not the payload, so a memoized runner can probe for a
    recorded result in O(1) without an index. The payload embeds both
    components, which is how ``fsck`` re-derives and verifies the key."""
    return "t_" + bytes_hash(f"{test_hash}\x00{manifest_key}".encode())


class CAS:
    def __init__(self, root: Optional[str] = None,
                 pack_threshold: int = DEFAULT_PACK_THRESHOLD,
                 pack_max_bytes: int = 64 * 2**20,
                 mmap_pool_max: Optional[int] = None) -> None:
        self.root = root
        self.pack_threshold = pack_threshold
        self.pack_max_bytes = pack_max_bytes
        self._mmap_pool_max = (_MMAP_POOL_MAX if mmap_pool_max is None
                               else max(1, int(mmap_pool_max)))
        self._mem: Dict[str, bytes] = {}
        self.refcounts: Dict[str, int] = {}
        self._lock = threading.RLock()
        self._defer_persist = 0
        self.stats = {"puts": 0, "gets": 0, "dedup_hits": 0, "bytes_written": 0,
                      "bytes_deduped": 0, "zero_copy_gets": 0, "fsyncs": 0}
        # pack state: key -> (pack_id, offset, length); offsets point at data
        self._pack_index: Dict[str, Tuple[int, int, int]] = {}
        self._pack_sizes: Dict[int, int] = {}   # pack_id -> bytes on disk
        self._pack_dead: Dict[int, int] = {}    # pack_id -> dead payload bytes
        self._next_pack = 0
        # O(1) accounting counters
        self._object_count = 0
        self._physical_bytes = 0
        # batched-write state: open append handles, live only inside batch()
        self._batch_depth = 0
        self._batch_handles: Dict[int, Any] = {}
        # pooled mmap views keyed by file path -> (mmap, mapped_size)
        self._mmap_pool: "OrderedDict[str, Tuple[mmap.mmap, int]]" = OrderedDict()
        # reader leases (DESIGN.md §16.2): while pins are held, gc() performs
        # logical deletes only — physical reclaim and pack compaction are
        # deferred until the last pin releases, so an in-flight ranged read
        # or mget stream can never observe a reclaimed object.
        self._pins = 0
        self._deferred_dead: Dict[str, int] = {}   # key -> payload bytes
        self._gc_epoch = 0
        if root is not None:
            os.makedirs(os.path.join(root, "objects"), exist_ok=True)
            os.makedirs(os.path.join(root, "packs"), exist_ok=True)
            rc = os.path.join(root, "refcounts.json")
            if os.path.exists(rc):
                with open(rc) as f:
                    self.refcounts = json.load(f)
            self._load_pack_index()
            self._rebuild_counters()

    # -- layout ----------------------------------------------------------------
    def _obj_path(self, key: str) -> str:
        return os.path.join(self.root, "objects", key)

    def _pack_path(self, pack_id: int) -> str:
        return os.path.join(self.root, "packs", f"pack-{pack_id:06d}.pack")

    def _index_path(self) -> str:
        return os.path.join(self.root, "packs", "pack-index.json")

    # -- pack index persistence / recovery --------------------------------------
    def _load_pack_index(self, truncate_torn: bool = True) -> None:
        if os.path.exists(self._index_path()):
            with open(self._index_path()) as f:
                payload = json.load(f)
            self._pack_index = {k: tuple(v)
                                for k, v in payload["entries"].items()}
            self._pack_sizes = {int(k): v
                                for k, v in payload["pack_sizes"].items()}
            self._pack_dead = {int(k): v
                               for k, v in payload.get("dead", {}).items()}
            self._next_pack = payload.get("next_pack", 0)
        # Recover records appended after the last index write (or ever, if the
        # index file is gone): scan each pack's unindexed tail.
        for fname in sorted(os.listdir(os.path.join(self.root, "packs"))):
            if not fname.endswith(".pack"):
                continue
            pid = int(fname.rsplit("-", 1)[1].split(".")[0])
            # keep appending to the newest pack (rotation happens on write
            # when it fills) — bumping past it would leak one stub pack per
            # process lifetime
            self._next_pack = max(self._next_pack, pid)
            path = self._pack_path(pid)
            actual = os.path.getsize(path)
            indexed = self._pack_sizes.get(pid, 0)
            if actual > indexed:
                self._scan_pack_tail(pid, indexed, actual,
                                     truncate_torn=truncate_torn)
        self._sweep_orphan_packs()

    def _scan_pack_tail(self, pack_id: int, start: int, end: int,
                        truncate_torn: bool = True) -> None:
        with open(self._pack_path(pack_id), "rb") as f:
            f.seek(start)
            pos = start
            while pos + _REC_HEAD.size <= end:
                head = f.read(_REC_HEAD.size)
                if len(head) < _REC_HEAD.size:
                    break
                klen, dlen = _REC_HEAD.unpack(head)
                if pos + _REC_HEAD.size + klen + dlen > end:
                    break  # torn tail record from a crash mid-append: ignore
                key = f.read(klen).decode("utf-8", "replace")
                data_off = pos + _REC_HEAD.size + klen
                f.seek(dlen, os.SEEK_CUR)
                # Last-wins: tail records are strictly newer than anything
                # in the persisted index (they were appended after its last
                # flush), and within/across tails the scan order is
                # chronological — so an overwrite-in-place record (ledger
                # ``t_`` scheme) recovered here must supersede the stale
                # entry, whose bytes become dead payload. Content-addressed
                # keys are unaffected (identical bytes either way).
                old = self._pack_index.get(key)
                if old is not None:
                    self._pack_dead[old[0]] = (self._pack_dead.get(old[0], 0)
                                               + old[2])
                self._pack_index[key] = (pack_id, data_off, dlen)
                pos = data_off + dlen
            self._pack_sizes[pack_id] = pos
        if pos < end and truncate_torn:
            # torn record from a crash mid-append — drop it so later appends
            # land exactly at the indexed offset (a read-only reload instead
            # leaves it alone: the writer may still be mid-append)
            with open(self._pack_path(pack_id), "r+b") as f:
                f.truncate(pos)

    def _persist_pack_index(self) -> None:
        if self.root is None:
            return
        payload = {"entries": {k: list(v) for k, v in self._pack_index.items()},
                   "pack_sizes": {str(k): v for k, v in self._pack_sizes.items()},
                   "dead": {str(k): v for k, v in self._pack_dead.items()},
                   "next_pack": self._next_pack}
        tmp = self._index_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self._index_path())

    def _rebuild_counters(self) -> None:
        """One O(n) pass at open; every later query is O(1)."""
        objdir = os.path.join(self.root, "objects")
        loose = [f for f in os.listdir(objdir) if not f.endswith(".tmp")]
        self._object_count = len(loose) + len(self._pack_index)
        self._physical_bytes = sum(
            os.path.getsize(os.path.join(objdir, f)) for f in loose)
        self._physical_bytes += sum(self._pack_sizes.values())

    # -- raw object interface ------------------------------------------------
    def has(self, key: str) -> bool:
        if self.root is None:
            return key in self._mem
        return (key in self._pack_index or key in self.refcounts
                or os.path.exists(self._obj_path(key)))

    def _write_loose(self, key: str, data: bytes) -> None:
        path = self._obj_path(key)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            # fsync BEFORE the rename: os.replace is atomic for the name but
            # not for the bytes — without this a crash can publish a
            # truncated object under its final (content-addressed!) key
            f.flush()
            os.fsync(f.fileno())
            self.stats["fsyncs"] += 1
        os.replace(tmp, path)
        # the rename swapped the inode: a pooled map of the old file would
        # serve stale bytes (matters for overwrite-in-place, e.g. a forced
        # diag ledger re-record whose payload crossed the pack threshold)
        with self._lock:
            self._mmap_pool.pop(path, None)
        self._physical_bytes += len(data)

    def _pack_handle(self, pid: int):
        """Append handle for ``pid``, cached for the duration of a batch."""
        f = self._batch_handles.get(pid)
        if f is None:
            f = self._batch_handles[pid] = open(self._pack_path(pid), "ab")
        return f

    def _write_packed(self, key: str, data: bytes) -> None:
        pid = self._next_pack
        size = self._pack_sizes.get(pid, 0)
        if size and size >= self.pack_max_bytes:
            pid = self._next_pack = self._next_pack + 1
            size = 0
        kb = key.encode()
        record = _REC_HEAD.pack(len(kb), len(data)) + kb + data
        if self._batch_depth > 0:
            f = self._pack_handle(pid)
            f.write(record)
            f.flush()  # reach the OS so concurrent readers/mmaps see it;
            # durability still waits for the single fsync at batch exit
        else:
            with open(self._pack_path(pid), "ab") as f:
                f.write(record)
        self._pack_index[key] = (pid, size + _REC_HEAD.size + len(kb),
                                 len(data))
        self._pack_sizes[pid] = size + len(record)
        self._physical_bytes += len(record)

    @contextlib.contextmanager
    def batch(self):
        """Buffered-append window: packed writes share one handle per pack
        and are fsynced ONCE when the outermost batch exits (the commit
        point). Without it every packed record pays an open/close — the
        dominant syscall cost of a many-object commit. Loose objects keep
        their own per-file fsync (they are published by rename and must be
        durable *before* the name exists). Reentrant and thread-shared:
        EVERY batch exit fsyncs the open handles — each exiting commit is a
        durability point even while other batches overlap — and the last
        exit also closes them."""
        with self._lock:
            self._batch_depth += 1
        try:
            yield self
        finally:
            with self._lock:
                self._batch_depth -= 1
                for f in self._batch_handles.values():
                    f.flush()
                    os.fsync(f.fileno())
                    self.stats["fsyncs"] += 1
                if self._batch_depth == 0:
                    for f in self._batch_handles.values():
                        f.close()
                    self._batch_handles.clear()

    def write_batch(self, items: Iterable[Tuple[str, bytes]]) -> List[str]:
        """Land many objects through one buffered batch; returns their keys."""
        with self.batch():
            return [self.put_bytes(data, key=key) for key, data in items]

    def put_bytes(self, data: bytes, key: Optional[str] = None,
                  overwrite: bool = False) -> str:
        """Store ``data`` under ``key`` (its content hash by default).

        ``overwrite=True`` replaces an existing object's bytes in place —
        same key, same refcount, old packed record marked dead for
        compaction. Only meaningful for the ledger scheme (``t_``), whose
        keys derive from the lookup pair rather than the payload; content-
        hashed objects can never legitimately change under their key."""
        key = key or bytes_hash(data)
        with self._lock:
            self.stats["puts"] += 1
            if self.has(key):
                if not overwrite:
                    self.stats["dedup_hits"] += 1
                    self.stats["bytes_deduped"] += len(data)
                    self.refcounts[key] = self.refcounts.get(key, 0) + 1
                    return key
                if self.root is None:
                    old = self._mem.get(key)
                    if old is not None:
                        self._physical_bytes -= len(old)
                    self._mem[key] = data
                    self._physical_bytes += len(data)
                elif key in self._pack_index:
                    pid, _, length = self._pack_index[key]
                    self._pack_dead[pid] = self._pack_dead.get(pid, 0) + length
                    self._write_packed(key, data)
                else:
                    path = self._obj_path(key)
                    if os.path.exists(path):
                        self._physical_bytes -= os.path.getsize(path)
                    self._write_loose(key, data)
                self.stats["bytes_written"] += len(data)
                return key
            if self.root is None:
                self._mem[key] = data
                self._physical_bytes += len(data)
            elif len(data) < self.pack_threshold:
                self._write_packed(key, data)
            else:
                self._write_loose(key, data)
            self._object_count += 1
            self.stats["bytes_written"] += len(data)
            self.refcounts[key] = self.refcounts.get(key, 0) + 1
            return key

    # -- pooled mmap views -------------------------------------------------------
    def _map_file(self, path: str, need_end: int) -> Optional[mmap.mmap]:
        """Shared read-only map of ``path`` covering at least ``need_end``.

        Maps are pooled (LRU) and remapped when the file has grown past the
        mapped size — pack files are append-only, so stale maps are only
        ever too *short*, never wrong. Returns None when the file cannot be
        mapped (missing, empty) — callers fall back to plain reads."""
        with self._lock:
            entry = self._mmap_pool.get(path)
            if entry is not None and entry[1] >= need_end:
                self._mmap_pool.move_to_end(path)
                return entry[0]
            try:
                with open(path, "rb") as f:
                    size = os.fstat(f.fileno()).st_size
                    if size < need_end or size == 0:
                        return None
                    mm = mmap.mmap(f.fileno(), size, access=mmap.ACCESS_READ)
            except (OSError, ValueError):
                return None
            # dropping an evicted/replaced map only releases OUR reference;
            # arrays holding views keep the mapping alive until they die
            self._mmap_pool[path] = (mm, size)
            self._mmap_pool.move_to_end(path)
            while len(self._mmap_pool) > self._mmap_pool_max:
                self._mmap_pool.popitem(last=False)
            return mm

    def get_view(self, key: str) -> memoryview:
        """Zero-copy read: a ``memoryview`` over the object's stored bytes.

        Backed by the pooled mmap for on-disk objects; raises ``KeyError``
        for missing keys (same contract as :meth:`get_bytes`)."""
        self.stats["gets"] += 1
        if self.root is None:
            try:
                return memoryview(self._mem[key])
            except KeyError:
                raise KeyError(f"no object {key!r} in CAS")
        entry = self._pack_index.get(key)
        if entry is not None:
            pid, off, length = entry
            mm = self._map_file(self._pack_path(pid), off + length)
            if mm is not None:
                self.stats["zero_copy_gets"] += 1
                return memoryview(mm)[off:off + length]
            return memoryview(self._read_packed(pid, off, length))
        path = self._obj_path(key)
        size = os.path.getsize(path) if os.path.exists(path) else 0
        mm = self._map_file(path, size) if size else None
        if mm is not None:
            self.stats["zero_copy_gets"] += 1
            return memoryview(mm)
        return memoryview(self._read_loose(key))

    def iter_views(self, keys: Iterable[str]):
        """Streaming multi-get: yield ``(key, view)`` pairs lazily.

        The hub's multi-object pack streaming (DESIGN.md §11.2) sits on
        this — each view is produced only when the consumer is ready to
        write it out, so serving an arbitrarily large object batch holds at
        most one object's view at a time (and usually zero copies: views
        come off the pooled mmap). Raises ``KeyError`` at the position of
        the first missing key, same contract as :meth:`get_view`."""
        for key in keys:
            yield key, self.get_view(key)

    def _read_packed(self, pid: int, off: int, length: int) -> bytes:
        with open(self._pack_path(pid), "rb") as f:
            f.seek(off)
            return f.read(length)

    def _read_loose(self, key: str) -> bytes:
        try:
            with open(self._obj_path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            # normalize the miss path: a missing object is a KeyError no
            # matter which placement tier it would have lived in
            raise KeyError(f"no object {key!r} in CAS")

    def get_bytes(self, key: str) -> bytes:
        """Object bytes (owned copy). Served off the pooled mmap when the
        file is mapped — repeated small reads skip the open/read/close
        syscall triple that dominates deep-chain checkouts."""
        self.stats["gets"] += 1
        if self.root is None:
            try:
                return self._mem[key]
            except KeyError:
                raise KeyError(f"no object {key!r} in CAS")
        entry = self._pack_index.get(key)
        if entry is not None:
            pid, off, length = entry
            mm = self._map_file(self._pack_path(pid), off + length)
            if mm is not None:
                return mm[off:off + length]
            return self._read_packed(pid, off, length)
        path = self._obj_path(key)
        try:
            size = os.path.getsize(path)
        except OSError:
            raise KeyError(f"no object {key!r} in CAS")
        mm = self._map_file(path, size) if size else None
        if mm is not None:
            return mm[:size]
        return self._read_loose(key)

    def get_bytes_nomap(self, key: str) -> bytes:
        """Object bytes via plain ``read()``, bypassing the mmap pool.

        The chunk streaming paths (DESIGN.md §12) use this: mapped pages are
        charged to the process RSS high-water mark, so a bounded-memory
        checkout of a multi-GB tensor must not page its chunks through
        long-lived maps. Plain reads copy through the kernel page cache,
        which is reclaimable and not part of ``ru_maxrss``."""
        self.stats["gets"] += 1
        if self.root is None:
            try:
                return self._mem[key]
            except KeyError:
                raise KeyError(f"no object {key!r} in CAS")
        entry = self._pack_index.get(key)
        if entry is not None:
            pid, off, length = entry
            return self._read_packed(pid, off, length)
        return self._read_loose(key)

    def size(self, key: str) -> int:
        if self.root is None:
            return len(self._mem[key])
        entry = self._pack_index.get(key)
        if entry is not None:
            return entry[2]
        return os.path.getsize(self._obj_path(key))

    # -- tensors ---------------------------------------------------------------
    def put_tensor(self, arr: np.ndarray, key: Optional[str] = None) -> str:
        """Store a tensor (npy-serialized); key is its content hash."""
        arr = np.asarray(arr)
        key = key or tensor_hash(arr)
        if self.has(key):  # avoid serializing at all on a dedup hit
            with self._lock:
                self.stats["puts"] += 1
                self.stats["dedup_hits"] += 1
                self.stats["bytes_deduped"] += arr.nbytes
                self.refcounts[key] = self.refcounts.get(key, 0) + 1
            return key
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        return self.put_bytes(buf.getvalue(), key=key)

    def get_tensor(self, key: str) -> np.ndarray:
        """Decode a stored npy payload, zero-copy where possible.

        The returned array aliases the pooled mmap (read-only,
        ``np.frombuffer`` over the payload view) — no intermediate ``bytes``
        object, no memcpy. Falls back to a copying ``np.load`` for payloads
        frombuffer can't express (Fortran order, object dtypes, odd
        headers)."""
        view = self.get_view(key)
        try:
            arr = _tensor_from_npy_view(view)
            if arr is not None:
                return arr
        except Exception:
            pass
        return np.load(io.BytesIO(bytes(view)), allow_pickle=False)

    # -- refcounting / GC --------------------------------------------------------
    def incref(self, key: str) -> None:
        with self._lock:
            self.refcounts[key] = self.refcounts.get(key, 0) + 1
            self._persist_refcounts()

    def decref(self, key: str) -> None:
        with self._lock:
            if key not in self.refcounts:
                return
            # clamp at zero: a double-release must not push the count negative
            # (a later incref would then resurrect a still-dead object)
            self.refcounts[key] = max(0, self.refcounts[key] - 1)
            self._persist_refcounts()

    @contextlib.contextmanager
    def batched_refcounts(self):
        """Coalesce refcount persistence across a multi-incref/decref
        operation (e.g. releasing a whole manifest) into ONE durable write at
        exit — otherwise every call rewrites refcounts.json, O(objects) each."""
        with self._lock:
            self._defer_persist += 1
        try:
            yield
        finally:
            with self._lock:
                self._defer_persist -= 1
                self._persist_refcounts()

    @contextlib.contextmanager
    def pin(self):
        """Reader lease (DESIGN.md §16.2).

        While any pin is held, :meth:`gc` only *logically* deletes dead
        objects (drops their refcount entries) — their bytes stay readable
        in packs/loose files, and pack compaction is deferred — so a reader
        that resolved keys before gc ran can finish its ranged reads/mget
        stream against a consistent store. The last pin release performs
        the deferred physical reclaim, re-checking refcounts first: a key
        re-put and re-referenced during the deferral window (resurrection)
        is kept."""
        with self._lock:
            self._pins += 1
        try:
            yield
        finally:
            with self._lock:
                self._pins -= 1
                if self._pins == 0 and self._deferred_dead:
                    self._reclaim_deferred_locked()

    @property
    def pins(self) -> int:
        with self._lock:
            return self._pins

    @property
    def gc_epoch(self) -> int:
        """Monotonic counter bumped by every :meth:`gc` call. Readers that
        snapshot it before resolving keys can detect a concurrent gc and
        abort-and-retry instead of trusting stale offsets."""
        with self._lock:
            return self._gc_epoch

    def deferred_dead_bytes(self) -> int:
        """Bytes logically dead but physically retained for active pins."""
        with self._lock:
            return sum(self._deferred_dead.values())

    def _object_size_locked(self, key: str) -> int:
        if self.root is None:
            return len(self._mem.get(key, b""))
        ent = self._pack_index.get(key)
        if ent is not None:
            return ent[2]
        p = self._obj_path(key)
        return os.path.getsize(p) if os.path.exists(p) else 0

    def _reclaim_one_locked(self, key: str) -> int:
        """Physically remove one object; returns payload bytes reclaimed."""
        if self.root is None:
            blob = self._mem.pop(key, None)
            if blob is None:
                return 0
            self._physical_bytes -= len(blob)
            self._object_count -= 1
            return len(blob)
        if key in self._pack_index:
            pid, _, length = self._pack_index.pop(key)
            self._pack_dead[pid] = self._pack_dead.get(pid, 0) + length
            self._object_count -= 1
            return length
        p = self._obj_path(key)
        if os.path.exists(p):
            n = os.path.getsize(p)
            self._physical_bytes -= n
            self._object_count -= 1
            os.remove(p)
            return n
        return 0

    def _reclaim_deferred_locked(self) -> int:
        reclaimed = 0
        for k in list(self._deferred_dead):
            self._deferred_dead.pop(k)
            if self.refcounts.get(k, 0) > 0:
                continue  # resurrected during the deferral window
            reclaimed += self._reclaim_one_locked(k)
        self._compact_packs()
        self._persist_refcounts()
        self._persist_pack_index()
        return reclaimed

    def gc(self) -> int:
        """Delete unreferenced objects; returns bytes reclaimed.

        Under active :meth:`pin` leases the dead set is removed from the
        refcount table immediately (unreachable to new readers that consult
        refcounts) but physical removal is deferred to the last pin release;
        the returned byte count includes deferred bytes — they are committed
        for reclaim and cannot be resurrected except by an explicit re-put."""
        reclaimed = 0
        with self._lock:
            kill_point("cas.gc.pre_reclaim")
            dead = [k for k, c in self.refcounts.items() if c <= 0]
            pinned = self._pins > 0
            for k in dead:
                del self.refcounts[k]
                if pinned:
                    size = self._object_size_locked(k)
                    self._deferred_dead[k] = size
                    reclaimed += size
                else:
                    reclaimed += self._reclaim_one_locked(k)
            if not pinned:
                self._compact_packs()
            self._gc_epoch += 1
            self._persist_refcounts()
            self._persist_pack_index()
        return reclaimed

    def compact(self, aggressive: bool = False) -> bool:
        """Explicit pack compaction (the hub maintenance entry point).

        ``aggressive=True`` rewrites every pack carrying ANY dead payload,
        not just those past the half-dead threshold. Refuses (returns
        False) while reader leases are pinned: compaction moves index
        entries between packs, and an in-flight mget preflight must see a
        stable index — the caller retries after the leases drain."""
        with self._lock:
            if self._pins > 0:
                return False
            self._compact_packs(aggressive=aggressive)
            self._persist_refcounts()
            self._persist_pack_index()
            return True

    def _compact_packs(self, aggressive: bool = False) -> None:
        """Rewrite packs whose dead payload exceeds half their size.

        Crash-safe ordering: live records are COPIED into the active pack and
        the index persisted BEFORE the old pack file is unlinked — a crash at
        any point leaves either the old locations (index not yet persisted)
        or the new ones plus an orphan pack, which ``_sweep_orphan_packs``
        removes on the next open. Live data is never the only copy at risk."""
        if self.root is None:
            return
        for pid, dead_bytes in list(self._pack_dead.items()):
            size = self._pack_sizes.get(pid, 0)
            if dead_bytes <= 0 or (not aggressive and dead_bytes * 2 < size):
                continue
            live = {k: e for k, e in self._pack_index.items() if e[0] == pid}
            path = self._pack_path(pid)
            if live:
                if self._next_pack == pid:
                    self._next_pack = pid + 1  # never copy into the victim
                with open(path, "rb") as f:
                    blobs = {}
                    for k, (_, off, length) in live.items():
                        f.seek(off)
                        blobs[k] = f.read(length)
                for k in live:
                    del self._pack_index[k]
                for k, blob in blobs.items():
                    self._write_packed(k, blob)
            self._pack_dead.pop(pid, None)
            # persist with the victim still fully accounted (so a crash here
            # cannot resurrect its dead records via a tail scan)...
            self._persist_pack_index()
            # ...then unlink and drop it from the books
            stale = self._batch_handles.pop(pid, None)
            if stale is not None:
                stale.close()
            self._mmap_pool.pop(path, None)  # live views keep the map alive
            if os.path.exists(path):
                os.remove(path)
            self._physical_bytes -= size
            self._pack_sizes.pop(pid, None)

    def _sweep_orphan_packs(self) -> None:
        """Remove fully-superseded packs left by a crash mid-compaction."""
        referenced = {e[0] for e in self._pack_index.values()}
        for pid in list(self._pack_sizes):
            if pid in referenced or pid == self._next_pack:
                continue
            path = self._pack_path(pid)
            size = self._pack_sizes[pid]
            if os.path.exists(path):
                os.remove(path)
            self._physical_bytes -= size
            self._pack_sizes.pop(pid, None)
            self._pack_dead.pop(pid, None)

    def _persist_refcounts(self) -> None:
        if self.root is None or self._defer_persist > 0:
            return
        tmp = os.path.join(self.root, "refcounts.json.tmp")
        with open(tmp, "w") as f:
            json.dump(self.refcounts, f)
        os.replace(tmp, os.path.join(self.root, "refcounts.json"))

    def flush(self) -> None:
        """Persist refcounts + pack index (called by stores at commit points)."""
        with self._lock:
            self._persist_refcounts()
            self._persist_pack_index()

    def reload(self) -> None:
        """Pick up objects appended by OTHER processes since open.

        Long-running readers (the serve daemon watching for publishes) see
        a snapshot of the pack index from open time; a writer process that
        commits afterwards appends records this instance has never indexed.
        Re-reading refcounts + the persisted index and tail-scanning the
        packs — exactly the open-time recovery pass — makes them visible.
        Read-only: torn tail records (a writer mid-append) are skipped,
        never truncated, and pooled mmaps remap on demand as packs grow."""
        if self.root is None:
            return
        with self._lock:
            rc = os.path.join(self.root, "refcounts.json")
            if os.path.exists(rc):
                with open(rc) as f:
                    self.refcounts = json.load(f)
            self._load_pack_index(truncate_torn=False)
            self._rebuild_counters()

    # -- integrity ----------------------------------------------------------------
    def keys(self) -> List[str]:
        """Every live object key (loose + packed, or in-memory)."""
        with self._lock:
            if self.root is None:
                return list(self._mem)
            objdir = os.path.join(self.root, "objects")
            loose = [f for f in os.listdir(objdir) if not f.endswith(".tmp")]
            return sorted(set(self._pack_index) | set(loose))

    def _verify_key(self, key: str, data: bytes) -> bool:
        """Check ``data`` reproduces its content-address ``key``.

        Five key schemes exist (DESIGN.md §3.2, §9.1, §12): manifests are
        ``"m_" + bytes_hash(payload)``; chunks are ``"c_" + bytes_hash(raw
        chunk bytes)``; diagnostics ledger entries are
        ``"t_" + bytes_hash(test_hash NUL manifest_key)`` re-derived from
        the payload's embedded pair; delta blobs and raw objects are
        ``bytes_hash(data)``; tensors are ``tensor_hash(arr)`` — a hash over
        (shape, dtype, raw bytes), NOT over the serialized npy stream — so
        tensor keys need a decode round-trip to re-derive."""
        if key.startswith("m_"):
            return bytes_hash(data) == key[2:]
        if key.startswith("c_"):
            return bytes_hash(data) == key[2:]
        if key.startswith("t_"):
            try:
                obj = json.loads(data)
                return ledger_key(obj["test_hash"], obj["manifest_key"]) == key
            except Exception:
                return False
        if bytes_hash(data) == key:
            return True
        try:
            arr = np.load(io.BytesIO(data), allow_pickle=False)
            return tensor_hash(arr) == key
        except Exception:
            return False

    def fsck(self) -> Dict[str, Any]:
        """Integrity pass: re-hash every object, cross-check refcounts.

        Reports ``corrupt`` objects (stored bytes no longer reproduce their
        key — bit rot or a torn write), ``dangling_refs`` (refcounted keys
        with no object behind them: these would crash on access) and
        ``untracked`` objects (present but unknown to the refcount table:
        unreachable until re-put, collected by nothing). Store-level drift
        against the manifest graph is layered on top by
        :meth:`repro.store.artifact_store.ArtifactStore.fsck`."""
        with self._lock:
            present = self.keys()
            corrupt: List[str] = []
            for key in present:
                try:
                    data = self.get_bytes(key)
                except Exception:
                    corrupt.append(key)
                    continue
                if not self._verify_key(key, data):
                    corrupt.append(key)
            present_set = set(present)
            dangling = sorted(k for k, c in self.refcounts.items()
                              if c > 0 and k not in present_set)
            # keys logically gc'd but physically retained for an active pin
            # are accounted-for, not untracked drift
            untracked = sorted(k for k in present_set
                               if k not in self.refcounts
                               and k not in self._deferred_dead)
            return {
                "objects_checked": len(present),
                "corrupt": corrupt,
                "dangling_refs": dangling,
                "untracked": untracked,
                "ok": not corrupt and not dangling,
            }

    # -- accounting ---------------------------------------------------------------
    def physical_bytes(self) -> int:
        """Total bytes on disk (or in memory) — O(1) counter."""
        return self._physical_bytes

    def object_count(self) -> int:
        """Live objects (loose + packed) — O(1) counter."""
        if self.root is None:
            return len(self._mem)
        return self._object_count

    def pack_stats(self) -> Dict[str, int]:
        return {
            "packs": len(self._pack_sizes),
            "packed_objects": len(self._pack_index),
            "packed_bytes": sum(self._pack_sizes.values()),
            "pack_dead_bytes": sum(self._pack_dead.values()),
        }
