"""Content-addressable store with refcounting (paper §4, content-based hashing).

Objects (tensors, delta blobs, manifests) are keyed by SHA-256 — writing the
same content twice costs nothing, which is exactly how parameters shared
across lineage-graph models are stored once. Supports a directory backend
(one file per object + a refcount journal) and an in-memory backend for
tests/benchmarks. All commits are atomic (tmp + rename).
"""

from __future__ import annotations

import io
import json
import os
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from repro.common.hashing import bytes_hash, tensor_hash


class CAS:
    def __init__(self, root: Optional[str] = None) -> None:
        self.root = root
        self._mem: Dict[str, bytes] = {}
        self.refcounts: Dict[str, int] = {}
        self._lock = threading.Lock()
        self.stats = {"puts": 0, "dedup_hits": 0, "bytes_written": 0,
                      "bytes_deduped": 0}
        if root is not None:
            os.makedirs(os.path.join(root, "objects"), exist_ok=True)
            rc = os.path.join(root, "refcounts.json")
            if os.path.exists(rc):
                with open(rc) as f:
                    self.refcounts = json.load(f)

    # -- raw object interface ------------------------------------------------
    def _obj_path(self, key: str) -> str:
        return os.path.join(self.root, "objects", key)

    def has(self, key: str) -> bool:
        if self.root is None:
            return key in self._mem
        return key in self.refcounts or os.path.exists(self._obj_path(key))

    def put_bytes(self, data: bytes, key: Optional[str] = None) -> str:
        key = key or bytes_hash(data)
        with self._lock:
            self.stats["puts"] += 1
            if self.has(key):
                self.stats["dedup_hits"] += 1
                self.stats["bytes_deduped"] += len(data)
                self.refcounts[key] = self.refcounts.get(key, 0) + 1
                return key
            if self.root is None:
                self._mem[key] = data
            else:
                tmp = self._obj_path(key) + ".tmp"
                with open(tmp, "wb") as f:
                    f.write(data)
                os.replace(tmp, self._obj_path(key))
            self.stats["bytes_written"] += len(data)
            self.refcounts[key] = self.refcounts.get(key, 0) + 1
            return key

    def get_bytes(self, key: str) -> bytes:
        if self.root is None:
            return self._mem[key]
        with open(self._obj_path(key), "rb") as f:
            return f.read()

    def size(self, key: str) -> int:
        if self.root is None:
            return len(self._mem[key])
        return os.path.getsize(self._obj_path(key))

    # -- tensors ---------------------------------------------------------------
    def put_tensor(self, arr: np.ndarray, key: Optional[str] = None) -> str:
        """Store a tensor (npy-serialized); key is its content hash."""
        arr = np.asarray(arr)
        key = key or tensor_hash(arr)
        if self.has(key):  # avoid serializing at all on a dedup hit
            with self._lock:
                self.stats["puts"] += 1
                self.stats["dedup_hits"] += 1
                self.stats["bytes_deduped"] += arr.nbytes
                self.refcounts[key] = self.refcounts.get(key, 0) + 1
            return key
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        return self.put_bytes(buf.getvalue(), key=key)

    def get_tensor(self, key: str) -> np.ndarray:
        return np.load(io.BytesIO(self.get_bytes(key)), allow_pickle=False)

    # -- refcounting / GC --------------------------------------------------------
    def incref(self, key: str) -> None:
        with self._lock:
            self.refcounts[key] = self.refcounts.get(key, 0) + 1

    def decref(self, key: str) -> None:
        with self._lock:
            if key not in self.refcounts:
                return
            self.refcounts[key] -= 1

    def gc(self) -> int:
        """Delete unreferenced objects; returns bytes reclaimed."""
        reclaimed = 0
        with self._lock:
            dead = [k for k, c in self.refcounts.items() if c <= 0]
            for k in dead:
                if self.root is None:
                    reclaimed += len(self._mem.pop(k, b""))
                else:
                    p = self._obj_path(k)
                    if os.path.exists(p):
                        reclaimed += os.path.getsize(p)
                        os.remove(p)
                del self.refcounts[k]
        self._persist_refcounts()
        return reclaimed

    def _persist_refcounts(self) -> None:
        if self.root is None:
            return
        tmp = os.path.join(self.root, "refcounts.json.tmp")
        with open(tmp, "w") as f:
            json.dump(self.refcounts, f)
        os.replace(tmp, os.path.join(self.root, "refcounts.json"))

    # -- accounting ---------------------------------------------------------------
    def physical_bytes(self) -> int:
        if self.root is None:
            return sum(len(v) for v in self._mem.values())
        objdir = os.path.join(self.root, "objects")
        return sum(os.path.getsize(os.path.join(objdir, f))
                   for f in os.listdir(objdir) if not f.endswith(".tmp"))

    def object_count(self) -> int:
        if self.root is None:
            return len(self._mem)
        return len(os.listdir(os.path.join(self.root, "objects")))
