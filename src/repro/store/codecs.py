"""Lossless codecs for quantized deltas (paper §4: RLE, LZMA, ...).

All codecs share one interface: ``encode(int32 ndarray) -> bytes`` and
``decode(bytes, n) -> int32 ndarray``. Quantized deltas of similar models are
dominated by zero runs, so RLE is fast/mediocre and LZMA is slow/strong —
exactly the paper's tradeoff (Table 4). ``sparse`` is a beyond-paper codec
(index+value pairs + zlib) that wins when density drops below ~5%.
"""

from __future__ import annotations

import lzma
import struct
import zlib
from typing import Dict

import numpy as np


class Codec:
    """Codecs are dtype-aware: the quantized delta may arrive as int8 (the
    fused snapshot kernel narrows when every value fits; §Perf-C) or int32."""

    name = "none"

    def encode(self, arr: np.ndarray) -> bytes:
        raise NotImplementedError

    def decode(self, data: bytes, n: int, dtype: str = "int32") -> np.ndarray:
        raise NotImplementedError


class RawCodec(Codec):
    name = "raw"

    def encode(self, arr: np.ndarray) -> bytes:
        return np.ascontiguousarray(arr).tobytes()

    def decode(self, data: bytes, n: int, dtype: str = "int32") -> np.ndarray:
        return np.frombuffer(data, dtype=np.dtype(dtype), count=n).copy()


class RLECodec(Codec):
    """Vectorized run-length encoding: header n_runs + values + runs(uint32)."""

    name = "rle"

    def encode(self, arr: np.ndarray) -> bytes:
        flat = np.ascontiguousarray(arr).ravel()
        if flat.size == 0:
            return struct.pack("<I", 0)
        boundaries = np.flatnonzero(np.diff(flat)) + 1
        starts = np.concatenate(([0], boundaries))
        ends = np.concatenate((boundaries, [flat.size]))
        values = flat[starts]
        runs = (ends - starts).astype(np.uint32)
        return struct.pack("<I", values.size) + values.tobytes() + runs.tobytes()

    def decode(self, data: bytes, n: int, dtype: str = "int32") -> np.ndarray:
        (k,) = struct.unpack("<I", data[:4])
        if n == 0 or k == 0:
            return np.zeros(n, dtype=np.dtype(dtype))
        item = np.dtype(dtype).itemsize
        values = np.frombuffer(data[4:4 + k * item], dtype=np.dtype(dtype))
        runs = np.frombuffer(data[4 + k * item:4 + k * item + 4 * k],
                             dtype=np.uint32)
        return np.repeat(values, runs.astype(np.int64))


class LZMACodec(Codec):
    """LZMA over raw bytes. preset=1 keeps runtime sane on large models
    with only a small ratio loss vs the default preset (see bench_compression)."""

    name = "lzma"

    def __init__(self, preset: int = 1) -> None:
        self.preset = preset

    def encode(self, arr: np.ndarray) -> bytes:
        return lzma.compress(np.ascontiguousarray(arr).tobytes(),
                             preset=self.preset)

    def decode(self, data: bytes, n: int, dtype: str = "int32") -> np.ndarray:
        return np.frombuffer(lzma.decompress(data), dtype=np.dtype(dtype),
                             count=n).copy()


class ZlibCodec(Codec):
    name = "zlib"

    def __init__(self, level: int = 6) -> None:
        self.level = level

    def encode(self, arr: np.ndarray) -> bytes:
        return zlib.compress(np.ascontiguousarray(arr).tobytes(), self.level)

    def decode(self, data: bytes, n: int, dtype: str = "int32") -> np.ndarray:
        return np.frombuffer(zlib.decompress(data), dtype=np.dtype(dtype),
                             count=n).copy()


class SparseCodec(Codec):
    """Beyond-paper: store (index-delta varint-ish uint32, value int32) of
    nonzeros, then zlib. Wins over RLE/LZMA below ~5% density."""

    name = "sparse"

    def encode(self, arr: np.ndarray) -> bytes:
        flat = np.ascontiguousarray(arr).ravel()
        idx = np.flatnonzero(flat).astype(np.uint32)
        vals = flat[idx]
        idx_delta = np.diff(idx, prepend=np.uint32(0)).astype(np.uint32)
        payload = struct.pack("<I", idx.size) + idx_delta.tobytes() + vals.tobytes()
        return zlib.compress(payload, 6)

    def decode(self, data: bytes, n: int, dtype: str = "int32") -> np.ndarray:
        dt = np.dtype(dtype)
        payload = zlib.decompress(data)
        (k,) = struct.unpack("<I", payload[:4])
        idx_delta = np.frombuffer(payload[4:4 + 4 * k], dtype=np.uint32)
        vals = np.frombuffer(payload[4 + 4 * k:4 + 4 * k + dt.itemsize * k],
                             dtype=dt)
        out = np.zeros(n, dtype=dt)
        out[np.cumsum(idx_delta.astype(np.int64))] = vals
        return out


class BytePlaneCodec(Codec):
    """Byte-plane shuffle + zlib for *lossless* bitpattern deltas (§15).

    The step-delta engine stores exact-tier hops as the elementwise
    difference of the raw bit patterns (mod 2^width, see
    :func:`bitpattern_delta`). Between consecutive optimizer steps most
    elements change only in their low-order mantissa bytes, so grouping
    byte position k of every element into one contiguous plane puts the
    all-zero sign/exponent planes next to each other and lets a cheap
    zlib level-1 pass erase them. Level 1 keeps the encode on the training
    hot path (~step time budget); the container is self-describing so
    readers don't care."""

    name = "xd"

    def __init__(self, level: int = 1) -> None:
        self.level = level

    def encode(self, arr: np.ndarray) -> bytes:
        a = np.ascontiguousarray(arr)
        item = a.dtype.itemsize
        planes = a.view(np.uint8).reshape(-1, item).T
        return zlib.compress(np.ascontiguousarray(planes).tobytes(), self.level)

    def decode(self, data: bytes, n: int, dtype: str = "uint32") -> np.ndarray:
        dt = np.dtype(dtype)
        planes = np.frombuffer(zlib.decompress(data), dtype=np.uint8)
        planes = planes.reshape(dt.itemsize, n)
        return np.ascontiguousarray(planes.T).reshape(-1).view(dt)


def _bitwidth_dtype(itemsize: int) -> np.dtype:
    return {8: np.dtype(np.uint64), 4: np.dtype(np.uint32),
            2: np.dtype(np.uint16)}.get(itemsize, np.dtype(np.uint8))


def bitpattern_delta(child: np.ndarray, parent: np.ndarray) -> np.ndarray:
    """Lossless delta: raw-bits subtraction mod 2^width, elementwise.

    Works for any dtype (floats are viewed as unsigned ints of the same
    width; odd itemsizes fall back to a byte-wise view). The inverse is
    :func:`bitpattern_apply`; ``child == apply(parent, delta)`` holds
    bit-for-bit, which is what makes the exact checkpoint tier resumable
    with no drift."""
    c = np.ascontiguousarray(child)
    p = np.ascontiguousarray(parent)
    ud = _bitwidth_dtype(c.dtype.itemsize)
    cv = c.view(ud).ravel() if ud.itemsize == c.dtype.itemsize else c.view(np.uint8).ravel()
    pv = p.view(ud).ravel() if ud.itemsize == p.dtype.itemsize else p.view(np.uint8).ravel()
    return cv - pv  # unsigned wraparound is the point


def bitpattern_apply(parent: np.ndarray, delta: np.ndarray,
                     dtype: str, shape) -> np.ndarray:
    """Inverse of :func:`bitpattern_delta`: reconstruct the child exactly."""
    dt = np.dtype(dtype)
    p = np.ascontiguousarray(parent)
    ud = delta.dtype
    pv = p.view(ud).ravel() if ud.itemsize == dt.itemsize else p.view(np.uint8).ravel()
    child = (pv + delta).view(np.uint8).reshape(-1)
    return child.view(dt).reshape(shape)


CODECS: Dict[str, Codec] = {
    "raw": RawCodec(),
    "rle": RLECodec(),
    "lzma": LZMACodec(),
    "lzma6": LZMACodec(preset=6),
    "zlib": ZlibCodec(),
    "sparse": SparseCodec(),
    "xd": BytePlaneCodec(),
}

#: nonzero density below which ``sparse`` reliably beats the run-based
#: codecs on quantized deltas (bench_compression's crossover, with margin)
SPARSE_DENSITY = 0.05


def pick_codec(nonzeros: int, n: int, default: Codec) -> Codec:
    """Density-adaptive codec choice for one quantized delta.

    Chunk-level delta encoding (DESIGN.md §12) makes density wildly
    non-uniform *within* one tensor: chunks near a localized edit are dense
    while the rest of the touched chunks carry a handful of stragglers. The
    nonzero count comes out of the snapshot kernel for free, so each blob
    can pick ``sparse`` below the crossover instead of paying the whole-
    tensor compromise codec. Whole-tensor delta blobs keep ``default``
    unconditionally — their density already informed the store-level codec
    configuration."""
    if n > 0 and nonzeros / n < SPARSE_DENSITY:
        return CODECS["sparse"]
    return default

_TUNED: Dict[tuple, Codec] = {}


def get_codec(name: str, preset: int = None) -> Codec:
    """Codec by name, optionally tuned.

    ``preset`` selects the LZMA preset (0 fastest … 9 strongest) or the
    zlib level. Decoding is container-self-describing for both, so the
    manifest only records the codec *name* — readers never need to know the
    preset the writer used. Tuned instances are cached (codec objects are
    stateless)."""
    if preset is None:
        return CODECS[name]
    key = (name, preset)
    if key not in _TUNED:
        if name == "lzma":
            _TUNED[key] = LZMACodec(preset=preset)
        elif name == "zlib":
            _TUNED[key] = ZlibCodec(level=preset)
        else:
            _TUNED[key] = CODECS[name]  # preset is a no-op for this codec
    return _TUNED[key]
