"""ArtifactStore — manifests binding the CAS + delta compression to lineage nodes.

Committing an artifact produces a *manifest* (JSON, itself CAS-stored):

    {name, model_type, graph, metadata, depth,
     params: {key: {kind: "full", tensor: <hash>}
                  | {kind: "delta", blob: <hash>, parent_ref, parent_key,
                     codec, eps, shape, dtype, hash}}}

Full tensors dedup automatically through content hashing; delta entries point
at their parent manifest (paper §4). ``max_chain_depth`` bounds reconstruction
latency, like git packfile delta-depth limits (beyond-paper knob).

Reconstruction is *plan-based and lazy* (DESIGN.md §3.3–3.4):

* ``load_artifact`` returns a lazy artifact whose params materialize
  per-tensor on first access — checkout/diff/traversal never force a full
  model into memory;
* ``resolve_chain(ref, key)`` walks one parameter's delta chain iteratively
  and emits a flat :class:`ReconstructionPlan` — ``(blob, parent)`` hops down
  to the first full tensor (or a cache hit);
* ``materialize_param`` executes the plan bottom-up with one
  ``dequant_apply`` per hop, so peak memory is O(tensor x chain depth), not
  O(full model x chain depth) like the old recursive whole-artifact loader
  (kept as ``load_artifact_recursive`` — the benchmark baseline);
* materialized tensors land in a byte-budget LRU (``cache_budget_bytes``)
  shared by every artifact the store serves.
"""

from __future__ import annotations

import dataclasses
import io
import json
import os
import threading
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.hashing import bytes_hash, tensor_hash
from repro.core.artifact import LazyParams, ModelArtifact, ParamRef
from repro.core.graphir import LayerGraph
from repro.store.cas import CAS
from repro.store.delta import (CompressResult, ParamDelta, decompress_param,
                               delta_compression)
from repro.store.manifest_walk import walk_manifests


# ---------------------------------------------------------------------------
# Reconstruction plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeltaHop:
    """One delta application: child (ref, key) reconstructed from its parent."""

    ref: str            # manifest holding this delta entry
    key: str            # child param key
    blob: str           # CAS key of the compressed quantized delta
    codec: str
    eps: float
    shape: Tuple[int, ...]
    dtype: str
    qdtype: str


@dataclasses.dataclass(frozen=True)
class ReconstructionPlan:
    """Flat recipe for one parameter: start at ``base``, apply ``hops`` in order.

    ``base_kind`` is ``"full"`` (base is a CAS tensor hash) or ``"cache"``
    (base is a (ref, key) already materialized in the tensor cache)."""

    base_kind: str
    base: Any
    hops: Tuple[DeltaHop, ...]

    @property
    def depth(self) -> int:
        return len(self.hops)


class TensorCache:
    """Byte-budget LRU over materialized tensors, keyed by (manifest_ref, key).

    Mutations are guarded by an RLock: the diagnostics runner (DESIGN.md §9)
    materializes parameters from a thread pool, and an unguarded
    ``move_to_end`` racing an eviction corrupts the OrderedDict."""

    def __init__(self, budget_bytes: int) -> None:
        self.budget_bytes = budget_bytes
        self._entries: "OrderedDict[Tuple[str, str], np.ndarray]" = OrderedDict()
        self._lock = threading.RLock()
        self.bytes_used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Tuple[str, str]) -> Optional[np.ndarray]:
        with self._lock:
            arr = self._entries.get(key)
            if arr is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return arr

    def put(self, key: Tuple[str, str], arr: np.ndarray) -> None:
        nbytes = int(arr.nbytes)
        if nbytes > self.budget_bytes:
            return  # larger than the whole budget: never cacheable
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes_used -= int(old.nbytes)
            self._entries[key] = arr
            self.bytes_used += nbytes
            while self.bytes_used > self.budget_bytes and self._entries:
                _, evicted = self._entries.popitem(last=False)
                self.bytes_used -= int(evicted.nbytes)
                self.evictions += 1

    def contains(self, key: Tuple[str, str]) -> bool:
        with self._lock:
            return key in self._entries

    def drop_ref(self, ref: str) -> None:
        with self._lock:
            for k in [k for k in self._entries if k[0] == ref]:
                self.bytes_used -= int(self._entries.pop(k).nbytes)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.bytes_used = 0

    def __len__(self) -> int:
        return len(self._entries)


class ArtifactStore:
    """The ``store`` object a :class:`repro.core.LineageGraph` plugs into."""

    def __init__(self, root: Optional[str] = None, codec: str = "lzma",
                 eps: float = 1e-4, t_thr: float = 0.5,
                 delta_enabled: bool = True, per_param: bool = True,
                 max_chain_depth: int = 8,
                 cache_budget_bytes: int = 256 * 2**20,
                 zero_frac_prefilter: float = 0.0,
                 backend: Optional[str] = None,
                 pack_threshold: int = 4096) -> None:
        self.cas = CAS(root, pack_threshold=pack_threshold)
        self.codec = codec
        self.eps = eps
        self.t_thr = t_thr
        self.delta_enabled = delta_enabled
        self.per_param = per_param
        self.max_chain_depth = max_chain_depth
        self.zero_frac_prefilter = zero_frac_prefilter
        self.backend = backend
        self._manifests: Dict[str, Dict[str, Any]] = {}
        self.cache = TensorCache(cache_budget_bytes)
        self.logical_bytes = 0
        self.last_result: Optional[CompressResult] = None
        # per-store materialization accounting (reset with reset_io_stats)
        self.io_stats = {"tensors_materialized": 0, "bytes_materialized": 0,
                         "chain_hops": 0, "plans_resolved": 0}
        self._stats_path = (os.path.join(root, "store_stats.json")
                            if root else None)
        if self._stats_path and os.path.exists(self._stats_path):
            with open(self._stats_path) as f:
                self.logical_bytes = json.load(f).get("logical_bytes", 0)

    # -- commit -----------------------------------------------------------------
    def commit_artifact(self, name: str, artifact: ModelArtifact,
                        parent_ref: Optional[str] = None,
                        tests: Sequence = ()) -> str:
        self.logical_bytes += artifact.nbytes()
        self._persist_stats()
        entries: Dict[str, Any] = {}
        depth = 0

        deltas = {}
        if self.delta_enabled and parent_ref is not None:
            parent_manifest = self.get_manifest(parent_ref)
            if parent_manifest["depth"] < self.max_chain_depth:
                # lazy view: delta_compression materializes parent params
                # one-at-a-time through the chain resolver
                parent = self.load_artifact(parent_ref)
                result = delta_compression(
                    artifact, parent, t_thr=self.t_thr, eps=self.eps,
                    codec=self.codec, tests=tests, per_param=self.per_param,
                    zero_frac_prefilter=self.zero_frac_prefilter,
                    backend=self.backend)
                self.last_result = result
                if result.accepted:
                    deltas = result.deltas
                    depth = parent_manifest["depth"] + 1
                    # persist the *reconstructed* model as this version's truth
                    artifact = result.reconstructed

        for key in artifact.params:
            value = np.asarray(artifact.params[key])
            thash = tensor_hash(value)  # content identity for every entry
            if key in deltas:
                d = deltas[key]
                blob_hash = self.cas.put_bytes(d.blob)
                entries[key] = {"kind": "delta", "blob": blob_hash,
                                "parent_ref": parent_ref,
                                "parent_key": d.parent_key, "codec": d.codec,
                                "eps": d.eps, "shape": list(d.shape),
                                "dtype": d.dtype, "qdtype": d.qdtype,
                                "hash": thash}
            else:
                self.cas.put_tensor(value, key=thash)  # content-hash dedup
                entries[key] = {"kind": "full", "tensor": thash,
                                "shape": list(value.shape),
                                "dtype": str(value.dtype), "hash": thash}

        delta_parents = sorted({e["parent_ref"] for e in entries.values()
                                if e["kind"] == "delta"})
        for pref in delta_parents:
            self.cas.incref(pref)  # chain dependency: parent must outlive child
        manifest = {
            "name": name,
            "model_type": artifact.model_type,
            "metadata": artifact.metadata,
            "graph": artifact.graph.to_json(),
            "params": entries,
            "depth": depth,
            "delta_parents": delta_parents,
        }
        payload = json.dumps(manifest, sort_keys=True, default=str).encode()
        ref = self.cas.put_bytes(payload, key="m_" + bytes_hash(payload))
        self._manifests[ref] = manifest
        self.cas.flush()  # commit point: index + refcounts durable
        return ref

    # -- manifests ----------------------------------------------------------------
    def get_manifest(self, ref: str) -> Dict[str, Any]:
        if ref not in self._manifests:
            self._manifests[ref] = json.loads(self.cas.get_bytes(ref))
        return self._manifests[ref]

    def _entry(self, ref: str, key: str) -> Dict[str, Any]:
        manifest = self.get_manifest(ref)
        try:
            return manifest["params"][key]
        except KeyError:
            raise KeyError(f"manifest {ref!r} has no param {key!r}")

    # -- chain resolution ---------------------------------------------------------
    def resolve_chain(self, ref: str, key: str) -> ReconstructionPlan:
        """Walk one parameter's delta chain; emit a flat reconstruction plan.

        Iterative (no recursion) and single-parameter: sibling tensors are
        never touched. The walk stops early at the first chain link already
        materialized in the tensor cache."""
        self.io_stats["plans_resolved"] += 1
        hops: List[DeltaHop] = []
        cur_ref, cur_key = ref, key
        # Termination is a visited-set, NOT this store's max_chain_depth:
        # the store may have been reopened with a smaller depth knob than the
        # one the chain was written with, and that is valid data.
        seen = set()
        while True:
            if (cur_ref, cur_key) in seen:
                raise RuntimeError(
                    f"delta chain cycle at {cur_ref!r}:{cur_key!r} "
                    f"(corrupt manifest chain)")
            seen.add((cur_ref, cur_key))
            if hops and self.cache.contains((cur_ref, cur_key)):
                return ReconstructionPlan("cache", (cur_ref, cur_key),
                                          tuple(reversed(hops)))
            e = self._entry(cur_ref, cur_key)
            if e["kind"] == "full":
                return ReconstructionPlan("full", e["tensor"],
                                          tuple(reversed(hops)))
            hops.append(DeltaHop(
                ref=cur_ref, key=cur_key, blob=e["blob"], codec=e["codec"],
                eps=e["eps"], shape=tuple(e["shape"]), dtype=e["dtype"],
                qdtype=e.get("qdtype", "int32")))
            cur_ref, cur_key = e["parent_ref"], e["parent_key"]

    def materialize_param(self, ref: str, key: str,
                          plan: Optional[ReconstructionPlan] = None
                          ) -> np.ndarray:
        """Materialize one parameter, executing its plan bottom-up.

        Pass ``plan`` to execute a chain already resolved by
        ``resolve_chain`` (avoids a second manifest walk)."""
        cached = self.cache.get((ref, key))
        if cached is not None:
            return cached
        if plan is None:
            plan = self.resolve_chain(ref, key)
        if plan.base_kind == "cache":
            value = self.cache.get(plan.base)
            if value is None:  # evicted between resolve and execute: replan
                self.cache.misses -= 1  # don't double-count the probe
                return self.materialize_param(ref, key)
        else:
            value = self.cas.get_tensor(plan.base)
            self._count_materialization(value)
        for hop in plan.hops:
            d = ParamDelta(child_key=hop.key, parent_key="", codec=hop.codec,
                           blob=self.cas.get_bytes(hop.blob), eps=hop.eps,
                           shape=hop.shape, dtype=hop.dtype, raw_bytes=0,
                           qdtype=hop.qdtype)
            value = decompress_param(np.asarray(value), d,
                                     backend=self.backend)
            self.io_stats["chain_hops"] += 1
            self._count_materialization(value)
            self.cache.put((hop.ref, hop.key), value)
        if not plan.hops:  # full tensors cache under their own (ref, key) too
            self.cache.put((ref, key), value)
        return value

    def _count_materialization(self, value: np.ndarray) -> None:
        self.io_stats["tensors_materialized"] += 1
        self.io_stats["bytes_materialized"] += int(np.asarray(value).nbytes)

    def reset_io_stats(self) -> None:
        for k in self.io_stats:
            self.io_stats[k] = 0

    # -- load --------------------------------------------------------------------
    def load_artifact(self, ref: str, lazy: bool = True) -> ModelArtifact:
        """Checkout ``ref``. Lazy by default: params materialize on access."""
        manifest = self.get_manifest(ref)
        refs = {
            key: ParamRef(store=self, ref=ref, key=key,
                          shape=tuple(e.get("shape", ())),
                          dtype=e.get("dtype", "float32"),
                          hash=e.get("hash") or e.get("tensor"))
            for key, e in manifest["params"].items()
        }
        params: Any = LazyParams(refs)
        if not lazy:
            params = {k: params[k] for k in params}
        return ModelArtifact(
            graph=LayerGraph.from_json(manifest["graph"]),
            params=params,
            model_type=manifest.get("model_type", "generic"),
            metadata=manifest.get("metadata", {}),
        )

    def load_artifact_recursive(self, ref: str,
                                _depth: int = 0) -> ModelArtifact:
        """Pre-plan eager loader (reference implementation).

        Recursively materializes every FULL ancestor artifact to resolve the
        chain — O(full model x chain depth) peak memory. Kept as the
        benchmark baseline for ``benchmarks/bench_compression.py``; all
        production paths go through ``load_artifact``/``materialize_param``."""
        manifest = self.get_manifest(ref)
        params: Dict[str, np.ndarray] = {}
        parent_cache: Dict[str, ModelArtifact] = {}
        for key, e in manifest["params"].items():
            if e["kind"] == "full":
                params[key] = self.cas.get_tensor(e["tensor"])
            else:
                pref = e["parent_ref"]
                if pref not in parent_cache:
                    parent_cache[pref] = self.load_artifact_recursive(
                        pref, _depth + 1)
                parent_val = parent_cache[pref].params[e["parent_key"]]
                d = ParamDelta(child_key=key, parent_key=e["parent_key"],
                               blob=self.cas.get_bytes(e["blob"]),
                               codec=e["codec"], eps=e["eps"],
                               shape=tuple(e["shape"]), dtype=e["dtype"],
                               raw_bytes=0, qdtype=e.get("qdtype", "int32"))
                params[key] = decompress_param(np.asarray(parent_val), d,
                                               backend=self.backend)
        return ModelArtifact(
            graph=LayerGraph.from_json(manifest["graph"]),
            params=params,
            model_type=manifest.get("model_type", "generic"),
            metadata=manifest.get("metadata", {}),
        )

    # -- sync/integrity support (DESIGN.md §8) ------------------------------------
    def manifest_closure(self, refs: Sequence[str]
                         ) -> Tuple[Dict[str, Any], List[str]]:
        """Transitive storage dependencies of ``refs`` along delta chains.

        Returns ``(closure, missing)``: ``{manifest_ref: ManifestInfo}`` via
        the shared walk (``repro.store.manifest_walk``) plus the refs that
        could not be read."""
        missing: List[str] = []

        def fetch(keys: Sequence[str]) -> Dict[str, bytes]:
            out: Dict[str, bytes] = {}
            for k in keys:
                try:
                    out[k] = self.cas.get_bytes(k)
                except Exception:
                    pass  # the walk records it as missing
            return out

        closure = walk_manifests(fetch, refs, missing=missing)
        return closure, missing

    def expected_refcounts(self, roots: Sequence[str]) -> Dict[str, int]:
        """Reconstruct exact refcounts from the manifest graph.

        Mirrors commit-time accounting: each manifest holds one reference
        per param entry on its tensor/blob and one per delta parent; each
        occurrence in ``roots`` (a lineage ``artifact_ref``) holds one
        reference on the manifest itself. Only keys *reachable from roots*
        appear — counts for anything else are out of scope."""
        closure, _ = self.manifest_closure(roots)
        counts: Dict[str, int] = {ref: 0 for ref in closure}
        for info in closure.values():
            for k in info.objects:
                counts[k] = counts.get(k, 0) + 1
            for p in info.parents:
                counts[p] = counts.get(p, 0) + 1
        for r in roots:
            if r in closure:
                counts[r] += 1
        return counts

    def rebuild_refcounts(self, roots: Sequence[str]) -> Dict[str, int]:
        """Install exact refcounts for everything reachable from ``roots``.

        The post-transfer step of a sync (DESIGN.md §8.5): imported objects
        arrive with placeholder counts; one rebuild makes the receiving side
        bit-equivalent to having committed the graph locally. Keys NOT
        reachable from ``roots`` are left untouched, so callers owning other
        root sets lose nothing."""
        counts = self.expected_refcounts(roots)
        with self.cas.batched_refcounts():
            for key, count in counts.items():
                if self.cas.has(key):
                    self.cas.refcounts[key] = count
        self.cas.flush()
        return counts

    def import_objects(self, objects) -> int:
        """Raw object ingestion for sync transfers (idempotent per key).

        Keys are trusted as content addresses here; ``fsck`` re-verifies.
        Returns bytes actually written (dedup hits cost nothing)."""
        written = 0
        for key, data in objects.items():
            if not self.cas.has(key):
                self.cas.put_bytes(data, key=key)
                written += len(data)
        self.cas.flush()
        return written

    def export_flat_manifest(self, ref: str, name: Optional[str] = None
                             ) -> Tuple[str, Dict[str, bytes]]:
        """Build a flattened (depth-0) equivalent of ``ref`` *transiently*.

        The shallow-push fallback: when a receiver can't get the delta
        chain, ship materialized tensors instead. Returns ``(flat_ref,
        objects)`` where ``objects`` holds the new manifest payload plus
        every tensor's npy bytes, ready for the wire. Nothing is committed
        into THIS store — a sender must stay refcount-clean after a push
        (committing here would orphan a manifest no lineage node references
        and bump shared-tensor counts into permanent fsck drift). Peak
        memory is O(model): tensors materialize through the chain resolver
        one at a time but their serialized bytes are all held for transfer.
        Plan execution is bit-exact with commit-time reconstruction
        (DESIGN.md §3.3), so the flattened model is bit-identical to the
        chained one."""
        manifest = self.get_manifest(ref)
        artifact = self.load_artifact(ref)
        entries: Dict[str, Any] = {}
        objects: Dict[str, bytes] = {}
        for key in artifact.params:
            value = np.asarray(artifact.params[key])
            thash = tensor_hash(value)
            buf = io.BytesIO()
            np.save(buf, value, allow_pickle=False)
            objects[thash] = buf.getvalue()
            entries[key] = {"kind": "full", "tensor": thash,
                            "shape": list(value.shape),
                            "dtype": str(value.dtype), "hash": thash}
        flat = {
            "name": name or manifest.get("name", "flat"),
            "model_type": manifest.get("model_type", "generic"),
            "metadata": manifest.get("metadata", {}),
            "graph": manifest["graph"],
            "params": entries,
            "depth": 0,
            "delta_parents": [],
        }
        payload = json.dumps(flat, sort_keys=True, default=str).encode()
        flat_ref = "m_" + bytes_hash(payload)
        objects[flat_ref] = payload
        return flat_ref, objects

    def fsck(self, roots: Sequence[str] = ()) -> Dict[str, Any]:
        """CAS integrity pass plus manifest-graph cross-checks.

        Extends :meth:`CAS.fsck` with: ``missing_objects`` (keys the manifest
        closure of ``roots`` references but the CAS lacks) and
        ``refcount_drift`` (``{key: [actual, expected]}``; undercounts risk
        premature collection, overcounts only delay it)."""
        report = self.cas.fsck()
        closure, missing_refs = self.manifest_closure(roots)
        expected = self.expected_refcounts(roots)
        missing = sorted(set(missing_refs)
                         | {k for k in expected if not self.cas.has(k)})
        drift = {k: [self.cas.refcounts.get(k, 0), v]
                 for k, v in expected.items()
                 if self.cas.has(k) and self.cas.refcounts.get(k, 0) != v}
        report["manifests_reachable"] = len(closure)
        report["missing_objects"] = missing
        report["refcount_drift"] = drift
        report["ok"] = bool(report["ok"] and not missing and not drift)
        return report

    # -- lifecycle ------------------------------------------------------------------
    def release(self, ref: str) -> None:
        """Drop one reference to a manifest and everything it points at."""
        try:
            manifest = self.get_manifest(ref)
        except Exception:
            return
        with self.cas.batched_refcounts():  # ONE durable write for the lot
            for e in manifest["params"].values():
                self.cas.decref(e["tensor"] if e["kind"] == "full"
                                else e["blob"])
            for pref in manifest.get("delta_parents", []):
                self.cas.decref(pref)
            self.cas.decref(ref)
        self.cache.drop_ref(ref)

    def gc(self) -> int:
        return self.cas.gc()

    def _persist_stats(self) -> None:
        if self._stats_path is None:
            return
        tmp = self._stats_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"logical_bytes": self.logical_bytes}, f)
        os.replace(tmp, self._stats_path)

    # -- accounting -------------------------------------------------------------------
    def compression_ratio(self) -> float:
        return self.logical_bytes / max(self.cas.physical_bytes(), 1)

    def stats(self) -> Dict[str, Any]:
        return {
            "logical_bytes": self.logical_bytes,
            "physical_bytes": self.cas.physical_bytes(),
            "compression_ratio": self.compression_ratio(),
            "objects": self.cas.object_count(),
            "cache_bytes": self.cache.bytes_used,
            "cache_entries": len(self.cache),
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "cache_evictions": self.cache.evictions,
            **self.cas.pack_stats(),
            **self.cas.stats,
        }
