"""ArtifactStore — manifests binding the CAS + delta compression to lineage nodes.

Committing an artifact produces a *manifest* (JSON, itself CAS-stored):

    {name, model_type, graph, metadata, depth,
     params: {key: {kind: "full", tensor: <hash>}
                  | {kind: "delta", blob: <hash>, parent_ref, parent_key,
                     codec, eps, shape, dtype, hash}}}

Full tensors dedup automatically through content hashing; delta entries point
at their parent manifest (paper §4). ``max_chain_depth`` bounds reconstruction
latency, like git packfile delta-depth limits (beyond-paper knob).

Reconstruction is *plan-based and lazy* (DESIGN.md §3.3–3.4), and both hot
paths are batched, pipelined engines (DESIGN.md §10):

* ``load_artifact`` returns a lazy artifact whose params materialize
  per-tensor on first access — checkout/diff/traversal never force a full
  model into memory;
* ``resolve_chain(ref, key)`` walks one parameter's delta chain iteratively
  and emits a flat :class:`ReconstructionPlan` — ``(blob, parent)`` hops down
  to the first full tensor (or a cache hit);
* ``materialize_param`` executes the chain with *segment folding*: runs of
  same-eps float32 hops accumulate into one exact int32 delta sum and apply
  as a SINGLE dequant (dequant is linear in q at fixed eps) — a depth-k
  uniform chain costs one dequant instead of k. Mixed-eps / non-f32 hops
  fall back to hop-by-hop within their own segments (§10.2);
* ``materialize_artifact`` is the batched checkout: per-param chains resolve
  against shared manifest/fold state and decode+fold fans out across a
  thread pool (LZMA decode releases the GIL);
* ``commit_artifact`` is a pipelined encoder by default: device quantization
  (``ops.snapshot_fused``) overlaps host codec work on a thread pool, the
  parent's reconstruction state resolves once per chain, and all objects
  land through one buffered ``CAS.batch()`` with a single fsync at the
  commit point. ``pipelined=False`` preserves the serial PR-1 path as the
  benchmark baseline (it implies ``fold_enabled=False`` — the two paths
  define reconstruction truth differently and must not be mixed in one
  store, §10.2);
* materialized tensors land in a byte-budget LRU (``cache_budget_bytes``)
  shared by every artifact the store serves; fold states (the open-segment
  ``(seg_base, Σq)`` pairs that let chains *extend* bit-exactly) land in a
  sibling :class:`FoldCache`.
"""

from __future__ import annotations

import dataclasses
import io
import json
import os
import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.hashing import TensorHasher, bytes_hash, tensor_hash
from repro.core.artifact import LazyParams, ModelArtifact, ParamRef
from repro.core.graphir import LayerGraph
from repro.obs import REGISTRY, propagate, span
from repro.store import chunks as chunklib
from repro.store.cas import CAS, DEFAULT_PACK_THRESHOLD
from repro.store.codecs import (bitpattern_apply, bitpattern_delta,
                                get_codec, pick_codec)
from repro.store.delta import (CompressResult, ParamDelta, decode_q,
                               decompress_param, delta_compression,
                               host_dequant, host_snapshot,
                               lcs_param_matching)
from repro.store.manifest_walk import walk_manifests


# ---------------------------------------------------------------------------
# Reconstruction plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeltaHop:
    """One delta application: child (ref, key) reconstructed from its parent."""

    ref: str            # manifest holding this delta entry
    key: str            # child param key
    blob: str           # CAS key of the compressed quantized delta
    codec: str
    eps: float
    shape: Tuple[int, ...]
    dtype: str
    qdtype: str


@dataclasses.dataclass(frozen=True)
class ReconstructionPlan:
    """Flat recipe for one parameter: start at ``base``, apply ``hops`` in order.

    ``base_kind`` is ``"full"`` (base is a CAS tensor hash) or ``"cache"``
    (base is a (ref, key) already materialized in the tensor cache)."""

    base_kind: str
    base: Any
    hops: Tuple[DeltaHop, ...]

    @property
    def depth(self) -> int:
        return len(self.hops)


@dataclasses.dataclass(frozen=True)
class FoldState:
    """Open-segment reconstruction state of one materialized parameter.

    The param's canonical value is ``dequant(seg_base, q_open, eps)``; a
    child hop with the same eps *extends* the segment bit-exactly:
    ``dequant(seg_base, q_open + q_child, eps)`` (int32 sums are exact, so
    the fold is associative even though float dequant is not). This is what
    lets commit derive a child's stored truth in one dequant and checkout
    collapse whole chains (DESIGN.md §10.2)."""

    seg_base: np.ndarray   # value BEFORE the open segment (read-only)
    q_open: np.ndarray     # int32 sum of the open segment's quantized deltas
    eps: float

    @property
    def nbytes(self) -> int:
        return int(self.seg_base.nbytes) + int(self.q_open.nbytes)


class TensorCache:
    """Byte-budget LRU over materialized tensors, keyed by (manifest_ref, key).

    Mutations are guarded by an RLock: the diagnostics runner (DESIGN.md §9)
    materializes parameters from a thread pool, and an unguarded
    ``move_to_end`` racing an eviction corrupts the OrderedDict."""

    def __init__(self, budget_bytes: int) -> None:
        self.budget_bytes = budget_bytes
        self._entries: "OrderedDict[Tuple[str, str], np.ndarray]" = OrderedDict()
        self._lock = threading.RLock()
        self.bytes_used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Tuple[str, str]) -> Optional[np.ndarray]:
        with self._lock:
            arr = self._entries.get(key)
            if arr is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return arr

    def put(self, key: Tuple[str, str], arr: np.ndarray) -> None:
        nbytes = int(arr.nbytes)
        if nbytes > self.budget_bytes:
            return  # larger than the whole budget: never cacheable
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes_used -= int(old.nbytes)
            self._entries[key] = arr
            self.bytes_used += nbytes
            while self.bytes_used > self.budget_bytes and self._entries:
                _, evicted = self._entries.popitem(last=False)
                self.bytes_used -= int(evicted.nbytes)
                self.evictions += 1

    def contains(self, key: Tuple[str, str]) -> bool:
        with self._lock:
            return key in self._entries

    def drop_ref(self, ref: str) -> None:
        with self._lock:
            for k in [k for k in self._entries if k[0] == ref]:
                self.bytes_used -= int(self._entries.pop(k).nbytes)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.bytes_used = 0

    def __len__(self) -> int:
        return len(self._entries)


class FoldCache:
    """Byte-budget LRU over :class:`FoldState`, keyed by (manifest_ref, key).

    Purely a performance cache: a fold state is always recomputable from the
    chain, and extending from a cached state is bit-exact by construction
    (int32 sums), so eviction can never change reconstruction results."""

    def __init__(self, budget_bytes: int) -> None:
        self.budget_bytes = budget_bytes
        self._entries: "OrderedDict[Tuple[str, str], FoldState]" = OrderedDict()
        self._lock = threading.RLock()
        self.bytes_used = 0

    def get(self, key: Tuple[str, str]) -> Optional[FoldState]:
        with self._lock:
            fs = self._entries.get(key)
            if fs is not None:
                self._entries.move_to_end(key)
            return fs

    def put(self, key: Tuple[str, str], fs: FoldState) -> None:
        if fs.nbytes > self.budget_bytes:
            return
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes_used -= old.nbytes
            self._entries[key] = fs
            self.bytes_used += fs.nbytes
            while self.bytes_used > self.budget_bytes and self._entries:
                _, evicted = self._entries.popitem(last=False)
                self.bytes_used -= evicted.nbytes

    def drop_ref(self, ref: str) -> None:
        with self._lock:
            for k in [k for k in self._entries if k[0] == ref]:
                self.bytes_used -= self._entries.pop(k).nbytes

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.bytes_used = 0

    def __len__(self) -> int:
        return len(self._entries)


class ArtifactStore:
    """The ``store`` object a :class:`repro.core.LineageGraph` plugs into."""

    def __init__(self, root: Optional[str] = None, codec: str = "lzma",
                 eps: float = 1e-4, t_thr: float = 0.5,
                 delta_enabled: bool = True, per_param: bool = True,
                 max_chain_depth: int = 8,
                 cache_budget_bytes: int = 256 * 2**20,
                 zero_frac_prefilter: float = 0.0,
                 backend: Optional[str] = None,
                 pack_threshold: int = DEFAULT_PACK_THRESHOLD,
                 pipelined: bool = True,
                 fold_enabled: bool = True,
                 fold_budget_bytes: int = 256 * 2**20,
                 lzma_preset: Optional[int] = None,
                 io_workers: Optional[int] = None,
                 chunk_threshold: Optional[int] = None,
                 chunk_window_bytes: int = chunklib.DEFAULT_WINDOW_BYTES,
                 chunk_min: int = chunklib.DEFAULT_MIN_CHUNK,
                 chunk_avg: int = chunklib.DEFAULT_AVG_CHUNK,
                 chunk_max: int = chunklib.DEFAULT_MAX_CHUNK,
                 chunk_mode: str = "cdc",
                 chunk_shards: int = 0) -> None:
        self.cas = CAS(root, pack_threshold=pack_threshold)
        # chunk layer (DESIGN.md §12): params >= chunk_threshold bytes are
        # stored as content-defined chunks instead of one monolithic object;
        # 0 disables chunking. chunk_window_bytes bounds commit/checkout
        # in-flight memory for chunked tensors; chunk_shards > 1 aligns the
        # chunk grid to that many axis-0 shard boundaries.
        self.chunk_threshold = (chunklib.DEFAULT_CHUNK_THRESHOLD
                                if chunk_threshold is None
                                else max(0, int(chunk_threshold)))
        self.chunk_window_bytes = int(chunk_window_bytes)
        self.chunk_min = int(chunk_min)
        self.chunk_avg = int(chunk_avg)
        self.chunk_max = int(chunk_max)
        self.chunk_mode = chunk_mode
        self.chunk_shards = int(chunk_shards)
        self.codec = codec
        self.eps = eps
        self.t_thr = t_thr
        self.delta_enabled = delta_enabled
        self.per_param = per_param
        self.max_chain_depth = max_chain_depth
        self.zero_frac_prefilter = zero_frac_prefilter
        self.backend = backend
        self.pipelined = pipelined
        # The serial baseline defines truth hop-by-hop; folding defines it
        # segment-wise. One store must pick ONE definition (§10.2).
        self.fold_enabled = fold_enabled and pipelined
        # LZMA preset default: the pipelined engine ships with preset 0 —
        # on quantized-delta streams it compresses as well as preset 1 at
        # ~2x the encode/decode speed (see bench_compression's preset
        # sweep); the serial baseline keeps the historical preset-1 codec.
        if lzma_preset is None and pipelined and codec == "lzma":
            lzma_preset = 0
        self.lzma_preset = lzma_preset
        self.io_workers = io_workers or max(2, min(4, os.cpu_count() or 2))
        self._codec_obj = get_codec(codec, preset=lzma_preset)
        self._pool: Optional[ThreadPoolExecutor] = None
        self._manifests: Dict[str, Dict[str, Any]] = {}
        self.cache = TensorCache(cache_budget_bytes)
        self.fold_cache = FoldCache(fold_budget_bytes)
        self.logical_bytes = 0
        self.last_result: Optional[CompressResult] = None
        # per-store materialization accounting (reset with reset_io_stats).
        # A registry-backed dict view: same `io_stats[k] += n` call sites,
        # but the counters are scrapeable as mgit_store_* and multi-key
        # snapshot/reset are atomic (DESIGN.md §14).
        self.io_stats = REGISTRY.group(
            "mgit_store",
            keys=("tensors_materialized", "bytes_materialized",
                  "chain_hops", "plans_resolved", "dequant_calls",
                  "hops_folded", "fold_hits", "chunks_written",
                  "chunk_bytes_written", "chunks_deduped",
                  "chunk_delta_blobs", "chunk_passthrough", "chunks_read",
                  "step_commits", "step_leaves_copied", "step_leaves_delta",
                  "step_leaves_xdelta", "step_leaves_full"),
            help="ArtifactStore I/O accounting")
        self._lock = threading.RLock()   # manifests dict + counters
        self._stats_path = (os.path.join(root, "store_stats.json")
                            if root else None)
        if self._stats_path and os.path.exists(self._stats_path):
            with open(self._stats_path) as f:
                payload = json.load(f)
            self.logical_bytes = payload.get("logical_bytes", 0)
            self._adopt_truth(payload.get("truth"))

    def _adopt_truth(self, recorded: Optional[str]) -> None:
        """Enforce one reconstruction-truth definition per repository.

        Fold and hop-by-hop reconstruction produce (equally valid but)
        different bits for depth>=2 chains, so manifests written under one
        definition must never be materialized under the other (§10.2). The
        definition is persisted in store_stats.json at first commit:

        * recorded == configured: fine;
        * recorded missing but commits exist (store_stats.json predates the
          marker — a PR-1..3 repo): its chains are hop-by-hop truth; adopt
          that rather than silently diverge from the recorded hashes;
        * recorded conflicts with an explicit config: fail fast."""
        configured = "fold" if self.fold_enabled else "hopwise"
        if recorded is None:
            if self.fold_enabled:
                self.fold_enabled = False
                self.pipelined = False
        elif recorded != configured:
            raise ValueError(
                f"store at {self.cas.root!r} was committed with "
                f"{recorded!r} reconstruction truth but this instance is "
                f"configured for {configured!r} — reopen with "
                f"{'pipelined=True (default)' if recorded == 'fold' else 'pipelined=False'} "
                f"(DESIGN.md §10.2: one truth definition per repository)")

    def _executor(self) -> ThreadPoolExecutor:
        """Shared worker pool for commit encode + batched checkout decode.

        Lazily created and kept for the store's lifetime — spawning a pool
        per operation costs more than a short commit's entire codec work.
        Workers never submit back into the pool (materialize_param is
        submission-free), so shared use cannot deadlock."""
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.io_workers,
                    thread_name_prefix="artifact-store-io")
            return self._pool

    # -- commit -----------------------------------------------------------------
    def commit_artifact(self, name: str, artifact: ModelArtifact,
                        parent_ref: Optional[str] = None,
                        tests: Sequence = ()) -> str:
        with span("store.commit", cat="store", model=name):
            return self._commit_artifact(name, artifact, parent_ref, tests)

    def _commit_artifact(self, name: str, artifact: ModelArtifact,
                         parent_ref: Optional[str],
                         tests: Sequence) -> str:
        with self._lock:
            self.logical_bytes += artifact.nbytes()
        self._persist_stats()
        entries: Dict[str, Any] = {}
        depth = 0

        # Chunk layer (DESIGN.md §12): params >= chunk_threshold go through
        # the streaming chunk engine and are carved OUT of the whole-tensor
        # delta stage — they must never be materialized as one array here.
        param_order = list(artifact.params)
        chunk_sources = self._chunk_candidates(artifact)
        parent_manifest = (self.get_manifest(parent_ref)
                           if parent_ref is not None else None)
        if chunk_sources:
            artifact = ModelArtifact(
                graph=artifact.graph,
                params={k: artifact.params[k] for k in param_order
                        if k not in chunk_sources},
                model_type=artifact.model_type,
                metadata=artifact.metadata)

        deltas = {}
        precomputed_hashes: Dict[str, str] = {}
        commit_result: Optional[CompressResult] = None
        if self.delta_enabled and parent_ref is not None and artifact.params:
            if parent_manifest["depth"] < self.max_chain_depth:
                if self.pipelined:
                    result = self._delta_compress_pipelined(
                        artifact, parent_ref, tests)
                else:
                    # serial baseline: lazy parent view, one param at a time
                    parent = self.load_artifact(parent_ref)
                    result = delta_compression(
                        artifact, parent, t_thr=self.t_thr, eps=self.eps,
                        codec=self.codec, tests=tests,
                        per_param=self.per_param,
                        zero_frac_prefilter=self.zero_frac_prefilter,
                        backend=self.backend)
                self.last_result = commit_result = result
                if result.accepted:
                    deltas = result.deltas
                    precomputed_hashes = result.param_hashes
                    depth = parent_manifest["depth"] + 1
                    # persist the *reconstructed* model as this version's truth
                    artifact = result.reconstructed

        with self.cas.batch():  # one append handle per pack, one fsync
            for key, source in chunk_sources.items():
                entries[key] = self._commit_chunked(key, source, parent_ref,
                                                    parent_manifest)
            if depth == 0 and any(e.get("parent_ref")
                                  for e in entries.values()):
                depth = parent_manifest["depth"] + 1
            for key in artifact.params:
                value = np.asarray(artifact.params[key])
                # content identity for every entry (worker-precomputed for
                # pipelined delta params)
                thash = precomputed_hashes.get(key) or tensor_hash(value)
                if key in deltas:
                    d = deltas[key]
                    blob_hash = self.cas.put_bytes(d.blob)
                    entries[key] = {"kind": "delta", "blob": blob_hash,
                                    "parent_ref": parent_ref,
                                    "parent_key": d.parent_key,
                                    "codec": d.codec,
                                    "eps": d.eps, "shape": list(d.shape),
                                    "dtype": d.dtype, "qdtype": d.qdtype,
                                    "hash": thash}
                else:
                    self.cas.put_tensor(value, key=thash)  # content-hash dedup
                    entries[key] = {"kind": "full", "tensor": thash,
                                    "shape": list(value.shape),
                                    "dtype": str(value.dtype), "hash": thash}

            # delta entries always carry parent_ref; chunked entries only
            # when at least one chunk is stored relative to the parent
            delta_parents = sorted({e["parent_ref"] for e in entries.values()
                                    if e.get("parent_ref")})
            with self.cas.batched_refcounts():
                for pref in delta_parents:
                    self.cas.incref(pref)  # parent must outlive child
            manifest = {
                "name": name,
                "model_type": artifact.model_type,
                "metadata": artifact.metadata,
                "graph": artifact.graph.to_json(),
                "params": entries,
                "depth": depth,
                "delta_parents": delta_parents,
            }
            payload = json.dumps(manifest, sort_keys=True, default=str).encode()
            ref = self.cas.put_bytes(payload, key="m_" + bytes_hash(payload))
        with self._lock:
            self._manifests[ref] = manifest
        if deltas and commit_result is not None:
            # seed the caches with this commit's reconstructed truth: the
            # NEXT commit onto this chain (or a checkout of it) resolves the
            # parent entirely from cache — zero decodes, zero dequants
            for ckey, st in commit_result.fold_states.items():
                self.fold_cache.put((ref, ckey), st)
            for ckey in deltas:
                value = artifact.params.get(ckey)
                if value is not None:
                    self.cache.put((ref, ckey), np.asarray(value))
        with span("commit.pack_fsync", cat="store"):
            self.cas.flush()  # commit point: index + refcounts durable
        return ref

    def _delta_compress_pipelined(self, child: ModelArtifact, parent_ref: str,
                                  tests: Sequence = ()) -> CompressResult:
        """Throughput-first Algorithm 1 (DESIGN.md §10.1).

        Stages, overlapped across a thread pool (GIL-releasing LZMA/XLA):

        1. the parent's reconstruction state resolves ONCE per chain —
           ``materialize_artifact`` warms tensor + fold caches in a batch;
        2. per matched pair, a worker runs the fused device pass
           (``ops.snapshot_fused``, fingerprint elided: commit never reads
           it), encodes the quantized delta, and derives the child's stored
           truth with one fold-extended dequant;
        3. acceptance and test-gating mirror :func:`delta_compression`
           exactly (per-param or whole-model, ``t_thr`` rejection).
        """
        from repro.kernels import ops

        cod = self._codec_obj
        parent_lazy = self.load_artifact(parent_ref)
        pairs = [(pk, ck) for pk, ck in lcs_param_matching(parent_lazy, child)]
        pvals = self.materialize_artifact(
            parent_ref, keys=[pk for pk, _ in pairs]).params

        host = self.backend in (None, "ref")

        def process(pair):
            pkey, ckey = pair
            p1 = np.asarray(pvals[pkey])
            p2 = np.asarray(child.params[ckey])
            if p1.size == 0:
                return None
            with span("commit.quantize", cat="store", key=ckey):
                if host:  # numpy twin, bit-identical, no dispatch overhead
                    q, nz, _narrow = host_snapshot(p1, p2, self.eps)
                else:
                    q, nz, _fp, _narrow = ops.snapshot_fused(
                        p1, p2, eps=self.eps, backend=self.backend,
                        with_fingerprint=False)
                    q = np.asarray(q)
            if nz / q.size < self.zero_frac_prefilter:
                return None  # on-device pre-filter: won't compress
            with span("commit.encode", cat="store", key=ckey):
                blob = cod.encode(q)
            if self.per_param and len(blob) >= p2.nbytes:
                return None  # no saving for this tensor
            q32 = q if q.dtype == np.int32 else q.astype(np.int32)
            recon, state = self._commit_truth(parent_ref, pkey, p1, q32,
                                              str(p2.dtype))
            recon = recon.reshape(p2.shape)
            delta = ParamDelta(
                child_key=ckey, parent_key=pkey, blob=blob, codec=self.codec,
                eps=self.eps, shape=tuple(p2.shape), dtype=str(p2.dtype),
                raw_bytes=int(p2.nbytes), qdtype=str(q.dtype))
            with span("commit.hash", cat="store", key=ckey):
                thash = tensor_hash(recon)
            return ckey, delta, recon, thash, state

        # the delta span is the propagation anchor: worker-side
        # quantize/encode/hash spans parent here even though the pool
        # threads never saw this contextvar scope
        with span("commit.delta", cat="store", params=len(pairs)):
            if len(pairs) > 1 and self.io_workers > 1:
                produced = list(self._executor().map(propagate(process),
                                                     pairs))
            else:
                produced = [process(p) for p in pairs]

        candidates: Dict[str, ParamDelta] = {}
        recon_params: Dict[str, np.ndarray] = {}
        hashes: Dict[str, str] = {}
        states: Dict[str, FoldState] = {}
        for item in produced:
            if item is None:
                continue
            ckey, delta, recon, thash, state = item
            candidates[ckey] = delta
            recon_params[ckey] = recon
            hashes[ckey] = thash
            if state is not None:
                states[ckey] = state

        total_raw = child.nbytes()
        delta_raw = sum(d.raw_bytes for d in candidates.values())
        delta_compressed = sum(len(d.blob) for d in candidates.values())
        storage_saving = delta_raw / max(delta_compressed, 1)
        if not candidates or (not self.per_param and storage_saving < 1.0):
            return CompressResult(False, {}, child, {}, total_raw, total_raw)

        m2_prime = child.replace_params(recon_params)
        test_deltas: Dict[str, float] = {}
        for t in tests:
            before = float(t.fn(child))
            after = float(t.fn(m2_prime))
            test_deltas[t.name] = after - before
            if abs(after - before) > self.t_thr:
                return CompressResult(False, {}, child, test_deltas,
                                      total_raw, total_raw)
        compressed_total = (total_raw - delta_raw) + delta_compressed
        return CompressResult(True, candidates, m2_prime, test_deltas,
                              total_raw, compressed_total,
                              param_hashes=hashes, fold_states=states)

    def _commit_truth(self, parent_ref: str, parent_key: str,
                      parent_value: np.ndarray, q32: np.ndarray,
                      dtype: str, eps: Optional[float] = None
                      ) -> Tuple[np.ndarray, Optional[FoldState]]:
        """The child's canonical stored value for a new delta hop, plus its
        resulting open-segment fold state.

        Fold-extends the parent's open segment when eps+dtype allow —
        EXACTLY what checkout computes for the same chain (§10.2) — else
        opens a new segment from the parent's value. Device-backend stores
        dequant through the same jit'd kernel checkout uses, so stored
        hashes always match what a later checkout reproduces. ``eps``
        defaults to the store's configured eps; the step-delta engine
        passes its per-leaf adaptive eps (§15) so segment-extension
        decisions here stay structurally identical to checkout's
        ``_is_segment_boundary``."""
        if eps is None:
            eps = self.eps
        if self.backend in (None, "ref"):
            dequant = host_dequant
        else:
            from repro.kernels import ops

            def dequant(v, q, e_, out_dtype="float32"):
                return np.asarray(ops.dequant_apply(
                    np.asarray(v), q, eps=e_, backend=self.backend,
                    out_dtype=out_dtype))

        if dtype == "float32" and self.fold_enabled:
            fs = self.fold_cache.get((parent_ref, parent_key))
            if fs is None:
                e = self._entry(parent_ref, parent_key)
                if e["kind"] == "delta":  # state evicted: recompute it
                    _, fs = self._materialize_with_state(parent_ref,
                                                         parent_key)
            if fs is not None and fs.eps == eps:
                state = FoldState(
                    seg_base=fs.seg_base,
                    q_open=np.add(fs.q_open, q32.reshape(fs.q_open.shape),
                                  dtype=np.int32),
                    eps=eps)
            else:
                state = FoldState(seg_base=np.asarray(parent_value),
                                  q_open=q32, eps=eps)
            return dequant(state.seg_base, state.q_open, eps), state
        return dequant(parent_value, q32, eps, out_dtype=dtype), None

    # -- step-delta commit engine (DESIGN.md §15) --------------------------------
    def _full_step_entry(self, key: str, value: np.ndarray,
                         parent_ref: Optional[str],
                         parent_manifest: Optional[Dict[str, Any]],
                         lossless: bool = True) -> Dict[str, Any]:
        """Depth-0 entry for one step leaf: chunked above the threshold
        (grid inheritance still dedups unchanged chunks; per-chunk
        quantized deltas only in the lossy tier), else a raw full tensor."""
        if self.chunk_threshold and value.nbytes >= self.chunk_threshold:
            e = self._commit_chunked(key, chunklib.as_source(value),
                                     parent_ref, parent_manifest,
                                     lossless=lossless)
            if e.get("parent_ref"):
                e["d"] = int(parent_manifest.get("depth", 0)) + 1
            return e
        thash = tensor_hash(value)
        self.cas.put_tensor(value, key=thash)
        return {"kind": "full", "tensor": thash, "shape": list(value.shape),
                "dtype": str(value.dtype), "hash": thash}

    @staticmethod
    def _copy_step_entry(pe: Dict[str, Any], parent_depth: int,
                         copy_objs: List[str]) -> Dict[str, Any]:
        """Verbatim re-reference of the parent's entry for an unchanged
        leaf. The new manifest holds its OWN reference on every object the
        entry owns (mirroring commit-time accounting), so ``copy_objs``
        collects them for one batched incref."""
        e = dict(pe)
        kind = e["kind"]
        if kind == "chunked":
            for item in e["chunks"]:
                k = item.get("c") or item.get("b")
                if k:
                    copy_objs.append(k)
        else:
            copy_objs.append(e["tensor"] if kind == "full" else e["blob"])
        if kind != "full" and "d" not in e:
            e["d"] = (parent_depth if (kind in ("delta", "xdelta")
                                       or e.get("parent_ref")) else 0)
        return e

    @staticmethod
    def _entry_nbytes(pe: Dict[str, Any]) -> int:
        if pe["kind"] == "chunked":
            return int(pe["nbytes"])
        shape = pe.get("shape", ())
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        return n * np.dtype(pe.get("dtype", "float32")).itemsize

    def commit_step(self, name: str,
                    flat: Dict[str, Optional[np.ndarray]],
                    parent_ref: Optional[str] = None, *,
                    skip: frozenset = frozenset(),
                    tier: str = "exact",
                    model_type: str = "model",
                    metadata: Optional[Dict[str, Any]] = None,
                    graph_json: Optional[str] = None,
                    parent_hint: Optional[Dict[str, np.ndarray]] = None,
                    step_codec: str = "zlib",
                    flush: bool = True) -> str:
        """Training-speed commit of one step's state (DESIGN.md §15).

        ``flat`` maps leaf key -> host array; keys in ``skip`` (fingerprint-
        unchanged since ``parent_ref``) may carry ``None`` and re-reference
        the parent's entry verbatim — no host transfer, no encode, no new
        object. Changed leaves store as:

        * ``tier="exact"``: an ``xdelta`` entry — lossless bitpattern
          subtraction vs the parent's committed truth, byte-plane + zlib-1
          encoded. The child's stored truth IS the live value, so resume is
          bit-identical.
        * ``tier="lossy"``: an int8 ``delta`` entry with per-leaf adaptive
          eps sized so the quantization grid matches the error-feedback
          estimator's (``amax/127``, ``repro.dist.compression``). Deltas
          are taken against the parent's *committed* truth, so quantization
          error never compounds along the chain (implicit error feedback:
          each hop's error is bounded by half its own grid).

        ``parent_hint`` (exact tier only) supplies the parent's committed
        values without a cache probe — the caller's previous live flat is
        exactly that, because exact-tier truth is the live value. Per-leaf
        chain depth (entry field ``d``) is gated by ``max_chain_depth``;
        overlong chains reset to full/chunked entries. A leaf whose bits
        did not change (but was transferred anyway) also degenerates to a
        verbatim copy."""
        if tier not in ("exact", "lossy"):
            raise ValueError(f"unknown commit tier {tier!r}")
        parent_manifest = (self.get_manifest(parent_ref)
                          if parent_ref is not None else None)
        if parent_manifest is None:
            skip = frozenset()
        parent_depth = (int(parent_manifest.get("depth", 0))
                        if parent_manifest else 0)
        if graph_json is None:
            if (parent_manifest is not None
                    and set(flat) == set(parent_manifest["params"])):
                graph_json = parent_manifest["graph"]
            else:
                raise ValueError(
                    "commit_step needs graph_json when the leaf set differs "
                    "from the parent manifest's")
        cod_q = get_codec(step_codec, 1)  # level 1: hot-path default
        xd = get_codec("xd")
        entries: Dict[str, Any] = {}
        truths: Dict[str, np.ndarray] = {}
        states: Dict[str, FoldState] = {}
        copy_objs: List[str] = []
        counts = {"copied": 0, "delta": 0, "xdelta": 0, "full": 0}
        logical = 0

        with span("ckpt.delta", cat="ckpt", model=name, params=len(flat),
                  skipped=len(skip)), self.cas.batch():
            for key, value in flat.items():
                pe = (parent_manifest["params"].get(key)
                      if parent_manifest else None)
                if key in skip and pe is not None:
                    entries[key] = self._copy_step_entry(pe, parent_depth,
                                                         copy_objs)
                    counts["copied"] += 1
                    logical += self._entry_nbytes(pe)
                    continue
                if value is None:
                    raise ValueError(f"leaf {key!r} not in skip but has no "
                                     f"value")
                value = np.ascontiguousarray(value)
                logical += int(value.nbytes)
                pd = None
                if (self.delta_enabled and pe is not None
                        and pe["kind"] != "chunked"
                        and tuple(pe.get("shape", ())) == value.shape
                        and pe.get("dtype") == str(value.dtype)):
                    pd = int(pe.get("d", parent_depth))
                    if pd + 1 > self.max_chain_depth:
                        pd = None  # per-leaf chain reset
                if pd is None:
                    entries[key] = self._full_step_entry(
                        key, value, parent_ref, parent_manifest,
                        lossless=tier != "lossy")
                    counts["full"] += 1
                    continue
                pv = None
                if parent_hint is not None:
                    pv = parent_hint.get(key)
                if pv is None:
                    pv = self.cache.get((parent_ref, key))
                if pv is None:
                    pv = self.materialize_param(parent_ref, key)
                pv = np.asarray(pv)
                if pv.shape != value.shape or pv.dtype != value.dtype:
                    entries[key] = self._full_step_entry(
                        key, value, parent_ref, parent_manifest,
                        lossless=tier != "lossy")
                    counts["full"] += 1
                    continue
                if tier == "lossy" and value.dtype == np.float32:
                    diff = np.subtract(pv, value, dtype=np.float32)
                    amax = (float(np.max(np.abs(diff)))
                            if diff.size else 0.0)
                    if amax == 0.0:  # bit-identical to parent truth
                        entries[key] = self._copy_step_entry(
                            pe, parent_depth, copy_objs)
                        counts["copied"] += 1
                        continue
                    # grid matched to the EF estimator: quant_scale(eps)
                    # == amax/_Q_LEVELS, so q always narrows to int8
                    from repro.dist.compression import ef_eps
                    eps = ef_eps(amax)
                    q, nz, _narrow = host_snapshot(pv, value, eps)
                    q32 = (q if q.dtype == np.int32
                           else q.astype(np.int32))
                    truth, state = self._commit_truth(
                        parent_ref, key, pv, q32, "float32", eps=eps)
                    truth = np.asarray(truth).reshape(value.shape)
                    ccod = pick_codec(int(nz), q.size, cod_q)
                    blob = ccod.encode(q)
                    if len(blob) >= value.nbytes:
                        entries[key] = self._full_step_entry(
                            key, value, parent_ref, parent_manifest)
                        counts["full"] += 1
                        continue
                    entries[key] = {
                        "kind": "delta", "blob": self.cas.put_bytes(blob),
                        "parent_ref": parent_ref, "parent_key": key,
                        "codec": ccod.name, "eps": eps,
                        "shape": list(value.shape), "dtype": "float32",
                        "qdtype": str(q.dtype),
                        "hash": tensor_hash(truth), "d": pd + 1}
                    truths[key] = truth
                    if state is not None:
                        states[key] = state
                    counts["delta"] += 1
                else:
                    d = bitpattern_delta(value, pv)
                    if not d.any():  # same bits: re-reference, store nothing
                        entries[key] = self._copy_step_entry(
                            pe, parent_depth, copy_objs)
                        counts["copied"] += 1
                        continue
                    blob = xd.encode(d)
                    if len(blob) >= value.nbytes:
                        entries[key] = self._full_step_entry(
                            key, value, parent_ref, parent_manifest)
                        counts["full"] += 1
                        continue
                    entries[key] = {
                        "kind": "xdelta", "blob": self.cas.put_bytes(blob),
                        "parent_ref": parent_ref, "parent_key": key,
                        "codec": "xd", "shape": list(value.shape),
                        "dtype": str(value.dtype), "qdtype": str(d.dtype),
                        "hash": tensor_hash(value), "d": pd + 1}
                    truths[key] = value
                    counts["xdelta"] += 1

            delta_parents = sorted({e["parent_ref"]
                                    for e in entries.values()
                                    if e.get("parent_ref")})
            with self.cas.batched_refcounts():
                for obj in copy_objs:
                    self.cas.incref(obj)
                for pref in delta_parents:
                    self.cas.incref(pref)
            depth = max((int(e.get("d", 0)) for e in entries.values()),
                        default=0)
            manifest = {
                "name": name,
                "model_type": model_type,
                "metadata": metadata or {},
                "graph": graph_json,
                "params": entries,
                "depth": depth,
                "delta_parents": delta_parents,
            }
            payload = json.dumps(manifest, sort_keys=True,
                                 default=str).encode()
            ref = self.cas.put_bytes(payload, key="m_" + bytes_hash(payload))

        with self._lock:
            self._manifests[ref] = manifest
            self.logical_bytes += logical
            self.io_stats["step_commits"] += 1
            self.io_stats["step_leaves_copied"] += counts["copied"]
            self.io_stats["step_leaves_delta"] += counts["delta"]
            self.io_stats["step_leaves_xdelta"] += counts["xdelta"]
            self.io_stats["step_leaves_full"] += counts["full"]
        self._persist_stats()
        # seed this commit's truth so the NEXT step's parent lookups (and
        # any checkout of this ref) are pure cache hits
        for k, v in truths.items():
            self.cache.put((ref, k), np.asarray(v))
        for k, st in states.items():
            self.fold_cache.put((ref, k), st)
        if parent_ref is not None:
            for k in skip:
                if k in entries:
                    v = self.cache.get((parent_ref, k))
                    if v is not None:
                        self.cache.put((ref, k), v)
        if flush:
            with span("commit.pack_fsync", cat="store"):
                self.cas.flush()  # commit point: index + refcounts durable
        return ref

    # -- chunk engine (DESIGN.md §12) --------------------------------------------
    def _chunk_candidates(self, artifact: ModelArtifact
                          ) -> "Dict[str, Any]":
        """Params of ``artifact`` routed through the chunk layer, as sources.

        Selection is metadata-only (spec/nbytes, no materialization); the
        values are chunk sources — wrappers exposing ``read(offset, size)``
        over raw contiguous bytes (``repro.store.chunks``)."""
        if not self.chunk_threshold:
            return {}
        params = artifact.params
        out: Dict[str, Any] = {}
        for key in params:
            value = params.get(key) if hasattr(params, "get") else None
            if isinstance(params, LazyParams):
                shape, dtype = params.spec_of(key)
                nb = (int(np.prod(shape, dtype=np.int64)
                          * np.dtype(dtype).itemsize) if shape
                      else np.dtype(dtype).itemsize)
                if nb < self.chunk_threshold:
                    continue
                value = params[key]  # materializes only >threshold params
            else:
                value = params[key]
                nb = getattr(value, "nbytes", None)
                if not isinstance(nb, (int, np.integer)):
                    nb = int(np.asarray(value).nbytes)
                if nb < self.chunk_threshold:
                    continue
            out[key] = chunklib.as_source(value)
        return out

    def _shard_segments(self, key: str, shape, itemsize: int):
        """Hard chunk-grid boundaries from the mesh sharding spec, or None."""
        if self.chunk_shards <= 1:
            return None
        from repro.dist.sharding import shard_cuts
        return shard_cuts(key, shape, itemsize, self.chunk_shards)

    def _chunk_parent_entry(self, key: str, parent_ref: Optional[str],
                            parent_manifest: Optional[Dict[str, Any]],
                            source) -> Optional[Dict[str, Any]]:
        """The parent's chunked entry for ``key`` when its grid can be
        inherited 1:1 (same dtype and byte length, chain depth allows)."""
        if (parent_ref is None or parent_manifest is None
                or not self.delta_enabled
                or parent_manifest["depth"] >= self.max_chain_depth):
            return None
        pe = parent_manifest["params"].get(key)
        if (pe is None or pe.get("kind") != "chunked"
                or pe["dtype"] != str(np.dtype(source.dtype))
                or int(pe["nbytes"]) != int(source.nbytes)):
            return None
        return pe

    def _commit_chunked(self, key: str, source, parent_ref: Optional[str],
                        parent_manifest: Optional[Dict[str, Any]],
                        lossless: bool = False) -> Dict[str, Any]:
        """Stream one large param into chunk objects; return its entry.

        The tensor is processed through a bounded window: chunks are read,
        (optionally) delta-encoded against the parent's corresponding chunk
        and written in batches sized so in-flight bytes stay within
        ``chunk_window_bytes`` — the full tensor never exists in memory.
        The entry's ``hash`` is the stored-truth tensor hash, accumulated
        incrementally in chunk order (bit-identical to ``tensor_hash`` of
        the materialized checkout).

        Grid inheritance: when the parent has a chunked entry of identical
        dtype/length, its grid is reused so chunks align 1:1 and each chunk
        stores as (a) a reference to the parent's identical raw chunk, (b) a
        quantized per-chunk delta blob, (c) a pass-through marker (``p``:
        bit-identical to the parent chunk's truth), or (d) a fresh raw
        ``c_`` object. Without an inheritable grid, content-defined (or
        fixed) boundaries are computed and every chunk stores raw.

        ``lossless`` (the exact checkpoint tier, DESIGN.md §15) disables
        the quantized per-chunk delta path: the inherited grid still
        dedups unchanged chunks by content key, but changed chunks store
        raw bytes so the entry's truth IS the live value bit-for-bit."""
        dtype = np.dtype(source.dtype)
        shape = tuple(int(d) for d in source.shape)
        nbytes = int(source.nbytes)
        pe = self._chunk_parent_entry(key, parent_ref, parent_manifest,
                                      source)
        parent_chain = None
        if pe is not None:
            cuts = np.cumsum([int(it["n"]) for it in pe["chunks"]]).tolist()
            if not lossless:
                parent_chain = self._chunk_chain(parent_ref, key)
        else:
            cuts = chunklib.cut_points(
                source.read, nbytes, dtype.itemsize,
                min_size=self.chunk_min, avg_size=self.chunk_avg,
                max_size=self.chunk_max, mode=self.chunk_mode,
                segments=self._shard_segments(key, shape, dtype.itemsize))
        spans = chunklib.spans_of(cuts)
        delta_f32 = parent_chain is not None and dtype == np.float32
        cod = self._codec_obj
        hasher = TensorHasher(shape, dtype)
        items: List[Optional[Dict[str, Any]]] = [None] * len(spans)

        def process(idx: int):
            """Worker: returns (tag, meta, payload, truth_bytes)."""
            off, n = spans[idx]
            data = bytes(source.read(off, n))
            ckey = "c_" + bytes_hash(data)
            if delta_f32:
                pitem = pe["chunks"][idx]
                if pitem.get("c") == ckey:
                    return ("c", ckey, data, data)  # identical raw chunk
                pbytes = self._chunk_value(parent_chain, idx)
                if data == pbytes:
                    # identical truth, but the parent chunk has no raw
                    # object of its own — record a pass-through
                    return ("p", None, None, data)
                child = np.frombuffer(data, dtype=np.float32)
                parent = np.frombuffer(pbytes, dtype=np.float32)
                q, nz, _narrow = host_snapshot(parent, child, self.eps)
                # density is free from the snapshot kernel: ultra-sparse
                # chunks (edit stragglers) switch to the sparse codec
                ccod = pick_codec(int(nz), q.size, cod)
                blob = ccod.encode(q)
                if len(blob) < n:
                    truth = host_dequant(parent, q, self.eps).tobytes()
                    if truth == pbytes:
                        return ("p", None, None, truth)
                    return ("b", (str(q.dtype), ccod.name), blob, truth)
            return ("c", ckey, data, data)

        # Bounded fan-out: each in-flight chunk holds ~4x its bytes (child,
        # parent, q, blob), so batches of window/(4*max_chunk) keep peak
        # in-flight memory within the configured window.
        max_len = max(n for _, n in spans)
        batch = max(1, self.chunk_window_bytes // max(1, 4 * max_len))
        use_pool = (self.io_workers > 1 and batch > 1 and len(spans) > 1)
        stream_span = span("commit.chunk_stream", cat="store", key=key,
                           chunks=len(spans), batch=batch)
        with stream_span:
            for lo in range(0, len(spans), batch):
                idxs = list(range(lo, min(len(spans), lo + batch)))
                if use_pool and len(idxs) > 1:
                    results = list(self._executor().map(propagate(process),
                                                        idxs))
                else:
                    results = [process(i) for i in idxs]
                for idx, (tag, meta, payload, truth) in zip(idxs, results):
                    n = spans[idx][1]
                    hasher.update(truth)
                    if tag == "c":
                        had = self.cas.has(meta)
                        self.cas.put_bytes(payload, key=meta)
                        items[idx] = {"c": meta, "n": n}
                        with self._lock:
                            self.io_stats["chunks_written"] += 1
                            if had:
                                self.io_stats["chunks_deduped"] += 1
                            else:
                                self.io_stats["chunk_bytes_written"] += n
                    elif tag == "b":
                        bkey = self.cas.put_bytes(payload)
                        qdtype, codname = meta
                        items[idx] = {"b": bkey, "n": n, "q": qdtype}
                        if codname != self.codec:
                            items[idx]["k"] = codname
                        with self._lock:
                            self.io_stats["chunk_delta_blobs"] += 1
                            self.io_stats["chunk_bytes_written"] += len(payload)
                    else:
                        items[idx] = {"p": 1, "n": n}
                        with self._lock:
                            self.io_stats["chunk_passthrough"] += 1

        entry: Dict[str, Any] = {"kind": "chunked",
                                 "hash": hasher.hexdigest(),
                                 "shape": list(shape), "dtype": str(dtype),
                                 "nbytes": nbytes, "chunks": items}
        if pe is not None and any("b" in it or "p" in it for it in items):
            # at least one chunk is stored relative to the parent: record
            # the chain link (and the decode parameters shared by all blobs)
            entry.update({"parent_ref": parent_ref, "parent_key": key,
                          "eps": self.eps, "codec": self.codec})
        return entry

    def _chunk_chain(self, ref: str, key: str) -> List[Dict[str, Any]]:
        """Chunked entries child-first along parent links (cycle-checked)."""
        chain: List[Dict[str, Any]] = []
        cur_ref, cur_key = ref, key
        seen = set()
        while True:
            if (cur_ref, cur_key) in seen:
                raise RuntimeError(
                    f"chunk chain cycle at {cur_ref!r}:{cur_key!r}")
            seen.add((cur_ref, cur_key))
            e = self._entry(cur_ref, cur_key)
            if e.get("kind") != "chunked":
                raise RuntimeError(
                    f"chunk chain of {ref!r}:{key!r} reaches non-chunked "
                    f"entry at {cur_ref!r}:{cur_key!r} (corrupt manifest)")
            chain.append(e)
            if not e.get("parent_ref"):
                return chain
            cur_ref, cur_key = e["parent_ref"], e["parent_key"]

    def _chunk_value(self, chain: List[Dict[str, Any]], idx: int) -> bytes:
        """Raw truth bytes of chunk ``idx`` of ``chain[0]``'s tensor.

        Walks down the chain until a raw ``c`` item, then applies the
        recorded per-chunk dequant hops back up (``p`` items copy through).
        Chunk reads bypass the mmap pool: checkout of a huge tensor must
        not charge mapped pages to the process RSS high-water mark."""
        level = 0
        hops: List[Tuple[Dict[str, Any], Dict[str, Any]]] = []
        while True:
            e = chain[level]
            item = e["chunks"][idx]
            if "c" in item:
                base = self.cas.get_bytes_nomap(item["c"])
                break
            if "p" in item:
                level += 1
                continue
            hops.append((e, item))
            level += 1
        with self._lock:
            self.io_stats["chunks_read"] += 1
        if not hops:
            return base
        value = np.frombuffer(base, dtype=np.float32)
        for e, item in reversed(hops):
            blob = self.cas.get_bytes_nomap(item["b"])
            n = int(item["n"]) // 4
            # per-item ``k`` overrides the entry codec (density-adaptive
            # sparse pick at commit time); absent means the entry default
            q = get_codec(item.get("k", e["codec"])).decode(
                blob, n, dtype=item.get("q", "int32"))
            value = host_dequant(value, q, float(e["eps"]))
            with self._lock:
                self.io_stats["dequant_calls"] += 1
                self.io_stats["chain_hops"] += 1
        return value.tobytes()

    def _materialize_chunked(self, ref: str, key: str) -> np.ndarray:
        """Decode a chunked param into one preallocated destination array."""
        e = self._entry(ref, key)
        chain = self._chunk_chain(ref, key)
        spans = chunklib.spans_of(
            np.cumsum([int(it["n"]) for it in e["chunks"]]).tolist())
        out = np.empty(tuple(e["shape"]), dtype=np.dtype(e["dtype"]))
        flat = out.reshape(-1).view(np.uint8)

        def fill(idx: int) -> None:
            off, n = spans[idx]
            flat[off:off + n] = np.frombuffer(
                self._chunk_value(chain, idx), dtype=np.uint8)

        # Fan out only from a non-pool thread (pool workers must never
        # submit back into the shared pool — materialize_artifact already
        # parallelizes across params); writes hit disjoint slices.
        on_pool = threading.current_thread().name.startswith(
            "artifact-store-io")
        if not on_pool and self.io_workers > 1 and len(spans) > 2:
            list(self._executor().map(fill, range(len(spans))))
        else:
            for i in range(len(spans)):
                fill(i)
        out.flags.writeable = False
        self._count_materialization(out)
        return out

    def stream_param(self, ref: str, key: str):
        """Yield ``(offset, bytes)`` covering one param's raw bytes in order.

        For chunked entries this is the bounded-memory checkout path — one
        chunk's truth is in flight at a time; non-chunked entries yield a
        single span (they are sub-threshold by construction)."""
        e = self._entry(ref, key)
        if e.get("kind") != "chunked":
            v = np.ascontiguousarray(self.materialize_param(ref, key))
            yield 0, v.tobytes()
            return
        chain = self._chunk_chain(ref, key)
        spans = chunklib.spans_of(
            np.cumsum([int(it["n"]) for it in e["chunks"]]).tolist())
        for idx, (off, _n) in enumerate(spans):
            yield off, self._chunk_value(chain, idx)

    def materialize_param_to_file(self, ref: str, key: str,
                                  path: str) -> str:
        """Streaming checkout of one param into a raw little-endian file.

        Returns the tensor hash of the bytes written (accumulated
        incrementally); equal to the manifest entry's ``hash`` iff the
        checkout is bit-identical to the committed truth."""
        e = self._entry(ref, key)
        hasher = TensorHasher(tuple(e["shape"]), e["dtype"])
        with open(path, "wb") as f:
            for _off, data in self.stream_param(ref, key):
                f.write(data)
                hasher.update(data)
        return hasher.hexdigest()

    def chunk_range_objects(self, ref: str, key: str, start: int,
                            end: int) -> List[str]:
        """CAS keys needed to reconstruct bytes [start, end) of a chunked
        param — the shard-scoped fetch set (DESIGN.md §12): a distributed
        consumer asks only for the chunks overlapping its shard."""
        e = self._entry(ref, key)
        if e.get("kind") != "chunked":
            raise ValueError(f"{ref!r}:{key!r} is not chunked")
        chain = self._chunk_chain(ref, key)
        spans = chunklib.spans_of(
            np.cumsum([int(it["n"]) for it in e["chunks"]]).tolist())
        needed: List[str] = []
        for idx, (off, n) in enumerate(spans):
            if off + n <= start or off >= end:
                continue
            level = 0
            while True:
                item = chain[level]["chunks"][idx]
                if "c" in item:
                    needed.append(item["c"])
                    break
                if "b" in item:
                    needed.append(item["b"])
                level += 1
        return needed

    def materialize_param_range(self, ref: str, key: str, start: int,
                                end: int) -> bytes:
        """Truth bytes [start, end) of a chunked param (shard checkout)."""
        e = self._entry(ref, key)
        if e.get("kind") != "chunked":
            v = np.ascontiguousarray(self.materialize_param(ref, key))
            return memoryview(v).cast("B")[start:end].tobytes()
        chain = self._chunk_chain(ref, key)
        spans = chunklib.spans_of(
            np.cumsum([int(it["n"]) for it in e["chunks"]]).tolist())
        out = bytearray(end - start)
        for idx, (off, n) in enumerate(spans):
            if off + n <= start or off >= end:
                continue
            data = self._chunk_value(chain, idx)
            s, t = max(start, off), min(end, off + n)
            out[s - start:t - start] = data[s - off:t - off]
        return bytes(out)

    # -- manifests ----------------------------------------------------------------
    def reload(self) -> None:
        """Pick up commits made by OTHER processes since this store opened.

        Delegates to :meth:`CAS.reload` (re-index packs, tail-scan new
        appends). Tensor/fold/manifest caches are content-addressed, so
        nothing cached can go stale — new refs simply read through."""
        self.cas.reload()

    def get_manifest(self, ref: str) -> Dict[str, Any]:
        with self._lock:
            cached = self._manifests.get(ref)
        if cached is not None:
            return cached
        manifest = json.loads(self.cas.get_bytes(ref))
        with self._lock:
            self._manifests[ref] = manifest
        return manifest

    def _entry(self, ref: str, key: str) -> Dict[str, Any]:
        manifest = self.get_manifest(ref)
        try:
            return manifest["params"][key]
        except KeyError:
            raise KeyError(f"manifest {ref!r} has no param {key!r}")

    # -- chain resolution ---------------------------------------------------------
    def _walk_entries(self, ref: str, key: str):
        """Yield ``(ref, key, entry)`` down one parameter's delta chain.

        The ONE chain-walk loop every resolver shares (plan inspection,
        fold recipes, manifest prefetch). Iterative, cycle-checked via a
        visited set — NOT this store's max_chain_depth: the store may have
        been reopened with a smaller depth knob than the one the chain was
        written with, and that is valid data. Ends after the first
        non-``delta`` entry (``full``, a ``chunked`` chain base, or an
        ``xdelta`` hop — those resolve through their own engines, not this
        walk); callers early-exit by breaking."""
        cur_ref, cur_key = ref, key
        seen = set()
        while True:
            if (cur_ref, cur_key) in seen:
                raise RuntimeError(
                    f"delta chain cycle at {cur_ref!r}:{cur_key!r} "
                    f"(corrupt manifest chain)")
            seen.add((cur_ref, cur_key))
            e = self._entry(cur_ref, cur_key)
            yield cur_ref, cur_key, e
            if e["kind"] != "delta":
                return
            cur_ref, cur_key = e["parent_ref"], e["parent_key"]

    def resolve_chain(self, ref: str, key: str) -> ReconstructionPlan:
        """Walk one parameter's delta chain; emit a flat reconstruction plan.

        Iterative (no recursion) and single-parameter: sibling tensors are
        never touched. The walk stops early at the first chain link already
        materialized in the tensor cache."""
        with self._lock:
            self.io_stats["plans_resolved"] += 1
        hops: List[DeltaHop] = []
        for cur_ref, cur_key, e in self._walk_entries(ref, key):
            if hops and self.cache.contains((cur_ref, cur_key)):
                return ReconstructionPlan("cache", (cur_ref, cur_key),
                                          tuple(reversed(hops)))
            if e["kind"] == "full":
                return ReconstructionPlan("full", e["tensor"],
                                          tuple(reversed(hops)))
            if e["kind"] in ("chunked", "xdelta"):
                # chain base owned by another engine (chunk decode or the
                # lossless bitpattern apply): downstream it behaves like an
                # already-cached value
                return ReconstructionPlan("chunked", (cur_ref, cur_key),
                                          tuple(reversed(hops)))
            hops.append(self._hop_of(e, cur_ref, cur_key))

    def chain_recipe(self, ref: str, key: str
                     ) -> Tuple[str, str, Dict[str, Any], List[DeltaHop]]:
        """Structural chain walk for out-of-store executors (the serving
        pool's derivative-view materialization, DESIGN.md §13).

        Returns ``(terminal_ref, terminal_key, terminal_entry, hops)``:
        the chain base entry (``full`` or ``chunked``) plus every delta hop
        in base->tip order. Unlike :meth:`resolve_chain` this never
        consults the tensor cache — the caller owns its own residency
        story and needs the full structural recipe, not a cache shortcut."""
        hops: List[DeltaHop] = []
        for cur_ref, cur_key, e in self._walk_entries(ref, key):
            if e["kind"] != "delta":
                return cur_ref, cur_key, e, list(reversed(hops))
            hops.append(self._hop_of(e, cur_ref, cur_key))
        raise RuntimeError(f"chain of {ref!r}:{key!r} has no base entry")

    @staticmethod
    def _hop_of(e: Dict[str, Any], ref: str, key: str) -> DeltaHop:
        return DeltaHop(ref=ref, key=key, blob=e["blob"], codec=e["codec"],
                        eps=e["eps"], shape=tuple(e["shape"]),
                        dtype=e["dtype"], qdtype=e.get("qdtype", "int32"))

    @staticmethod
    def _is_segment_boundary(above: DeltaHop, below: Dict[str, Any]) -> bool:
        """True iff hop ``above`` STARTS a new fold segment over entry
        ``below`` (its chain parent). Structural — depends only on manifest
        metadata, never on cache state, so every reader segments a chain
        identically (§10.2)."""
        return (above.dtype != "float32" or below["dtype"] != "float32"
                or float(below["eps"]) != above.eps)

    def _resolve_recipe(self, ref: str, key: str):
        """Chain walk for the folding executor.

        Returns ``(origin, pending)`` where ``pending`` lists hops tip-first
        and ``origin`` is one of ``("tensor", hash)`` — the chain base —
        ``("value", ndarray)`` — a cached link at a segment boundary (safe:
        the hops above it fold independently of how the link was computed) —
        or ``("fold", FoldState)`` — a cached open-segment state the
        remaining hops extend bit-exactly."""
        with self._lock:
            self.io_stats["plans_resolved"] += 1
        pending: List[DeltaHop] = []
        for cur_ref, cur_key, e in self._walk_entries(ref, key):
            if e["kind"] in ("chunked", "xdelta"):
                # chunk-engine or xdelta base for a delta chain built on
                # top of it: materialize it (cached) as a value origin
                v = self.cache.get((cur_ref, cur_key))
                if v is None:
                    v = self.materialize_param(cur_ref, cur_key)
                return ("value", v), pending
            if e["kind"] == "full":
                if pending:
                    v = self.cache.get((cur_ref, cur_key))
                    if v is not None:
                        return ("value", v), pending
                return ("tensor", e["tensor"]), pending
            if pending:
                if self.fold_enabled:
                    fs = self.fold_cache.get((cur_ref, cur_key))
                    if fs is not None:
                        with self._lock:
                            self.io_stats["fold_hits"] += 1
                        return ("fold", fs), pending
                if self._is_segment_boundary(pending[-1], e):
                    v = self.cache.get((cur_ref, cur_key))
                    if v is not None:
                        return ("value", v), pending
            pending.append(self._hop_of(e, cur_ref, cur_key))

    def _dequant(self, value: np.ndarray, q: np.ndarray, eps: float,
                 out_dtype: str) -> np.ndarray:
        """One counted dequant application.

        The pipelined engine uses the numpy host path on CPU hosts (bit-
        identical to the jax ref kernel, no dispatch overhead); the serial
        baseline (``pipelined=False``) keeps the original per-hop jax
        dispatch so benchmarks measure the pre-pipeline engine faithfully.
        Device backends always dispatch."""
        if self.pipelined and self.backend in (None, "ref"):
            out = host_dequant(value, q, eps, out_dtype=out_dtype)
        else:
            from repro.kernels import ops
            out = np.asarray(ops.dequant_apply(
                np.asarray(value), q, eps=eps, backend=self.backend,
                out_dtype=out_dtype))
        with self._lock:
            self.io_stats["dequant_calls"] += 1
        self._count_materialization(out)
        return out

    def _sum_q(self, qs: List[np.ndarray]) -> np.ndarray:
        """Exact int32 sum of a segment's quantized deltas (narrowed int8
        hops widen on the first accumulation; a cached state's sum is
        never mutated — the first add allocates)."""
        acc = qs[0] if qs[0].dtype == np.int32 else qs[0].astype(np.int32)
        for q in qs[1:]:
            acc = np.add(acc, q.reshape(acc.shape), dtype=np.int32)
        return acc

    def _apply_segment(self, value: np.ndarray, open_qs: List[np.ndarray],
                       eps: float, need_sum: bool
                       ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Close one fold segment: value <- dequant(value, Σ open_qs, eps).

        On device backends a multi-hop segment goes through the fused
        Pallas chain-apply kernel (one HBM pass over base + q stack, int32
        reduction in VMEM) — bit-identical to host sum + dequant. Returns
        ``(value, qsum)``; the sum is only computed when the caller needs
        it for a FoldState (``need_sum``) or the host path uses it."""
        if len(open_qs) > 1 and self.backend not in (None, "ref"):
            from repro.kernels import ops
            out = np.asarray(ops.chain_apply(
                np.asarray(value), open_qs, eps=eps, backend=self.backend,
                out_dtype="float32"))
            with self._lock:
                self.io_stats["dequant_calls"] += 1
            self._count_materialization(out)
            return out, (self._sum_q(open_qs) if need_sum else None)
        qsum = self._sum_q(open_qs)
        return self._dequant(value, qsum, eps, "float32"), qsum

    def _materialize_with_state(self, ref: str, key: str,
                                plan: Optional[ReconstructionPlan] = None
                                ) -> Tuple[np.ndarray, Optional[FoldState]]:
        """Execute one param's chain, returning (value, open FoldState|None).

        Bypasses the (ref, key) tensor-cache probe — callers that need the
        fold state (commit) must re-derive it even when the value is warm.
        A full-base ``plan`` (from ``resolve_chain``) substitutes for the
        walk; cache-base plans are not segment-aware and are re-resolved."""
        if plan is not None and plan.base_kind == "full":
            origin, pending = ("tensor", plan.base), list(reversed(plan.hops))
        else:
            origin, pending = self._resolve_recipe(ref, key)
        hops = list(reversed(pending))  # base -> tip order
        kind, payload = origin
        open_qs: List[np.ndarray] = []
        open_eps = 0.0
        if kind == "tensor":
            value = self.cas.get_tensor(payload)
            self._count_materialization(value)
        elif kind == "value":
            value = payload
        else:  # fold state: its accumulated sum seeds the open segment
            fs: FoldState = payload
            value, open_qs, open_eps = fs.seg_base, [fs.q_open], fs.eps
        for hop in hops:
            q = decode_q(hop, self.cas.get_view(hop.blob))
            with self._lock:
                self.io_stats["chain_hops"] += 1
            if self.fold_enabled and hop.dtype == "float32":
                if open_qs and hop.eps == open_eps:
                    open_qs.append(q)
                    with self._lock:
                        self.io_stats["hops_folded"] += 1
                else:
                    if open_qs:
                        value, _ = self._apply_segment(value, open_qs,
                                                       open_eps, False)
                    open_qs, open_eps = [q], hop.eps
            else:
                if open_qs:
                    value, _ = self._apply_segment(value, open_qs, open_eps,
                                                   False)
                    open_qs = []
                value = self._dequant(value, q, hop.eps, hop.dtype
                                      ).reshape(hop.shape)
        state = None
        if open_qs:
            value = np.asarray(value)
            new_value, qsum = self._apply_segment(value, open_qs, open_eps,
                                                  True)
            state = FoldState(seg_base=value, q_open=qsum, eps=open_eps)
            value = new_value
        if hops:
            value = np.asarray(value).reshape(hops[-1].shape)
        return value, state

    def _materialize_xdelta(self, ref: str, key: str,
                            e: Dict[str, Any]) -> np.ndarray:
        """Apply one lossless bitpattern hop: parent truth + stored delta.

        The recursive parent materialization handles mixed chains (xdelta
        over delta over full, etc.) and is bounded by the per-leaf chain
        depth gate at commit time."""
        parent = self.materialize_param(e["parent_ref"], e["parent_key"])
        n = int(np.prod(e["shape"], dtype=np.int64)) if e["shape"] else 1
        qdt = np.dtype(e.get("qdtype", "uint32"))
        # element count of the stored delta, not of the tensor: dtypes
        # whose itemsize has no native unsigned width (complex, …) delta
        # over a byte-wise view, so the blob holds nbytes uint8 elements
        n = n * np.dtype(e["dtype"]).itemsize // qdt.itemsize
        d = get_codec(e["codec"]).decode(
            self.cas.get_view(e["blob"]), n, dtype=str(qdt))
        value = bitpattern_apply(parent, d, e["dtype"], tuple(e["shape"]))
        with self._lock:
            self.io_stats["chain_hops"] += 1
        self._count_materialization(value)
        return value

    def materialize_param(self, ref: str, key: str,
                          plan: Optional[ReconstructionPlan] = None
                          ) -> np.ndarray:
        """Materialize one parameter through the segment-folding executor.

        A full-base ``plan`` (already resolved by ``resolve_chain``) skips
        the second chain walk; cache-base plans are re-resolved — their
        shortcut is not segment-aware."""
        cached = self.cache.get((ref, key))
        if cached is not None:
            return cached
        e = self._entry(ref, key)
        if e["kind"] == "chunked":
            with span("checkout.param", cat="store", key=key,
                      kind="chunked"):
                value = self._materialize_chunked(ref, key)
            self.cache.put((ref, key), value)
            return value
        if e["kind"] == "xdelta":
            with span("checkout.param", cat="store", key=key,
                      kind="xdelta"):
                value = self._materialize_xdelta(ref, key, e)
            self.cache.put((ref, key), value)
            return value
        with span("checkout.param", cat="store", key=key):
            value, state = self._materialize_with_state(ref, key, plan=plan)
        self.cache.put((ref, key), value)
        if state is not None:
            self.fold_cache.put((ref, key), state)
        return value

    def materialize_artifact(self, ref: str,
                             keys: Optional[Sequence[str]] = None,
                             max_workers: Optional[int] = None
                             ) -> ModelArtifact:
        """Batched checkout: materialize all (or ``keys``) params of ``ref``.

        The full-model counterpart of ``materialize_param`` (DESIGN.md
        §10.3): per-param chains share manifest state (prefetched once on
        the calling thread) and fold states, and blob decode + fold fans
        out across a thread pool — LZMA decompression releases the GIL, so
        the batch overlaps codec work the serial loop serializes. Returns a
        NON-lazy artifact; everything lands in the tensor cache, so lazy
        views of the same ref become cache hits."""
        manifest = self.get_manifest(ref)
        want = list(keys if keys is not None else manifest["params"])
        out: Dict[str, np.ndarray] = {}
        misses: List[str] = []
        for k in want:
            v = self.cache.get((ref, k))
            if v is not None:
                out[k] = v
            else:
                misses.append(k)
        if misses:
            with span("store.checkout", cat="store", params=len(misses)):
                # prefetch the manifest chains serially (dict work, no
                # decode): worker threads then walk fully-cached manifests
                for k in misses:
                    for _ in self._walk_entries(ref, k):
                        pass
                workers = min(max_workers or self.io_workers, len(misses))
                one = propagate(lambda k: self.materialize_param(ref, k))
                if workers > 1 and len(misses) > 1:
                    if (max_workers is not None
                            and max_workers != self.io_workers):
                        # explicit sizing (CLI --jobs): a transient pool of
                        # the requested width, not the store's shared default
                        with ThreadPoolExecutor(max_workers=workers) as pool:
                            mapped = list(pool.map(one, misses))
                    else:
                        mapped = list(self._executor().map(one, misses))
                    for k, v in zip(misses, mapped):
                        out[k] = v
                else:
                    for k in misses:
                        out[k] = one(k)
        return ModelArtifact(
            graph=LayerGraph.from_json(manifest["graph"]),
            params={k: out[k] for k in want},
            model_type=manifest.get("model_type", "generic"),
            metadata=manifest.get("metadata", {}),
        )

    def _count_materialization(self, value: np.ndarray) -> None:
        with self._lock:
            self.io_stats["tensors_materialized"] += 1
            self.io_stats["bytes_materialized"] += int(
                np.asarray(value).nbytes)

    def reset_io_stats(self) -> Dict[str, float]:
        # Registry-atomic reset: every key zeroes under ONE group lock, so
        # a concurrent reader can never observe the half-reset view the
        # old per-key mutation loop allowed. The store lock additionally
        # serializes against in-flight `io_stats[k] += n` read-modify-write
        # sequences (which hold it). Returns the pre-reset snapshot.
        with self._lock:
            return self.io_stats.reset()

    # -- load --------------------------------------------------------------------
    def load_artifact(self, ref: str, lazy: bool = True) -> ModelArtifact:
        """Checkout ``ref``. Lazy by default: params materialize on access.

        ``lazy=False`` routes through the batched ``materialize_artifact``
        engine (threaded decode + chain folding)."""
        if not lazy:
            return self.materialize_artifact(ref)
        manifest = self.get_manifest(ref)
        refs = {
            key: ParamRef(store=self, ref=ref, key=key,
                          shape=tuple(e.get("shape", ())),
                          dtype=e.get("dtype", "float32"),
                          hash=e.get("hash") or e.get("tensor"))
            for key, e in manifest["params"].items()
        }
        return ModelArtifact(
            graph=LayerGraph.from_json(manifest["graph"]),
            params=LazyParams(refs),
            model_type=manifest.get("model_type", "generic"),
            metadata=manifest.get("metadata", {}),
        )

    def load_artifact_recursive(self, ref: str,
                                _depth: int = 0) -> ModelArtifact:
        """Pre-plan eager loader (reference implementation).

        Recursively materializes every FULL ancestor artifact to resolve the
        chain — O(full model x chain depth) peak memory. Kept as the
        benchmark baseline for ``benchmarks/bench_compression.py``; all
        production paths go through ``load_artifact``/``materialize_param``.
        Reconstruction follows the same segment-folding semantics (§10.2) —
        the recursion threads each param's open-segment state — so its
        output is bit-identical to the plan engine's."""
        artifact, _ = self._load_recursive_with_states(ref)
        return artifact

    def _load_recursive_with_states(self, ref: str):
        manifest = self.get_manifest(ref)
        params: Dict[str, np.ndarray] = {}
        states: Dict[str, Optional[FoldState]] = {}
        parent_cache: Dict[str, Tuple[ModelArtifact, Dict]] = {}
        for key, e in manifest["params"].items():
            if e["kind"] == "full":
                params[key] = self.cas.get_tensor(e["tensor"])
                states[key] = None
                continue
            if e["kind"] == "chunked":
                params[key] = self._materialize_chunked(ref, key)
                states[key] = None
                continue
            if e["kind"] == "xdelta":
                params[key] = self._materialize_xdelta(ref, key, e)
                states[key] = None
                continue
            pref = e["parent_ref"]
            if pref not in parent_cache:
                parent_cache[pref] = self._load_recursive_with_states(pref)
            parent_art, parent_states = parent_cache[pref]
            pkey = e["parent_key"]
            parent_val = np.asarray(parent_art.params[pkey])
            hop = self._hop_of(e, ref, key)
            q = decode_q(hop, self.cas.get_view(hop.blob))
            ps = parent_states.get(pkey)
            if self.fold_enabled and hop.dtype == "float32":
                if ps is not None and ps.eps == hop.eps:
                    st = FoldState(seg_base=ps.seg_base,
                                   q_open=np.add(ps.q_open, q.reshape(
                                       ps.q_open.shape), dtype=np.int32),
                                   eps=hop.eps)
                else:
                    st = FoldState(seg_base=parent_val, q_open=q,
                                   eps=hop.eps)
                states[key] = st
                params[key] = host_dequant(st.seg_base, st.q_open, st.eps
                                           ).reshape(hop.shape)
            else:
                d = ParamDelta(child_key=key, parent_key=pkey,
                               blob=self.cas.get_bytes(e["blob"]),
                               codec=e["codec"], eps=e["eps"],
                               shape=tuple(e["shape"]), dtype=e["dtype"],
                               raw_bytes=0, qdtype=e.get("qdtype", "int32"))
                params[key] = decompress_param(parent_val, d,
                                               backend=self.backend)
                states[key] = None
        artifact = ModelArtifact(
            graph=LayerGraph.from_json(manifest["graph"]),
            params=params,
            model_type=manifest.get("model_type", "generic"),
            metadata=manifest.get("metadata", {}),
        )
        return artifact, states

    # -- sync/integrity support (DESIGN.md §8) ------------------------------------
    def manifest_closure(self, refs: Sequence[str]
                         ) -> Tuple[Dict[str, Any], List[str]]:
        """Transitive storage dependencies of ``refs`` along delta chains.

        Returns ``(closure, missing)``: ``{manifest_ref: ManifestInfo}`` via
        the shared walk (``repro.store.manifest_walk``) plus the refs that
        could not be read."""
        missing: List[str] = []

        def fetch(keys: Sequence[str]) -> Dict[str, bytes]:
            out: Dict[str, bytes] = {}
            for k in keys:
                try:
                    out[k] = self.cas.get_bytes(k)
                except Exception:
                    pass  # the walk records it as missing
            return out

        closure = walk_manifests(fetch, refs, missing=missing)
        return closure, missing

    def expected_refcounts(self, roots: Sequence[str]) -> Dict[str, int]:
        """Reconstruct exact refcounts from the manifest graph.

        Mirrors commit-time accounting: each manifest holds one reference
        per param entry on its tensor/blob and one per delta parent; each
        occurrence in ``roots`` (a lineage ``artifact_ref``) holds one
        reference on the manifest itself. Only keys *reachable from roots*
        appear — counts for anything else are out of scope."""
        closure, _ = self.manifest_closure(roots)
        counts: Dict[str, int] = {ref: 0 for ref in closure}
        for info in closure.values():
            for k in info.objects:
                counts[k] = counts.get(k, 0) + 1
            for p in info.parents:
                counts[p] = counts.get(p, 0) + 1
        for r in roots:
            if r in closure:
                counts[r] += 1
        return counts

    def rebuild_refcounts(self, roots: Sequence[str]) -> Dict[str, int]:
        """Install exact refcounts for everything reachable from ``roots``.

        The post-transfer step of a sync (DESIGN.md §8.5): imported objects
        arrive with placeholder counts; one rebuild makes the receiving side
        bit-equivalent to having committed the graph locally. Keys NOT
        reachable from ``roots`` are left untouched, so callers owning other
        root sets lose nothing."""
        counts = self.expected_refcounts(roots)
        with self.cas.batched_refcounts():
            for key, count in counts.items():
                if self.cas.has(key):
                    self.cas.refcounts[key] = count
        self.cas.flush()
        return counts

    def import_objects(self, objects) -> int:
        """Raw object ingestion for sync transfers (idempotent per key).

        Keys are trusted as content addresses here; ``fsck`` re-verifies.
        Returns bytes actually written (dedup hits cost nothing). Lands
        through one buffered CAS batch — a pull/clone pays one fsync, not
        one per object."""
        written = 0
        with self.cas.batch():
            for key, data in objects.items():
                if not self.cas.has(key):
                    self.cas.put_bytes(data, key=key)
                    written += len(data)
        self.cas.flush()
        return written

    def export_flat_manifest(self, ref: str, name: Optional[str] = None
                             ) -> Tuple[str, Dict[str, bytes]]:
        """Build a flattened (depth-0) equivalent of ``ref`` *transiently*.

        The shallow-push fallback: when a receiver can't get the delta
        chain, ship materialized tensors instead. Returns ``(flat_ref,
        objects)`` where ``objects`` holds the new manifest payload plus
        every tensor's npy bytes, ready for the wire. Nothing is committed
        into THIS store — a sender must stay refcount-clean after a push
        (committing here would orphan a manifest no lineage node references
        and bump shared-tensor counts into permanent fsck drift). Tensors
        materialize through the batched checkout engine; their serialized
        bytes are all held for transfer, so peak memory is O(model). Plan
        execution is bit-exact with commit-time reconstruction (§10.2), so
        the flattened model is bit-identical to the chained one."""
        manifest = self.get_manifest(ref)
        artifact = self.materialize_artifact(ref)
        entries: Dict[str, Any] = {}
        objects: Dict[str, bytes] = {}
        for key in artifact.params:
            value = np.asarray(artifact.params[key])
            thash = tensor_hash(value)
            buf = io.BytesIO()
            np.save(buf, value, allow_pickle=False)
            objects[thash] = buf.getvalue()
            entries[key] = {"kind": "full", "tensor": thash,
                            "shape": list(value.shape),
                            "dtype": str(value.dtype), "hash": thash}
        flat = {
            "name": name or manifest.get("name", "flat"),
            "model_type": manifest.get("model_type", "generic"),
            "metadata": manifest.get("metadata", {}),
            "graph": manifest["graph"],
            "params": entries,
            "depth": 0,
            "delta_parents": [],
        }
        payload = json.dumps(flat, sort_keys=True, default=str).encode()
        flat_ref = "m_" + bytes_hash(payload)
        objects[flat_ref] = payload
        return flat_ref, objects

    def fsck(self, roots: Sequence[str] = ()) -> Dict[str, Any]:
        """CAS integrity pass plus manifest-graph cross-checks.

        Extends :meth:`CAS.fsck` with: ``missing_objects`` (keys the manifest
        closure of ``roots`` references but the CAS lacks) and
        ``refcount_drift`` (``{key: [actual, expected]}``; undercounts risk
        premature collection, overcounts only delay it).

        For chunked params, damage is pinpointed: ``chunk_damage`` maps each
        corrupt/missing chunk object back to ``(ref, param, chunk index)``,
        so a single bad chunk identifies exactly which slice of which tensor
        is lost rather than condemning the whole multi-GB object."""
        report = self.cas.fsck()
        closure, missing_refs = self.manifest_closure(roots)
        expected = self.expected_refcounts(roots)
        # has() treats a refcounted key as present even when its object file
        # is gone (the refcount table is authoritative for liveness, not
        # bytes) — the CAS pass reports those as dangling refs; reachable
        # ones are missing objects from the manifest graph's point of view
        missing = sorted(set(missing_refs)
                         | {k for k in expected if not self.cas.has(k)}
                         | (set(report["dangling_refs"]) & set(expected)))
        drift = {k: [self.cas.refcounts.get(k, 0), v]
                 for k, v in expected.items()
                 if self.cas.has(k) and self.cas.refcounts.get(k, 0) != v}
        bad = set(report["corrupt"]) | set(missing)
        chunk_damage: List[Dict[str, Any]] = []
        if bad:
            for mref in closure:
                try:
                    manifest = self.get_manifest(mref)
                except Exception:
                    continue
                for pkey, e in manifest["params"].items():
                    if e.get("kind") != "chunked":
                        continue
                    for i, item in enumerate(e["chunks"]):
                        k = item.get("c") or item.get("b")
                        if k and k in bad:
                            chunk_damage.append(
                                {"ref": mref, "param": pkey, "chunk": i,
                                 "object": k,
                                 "problem": ("corrupt"
                                             if k in report["corrupt"]
                                             else "missing")})
        report["manifests_reachable"] = len(closure)
        report["missing_objects"] = missing
        report["refcount_drift"] = drift
        report["chunk_damage"] = chunk_damage
        report["ok"] = bool(report["ok"] and not missing and not drift)
        return report

    # -- lifecycle ------------------------------------------------------------------
    def release(self, ref: str) -> None:
        """Drop one reference to a manifest and everything it points at."""
        try:
            manifest = self.get_manifest(ref)
        except Exception:
            return
        with self.cas.batched_refcounts():  # ONE durable write for the lot
            for e in manifest["params"].values():
                if e["kind"] == "chunked":
                    # mirror of commit/parse_manifest accounting: one ref
                    # per chunk object occurrence (pass-throughs own none)
                    for item in e["chunks"]:
                        k = item.get("c") or item.get("b")
                        if k:
                            self.cas.decref(k)
                else:
                    self.cas.decref(e["tensor"] if e["kind"] == "full"
                                    else e["blob"])
            for pref in manifest.get("delta_parents", []):
                self.cas.decref(pref)
            self.cas.decref(ref)
        self.cache.drop_ref(ref)
        self.fold_cache.drop_ref(ref)

    def gc(self) -> int:
        return self.cas.gc()

    def _persist_stats(self) -> None:
        if self._stats_path is None:
            return
        with self._lock:  # concurrent commits share one tmp path
            tmp = self._stats_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"logical_bytes": self.logical_bytes,
                           "truth": ("fold" if self.fold_enabled
                                     else "hopwise")}, f)
            os.replace(tmp, self._stats_path)

    # -- accounting -------------------------------------------------------------------
    def compression_ratio(self) -> float:
        return self.logical_bytes / max(self.cas.physical_bytes(), 1)

    def stats(self) -> Dict[str, Any]:
        return {
            "logical_bytes": self.logical_bytes,
            "physical_bytes": self.cas.physical_bytes(),
            "compression_ratio": self.compression_ratio(),
            "objects": self.cas.object_count(),
            "cache_bytes": self.cache.bytes_used,
            "cache_entries": len(self.cache),
            "cache_hits": self.cache.hits,
            "cache_misses": self.cache.misses,
            "cache_evictions": self.cache.evictions,
            "fold_cache_bytes": self.fold_cache.bytes_used,
            "fold_cache_entries": len(self.fold_cache),
            **self.io_stats.snapshot(),  # one lock: no torn multi-key view
            **self.cas.pack_stats(),
            **self.cas.stats,
        }
