"""ArtifactStore — manifests binding the CAS + delta compression to lineage nodes.

Committing an artifact produces a *manifest* (JSON, itself CAS-stored):

    {name, model_type, graph, metadata, depth,
     params: {key: {kind: "full", tensor: <hash>}
                  | {kind: "delta", blob: <hash>, parent_ref, parent_key,
                     codec, eps, shape, dtype}}}

Full tensors dedup automatically through content hashing; delta entries point
at their parent manifest and decompress recursively up the chain to the first
non-delta ancestor (paper §4). ``max_chain_depth`` bounds reconstruction
latency, like git packfile delta-depth limits (beyond-paper knob).
"""

from __future__ import annotations

import json
import os
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.common.hashing import bytes_hash, tensor_hash
from repro.core.artifact import ModelArtifact
from repro.core.graphir import LayerGraph
from repro.store.cas import CAS
from repro.store.delta import (CompressResult, decompress_param,
                               delta_compression)


class ArtifactStore:
    """The ``store`` object a :class:`repro.core.LineageGraph` plugs into."""

    def __init__(self, root: Optional[str] = None, codec: str = "lzma",
                 eps: float = 1e-4, t_thr: float = 0.5,
                 delta_enabled: bool = True, per_param: bool = True,
                 max_chain_depth: int = 8, cache_size: int = 4,
                 zero_frac_prefilter: float = 0.0,
                 backend: Optional[str] = None) -> None:
        self.cas = CAS(root)
        self.codec = codec
        self.eps = eps
        self.t_thr = t_thr
        self.delta_enabled = delta_enabled
        self.per_param = per_param
        self.max_chain_depth = max_chain_depth
        self.zero_frac_prefilter = zero_frac_prefilter
        self.backend = backend
        self._manifests: Dict[str, Dict[str, Any]] = {}
        self._cache: "OrderedDict[str, ModelArtifact]" = OrderedDict()
        self._cache_size = cache_size
        self.logical_bytes = 0
        self.last_result: Optional[CompressResult] = None
        self._stats_path = (os.path.join(root, "store_stats.json")
                            if root else None)
        if self._stats_path and os.path.exists(self._stats_path):
            with open(self._stats_path) as f:
                self.logical_bytes = json.load(f).get("logical_bytes", 0)

    # -- commit -----------------------------------------------------------------
    def commit_artifact(self, name: str, artifact: ModelArtifact,
                        parent_ref: Optional[str] = None,
                        tests: Sequence = ()) -> str:
        self.logical_bytes += artifact.nbytes()
        self._persist_stats()
        entries: Dict[str, Any] = {}
        depth = 0

        deltas = {}
        if self.delta_enabled and parent_ref is not None:
            parent_manifest = self.get_manifest(parent_ref)
            if parent_manifest["depth"] < self.max_chain_depth:
                parent = self.load_artifact(parent_ref)
                result = delta_compression(
                    artifact, parent, t_thr=self.t_thr, eps=self.eps,
                    codec=self.codec, tests=tests, per_param=self.per_param,
                    zero_frac_prefilter=self.zero_frac_prefilter,
                    backend=self.backend)
                self.last_result = result
                if result.accepted:
                    deltas = result.deltas
                    depth = parent_manifest["depth"] + 1
                    # persist the *reconstructed* model as this version's truth
                    artifact = result.reconstructed

        for key, value in artifact.params.items():
            value = np.asarray(value)
            if key in deltas:
                d = deltas[key]
                blob_hash = self.cas.put_bytes(d.blob)
                entries[key] = {"kind": "delta", "blob": blob_hash,
                                "parent_ref": parent_ref,
                                "parent_key": d.parent_key, "codec": d.codec,
                                "eps": d.eps, "shape": list(d.shape),
                                "dtype": d.dtype, "qdtype": d.qdtype}
            else:
                thash = tensor_hash(value)  # content-based hashing dedup
                self.cas.put_tensor(value, key=thash)
                entries[key] = {"kind": "full", "tensor": thash,
                                "shape": list(value.shape),
                                "dtype": str(value.dtype)}

        delta_parents = sorted({e["parent_ref"] for e in entries.values()
                                if e["kind"] == "delta"})
        for pref in delta_parents:
            self.cas.incref(pref)  # chain dependency: parent must outlive child
        manifest = {
            "name": name,
            "model_type": artifact.model_type,
            "metadata": artifact.metadata,
            "graph": artifact.graph.to_json(),
            "params": entries,
            "depth": depth,
            "delta_parents": delta_parents,
        }
        payload = json.dumps(manifest, sort_keys=True, default=str).encode()
        ref = self.cas.put_bytes(payload, key="m_" + bytes_hash(payload))
        self._manifests[ref] = manifest
        return ref

    # -- load --------------------------------------------------------------------
    def get_manifest(self, ref: str) -> Dict[str, Any]:
        if ref not in self._manifests:
            self._manifests[ref] = json.loads(self.cas.get_bytes(ref))
        return self._manifests[ref]

    def load_artifact(self, ref: str) -> ModelArtifact:
        if ref in self._cache:
            self._cache.move_to_end(ref)
            return self._cache[ref]
        manifest = self.get_manifest(ref)
        params: Dict[str, np.ndarray] = {}
        parent_cache: Dict[str, ModelArtifact] = {}
        for key, e in manifest["params"].items():
            if e["kind"] == "full":
                params[key] = self.cas.get_tensor(e["tensor"])
            else:
                pref = e["parent_ref"]
                if pref not in parent_cache:
                    parent_cache[pref] = self.load_artifact(pref)  # recursive chain
                parent_val = parent_cache[pref].params[e["parent_key"]]
                from repro.store.delta import ParamDelta
                d = ParamDelta(child_key=key, parent_key=e["parent_key"],
                               blob=self.cas.get_bytes(e["blob"]),
                               codec=e["codec"], eps=e["eps"],
                               shape=tuple(e["shape"]), dtype=e["dtype"],
                               raw_bytes=0, qdtype=e.get("qdtype", "int32"))
                params[key] = decompress_param(np.asarray(parent_val), d,
                                               backend=self.backend)
        artifact = ModelArtifact(
            graph=LayerGraph.from_json(manifest["graph"]),
            params=params,
            model_type=manifest.get("model_type", "generic"),
            metadata=manifest.get("metadata", {}),
        )
        self._cache[ref] = artifact
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        return artifact

    # -- lifecycle ------------------------------------------------------------------
    def release(self, ref: str) -> None:
        """Drop one reference to a manifest and everything it points at."""
        try:
            manifest = self.get_manifest(ref)
        except Exception:
            return
        for e in manifest["params"].values():
            self.cas.decref(e["tensor"] if e["kind"] == "full" else e["blob"])
        for pref in manifest.get("delta_parents", []):
            self.cas.decref(pref)
        self.cas.decref(ref)
        self._cache.pop(ref, None)

    def gc(self) -> int:
        return self.cas.gc()

    def _persist_stats(self) -> None:
        if self._stats_path is None:
            return
        tmp = self._stats_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"logical_bytes": self.logical_bytes}, f)
        os.replace(tmp, self._stats_path)

    # -- accounting -------------------------------------------------------------------
    def compression_ratio(self) -> float:
        return self.logical_bytes / max(self.cas.physical_bytes(), 1)

    def stats(self) -> Dict[str, Any]:
        return {
            "logical_bytes": self.logical_bytes,
            "physical_bytes": self.cas.physical_bytes(),
            "compression_ratio": self.compression_ratio(),
            "objects": self.cas.object_count(),
            **self.cas.stats,
        }
