"""Manifest-graph walk: the single source of truth for closure traversal.

Both sides of the sync protocol need the transitive storage dependencies of
a set of manifests — push/pull planning (``repro.remote.negotiate``) and
refcount replay / fsck (``ArtifactStore``). One implementation serves both,
parameterized by a ``fetch`` callable so the walk runs against a local CAS,
a remote transport, or local-first-then-transport. A manifest-schema change
(e.g. a new entry kind) lands here once.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Sequence, Set

Fetch = Callable[[Sequence[str]], Dict[str, bytes]]


@dataclasses.dataclass
class ManifestInfo:
    """One manifest's direct references, as occurrence lists (not sets):
    refcount replay needs multiplicity — a tensor shared by two entries of
    the same manifest was incref'd twice at commit time."""

    objects: List[str]          # tensor / delta-blob keys, one per param entry
    parents: List[str]          # unique delta-parent manifest refs
    depth: int


def parse_manifest(data: bytes) -> ManifestInfo:
    manifest = json.loads(data)
    objects: List[str] = []
    parents_set = set()
    for e in manifest["params"].values():
        kind = e["kind"]
        if kind == "chunked":
            # one occurrence per chunk item that owns an object: raw chunks
            # (``c``) and per-chunk delta blobs (``b``); pass-through items
            # (``p``) reference no object. Listing chunk keys here is what
            # makes have/want negotiation chunk-granular for free.
            for item in e["chunks"]:
                if "c" in item:
                    objects.append(item["c"])
                elif "b" in item:
                    objects.append(item["b"])
            if e.get("parent_ref"):
                parents_set.add(e["parent_ref"])
        else:
            objects.append(e["tensor"] if kind == "full" else e["blob"])
            if kind in ("delta", "xdelta"):
                parents_set.add(e["parent_ref"])
    return ManifestInfo(objects=objects, parents=sorted(parents_set),
                        depth=int(manifest.get("depth", 0)))


def walk_manifests(fetch: Fetch, refs: Sequence[str],
                   missing: Optional[List[str]] = None
                   ) -> Dict[str, ManifestInfo]:
    """BFS the manifest graph from ``refs`` along delta-parent edges.

    ``fetch(keys) -> {key: bytes}`` supplies manifest payloads. Refs the
    fetch omits are appended to ``missing`` (when given) and skipped; with
    ``missing=None`` an absent ref raises ``KeyError`` — transfer planning
    wants the hard failure, fsck wants the report."""
    closure: Dict[str, ManifestInfo] = {}
    skipped: Set[str] = set()
    frontier = [r for r in dict.fromkeys(refs) if r]
    while frontier:
        batch = [r for r in frontier if r not in closure and r not in skipped]
        frontier = []
        if not batch:
            break
        payloads = fetch(batch)
        for ref in batch:
            data = payloads.get(ref)
            if data is None:
                if missing is None:
                    raise KeyError(f"manifest {ref!r} unavailable")
                missing.append(ref)
                skipped.add(ref)
                continue
            info = parse_manifest(data)
            closure[ref] = info
            frontier.extend(p for p in info.parents
                            if p not in closure and p not in skipped)
    return closure


def closure_keys(closure: Dict[str, ManifestInfo]) -> Set[str]:
    """Every CAS key the closure touches: manifests + referenced objects."""
    keys: Set[str] = set(closure)
    for info in closure.values():
        keys.update(info.objects)
    return keys
