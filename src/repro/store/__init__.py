"""MGit storage: CAS dedup, codecs, delta compression, versioned checkpoints."""

from repro.store.artifact_store import ArtifactStore
from repro.store.cas import CAS
from repro.store.checkpoint import (CheckpointManager, flatten_state,
                                    unflatten_state)
from repro.store.codecs import CODECS, get_codec
from repro.store.delta import (CompressResult, ParamDelta, decompress_param,
                               delta_compression, lcs_param_matching)

__all__ = [
    "ArtifactStore", "CAS", "CheckpointManager", "flatten_state",
    "unflatten_state", "CODECS", "get_codec", "CompressResult", "ParamDelta",
    "decompress_param", "delta_compression", "lcs_param_matching",
]
