"""mgit — the command-line interface over the lineage graph (paper §3.1).

    python -m repro.cli -C <repo_dir> <command> [...]

Commands (analogous to git's CLI, per the paper):
    log                         render the lineage graph
    show <node>                 node details (parents, versions, storage)
    diff <a> <b> [--mode]       structural/contextual diff between two models
    add-edge <x> <y>            provenance edge
    add-version-edge <x> <y>    versioning edge
    remove-node <x>             remove node + subtree
    test <node|--all> [--re P | --glob P]
                                run registered tests via a traversal
                                (one explicit pattern mode, regex or glob)
    param <node> <key>          materialize ONE parameter (lazy checkout):
                                prints its reconstruction plan + summary stats
    checkout <node>             batched full-model materialization through
                                the chain-folding engine (DESIGN.md §10):
                                prints per-param chain stats (hops decoded,
                                dequants applied, folds, zero-copy reads)
    stats                       storage statistics (ratio, dedup, objects,
                                packfiles, tensor + fold caches)
    gc                          collect unreferenced objects

Global storage knobs:
    --lzma-preset N             LZMA preset for newly committed delta blobs
                                (0 fastest ... 9 strongest; default 0 — see
                                bench_compression's preset sweep)

Collaboration commands (paper §5; DESIGN.md §8, §11):
    remote add <name> <url>     register a peer repository (url = directory
                                or an http(s):// hub daemon)
    remote list                 configured remotes
    remote remove <name>        unregister a remote
    push <remote> [--filter P] [--force]
                                ship the (fnmatch-filtered) lineage subgraph:
                                have/want negotiation transfers only objects
                                the remote is missing; a lineage conflict
                                aborts before publish unless --force; a
                                concurrent pusher is absorbed via the
                                409/etag retry loop (DESIGN.md §11.3)
    pull <remote> [--filter P]  fetch the (filtered) remote subgraph and
                                three-way merge it into the local lineage;
                                divergent models auto-merge when the §5
                                decision tree allows
    clone <url> <dest>          materialize a remote repo (directory or hub
                                url) into a fresh directory (sets up
                                'origin' tracking)
    fsck                        integrity pass: re-hash all CAS objects,
                                verify manifest closures, report dangling
                                refs / refcount drift / stale transfers

Hub commands (DESIGN.md §11; 'hub' namespace — the bare name 'serve' is
reserved for the inference engine in repro/serve):
    hub serve [--host H] [--port N] [--token T] [--allow-quarantined]
                                serve THIS repo (-C) to HTTP clients:
                                threaded daemon, optimistic-swap publishes,
                                zero-copy ranged object reads, resumable
                                journalled transfers
    hub stats <url>             live counters of a running hub daemon

Serving commands (DESIGN.md §13; the inference tier over -C repo's store):
    serve <name>=<mode>:<target> [...] [--hub URL] [--host H] [--port N]
                                lineage-native model serving: one resident
                                chain base, per-endpoint derivative views
                                by fused delta application, hot-swapped on
                                lineage publish (local lineage.json etag,
                                or a hub's ETag'd GET /api/lineage with
                                --hub). Endpoint specs pin a branch
                                (prod=branch:main — head re-resolves, a
                                merge INTO the branch promotes), a node
                                (canary=node:m@v2), or a raw manifest ref.
                                Quarantined nodes never get traffic.

Observability commands (DESIGN.md §14):
    obs metrics                 print the process-wide metrics registry in
                                Prometheus text exposition format (counters
                                register at zero for a fresh process; run a
                                command under `obs trace` or scrape a live
                                daemon's /api/metrics for hot numbers)
    obs trace [--out F] [cmd ...]
                                run an mgit command with tracing enabled
                                (default: a chain-folded checkout sweep of
                                every stored node) and write the spans as
                                Chrome-trace/Perfetto JSON — load the file
                                at https://ui.perfetto.dev

Diagnostics commands (paper §4; DESIGN.md §9):
    diag run [node] [--pattern P] [--match-glob] [--jobs N] [--force]
             [--builtin]        memoized parallel test sweep: unchanged
                                models answer from the result ledger with
                                zero materializations (--builtin registers a
                                param-RMS probe per model type so the ledger
                                is exercisable without the Python API)
    diag blame <node> <test>    DAG-wide regression attribution: classify
                                each ancestor failure as introduced /
                                inherited / merge-emergent and report the
                                earliest failing frontier
    diag history <node> [test]  ledger entries across the node's version
                                chain (ModelHub-style evaluation history)
    diag gate-report            quarantined nodes + recorded regressions
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.core import LineageGraph, bfs, module_diff
from repro.store import ArtifactStore


def _graph(repo: str, lzma_preset=None,
           chunk_threshold=None) -> LineageGraph:
    return LineageGraph(path=repo,
                        store=ArtifactStore(root=repo,
                                            lzma_preset=lzma_preset,
                                            chunk_threshold=chunk_threshold))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="mgit", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("-C", dest="repo", default=".", help="lineage repo directory")
    ap.add_argument("--lzma-preset", dest="lzma_preset", type=int,
                    default=None, metavar="N",
                    help="LZMA preset for new delta blobs (0..9; default 0)")
    ap.add_argument("--chunk-threshold", dest="chunk_threshold", type=int,
                    default=None, metavar="BYTES",
                    help="tensors at/above this size commit as content-"
                         "defined chunk objects (default 8 MiB; 0 disables "
                         "chunking)")
    ap.add_argument("--dump-docs", action="store_true",
                    help="print the generated CLI reference (docs/cli.md) "
                         "and exit")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("log", help="render the lineage graph")
    p = sub.add_parser("show", help="node details (parents, versions, storage)")
    p.add_argument("node", help="lineage node name (e.g. bert@v2)")
    p = sub.add_parser("diff",
                       help="structural/contextual diff between two models")
    p.add_argument("a", help="first node name")
    p.add_argument("b", help="second node name")
    p.add_argument("--mode", default="contextual",
                   choices=["structural", "contextual"],
                   help="matching mode (paper §3.2)")
    p = sub.add_parser("add-edge", help="add a provenance edge")
    p.add_argument("x", help="parent node")
    p.add_argument("y", help="child node")
    p = sub.add_parser("add-version-edge", help="add a versioning edge")
    p.add_argument("x", help="earlier version node")
    p.add_argument("y", help="later version node")
    p = sub.add_parser("remove-node", help="remove a node and its subtree")
    p.add_argument("x", help="node to remove")
    p = sub.add_parser("test",
                       help="run registered tests via a graph traversal")
    p.add_argument("node", nargs="?", default=None,
                   help="traversal start (default: whole graph)")
    grp = p.add_mutually_exclusive_group()
    grp.add_argument("--re", dest="pattern", default=None,
                     help="regex test-name filter")
    grp.add_argument("--glob", dest="glob_pattern", default=None,
                     help="fnmatch glob test-name filter")
    p = sub.add_parser("param",
                       help="materialize ONE parameter (lazy checkout)")
    p.add_argument("node", help="lineage node name")
    p.add_argument("key", help="flat parameter key (layer/param)")
    p = sub.add_parser("checkout",
                       help="batched full-model materialization "
                            "(chain-folding engine, DESIGN.md §10)")
    p.add_argument("node", help="lineage node name")
    p.add_argument("--jobs", type=int, default=None,
                   help="decode worker threads (default: store io_workers)")
    sub.add_parser("stats", help="storage statistics (ratio, dedup, caches)")
    sub.add_parser("gc", help="collect unreferenced objects")
    p = sub.add_parser("remote", help="manage peer repositories")
    p.add_argument("action", choices=["add", "list", "remove"],
                   help="what to do with the remote registry")
    p.add_argument("name", nargs="?", help="remote name (add/remove)")
    p.add_argument("url", nargs="?",
                   help="peer directory or http(s):// hub url (add)")
    p = sub.add_parser("push",
                       help="ship the lineage subgraph to a remote "
                            "(DESIGN.md §8, §11.3)")
    p.add_argument("remote", help="remote name, directory, or hub url")
    p.add_argument("--filter", default=None,
                   help="fnmatch node filter for a shallow push")
    p.add_argument("--force", action="store_true",
                   help="publish even on a lineage conflict (keeps pushed "
                        "versions)")
    p.add_argument("--include-quarantined", action="store_true",
                   help="ship nodes a test gate quarantined (excluded by default)")
    p = sub.add_parser("pull",
                       help="fetch a remote subgraph and three-way merge it")
    p.add_argument("remote", help="remote name, directory, or hub url")
    p.add_argument("--filter", default=None,
                   help="fnmatch node filter for a shallow pull")
    p = sub.add_parser("clone",
                       help="materialize a remote repo into a fresh directory")
    p.add_argument("url", help="peer directory or http(s):// hub url")
    p.add_argument("dest", help="destination directory (must be fresh)")
    p.add_argument("--filter", default=None,
                   help="fnmatch node filter for a shallow clone")
    sub.add_parser("fsck",
                   help="integrity pass: re-hash objects, closures, refcounts")
    p = sub.add_parser("diag",
                       help="memoized diagnostics: run/blame/history/"
                            "gate-report (DESIGN.md §9)")
    p.add_argument("action", choices=["run", "blame", "history", "gate-report"],
                   help="diagnostics subcommand")
    p.add_argument("node", nargs="?", default=None,
                   help="node scope (run) / target node (blame, history)")
    p.add_argument("test", nargs="?", default=None,
                   help="test name (blame) / filter (history)")
    p.add_argument("--pattern", default=None, help="test-name filter")
    p.add_argument("--match-glob", action="store_true",
                   help="interpret --pattern as an fnmatch glob (default: regex)")
    p.add_argument("--jobs", type=int, default=4)
    p.add_argument("--force", action="store_true",
                   help="bypass the result ledger (results are re-recorded)")
    p.add_argument("--builtin", action="store_true",
                   help="register the builtin param-RMS probe per model type")
    p.add_argument("--prefetch", action="store_true",
                   help="batch-materialize each model before its tests run "
                        "(chain-folded, threaded checkout; DESIGN.md §10.3)")
    p = sub.add_parser("obs",
                       help="offline observability: metrics registry dump / "
                            "traced command runs (DESIGN.md §14)")
    p.add_argument("action", choices=["metrics", "trace"],
                   help="observability subcommand")
    p.add_argument("--out", default=None, metavar="FILE",
                   help="trace output path (default: <repo>/trace.json)")
    p.add_argument("rest", nargs=argparse.REMAINDER, metavar="CMD",
                   help="mgit command to run under tracing (trace action; "
                        "default: a checkout sweep of every stored node)")
    p = sub.add_parser("hub", help="model-hub daemon (DESIGN.md §11, §16)")
    p.add_argument("action",
                   choices=["serve", "stats", "gc", "compact", "replica"])
    p.add_argument("url", nargs="?",
                   help="hub url (stats/gc/compact actions; omitted = run "
                        "gc/compact offline over the -C repo)")
    p.add_argument("--host", default="127.0.0.1",
                   help="bind address for hub serve / hub replica")
    p.add_argument("--port", type=int, default=8943,
                   help="bind port for hub serve / hub replica (0 picks an "
                        "ephemeral one)")
    p.add_argument("--token", default=None,
                   help="bearer token: required of clients (serve) / sent "
                        "to the hub (stats; also $MGIT_HUB_TOKEN)")
    p.add_argument("--allow-quarantined", action="store_true",
                   help="accept pushed nodes flagged quarantined instead of "
                        "rejecting them server-side")
    p.add_argument("--max-workers", type=int, default=None, metavar="N",
                   help="request worker-pool size (serve/replica; 0 = "
                        "unbounded thread-per-request compat mode)")
    p.add_argument("--queue-depth", type=int, default=None, metavar="N",
                   help="accepted-but-unserviced request backlog before the "
                        "hub sheds load with 503 + Retry-After")
    p.add_argument("--confirm-cycles", type=int, default=2, metavar="N",
                   help="hub gc: orphan confirmation cycles (1 = reclaim "
                        "on first sight; offline use only)")
    p.add_argument("--grace", type=int, default=1, metavar="N",
                   help="hub gc: cycles an imported-but-unpublished object "
                        "is protected from candidacy")
    p.add_argument("--primary", default=None, metavar="URL",
                   help="hub replica: primary hub to mirror (required)")
    p.add_argument("--sync-interval", type=float, default=5.0, metavar="S",
                   help="hub replica: seconds between mirror passes (0 = "
                        "sync only on POST /api/replica/sync)")
    p = sub.add_parser("serve",
                       help="lineage-native inference daemon (DESIGN.md "
                            "§13): one resident base, hot-swappable "
                            "branch-pinned endpoints")
    p.add_argument("endpoints", nargs="+", metavar="NAME=MODE:TARGET",
                   help="endpoint specs, e.g. prod=branch:main "
                        "canary=node:m@v2 pin=ref:m_<hash>")
    p.add_argument("--hub", default=None, metavar="URL",
                   help="watch this hub's ETag'd lineage instead of the "
                        "local lineage.json (store still reads -C repo)")
    p.add_argument("--token", default=None,
                   help="bearer token for --hub (also $MGIT_HUB_TOKEN)")
    p.add_argument("--host", default="127.0.0.1",
                   help="bind address for the serving daemon")
    p.add_argument("--port", type=int, default=8944,
                   help="bind port (0 picks an ephemeral one)")
    p.add_argument("--poll", type=float, default=1.0, metavar="S",
                   help="lineage watch interval in seconds")
    p.add_argument("--max-resident", type=int, default=8, metavar="N",
                   help="LRU cap on resident derivative views")
    p.add_argument("--budget-mb", type=int, default=None, metavar="MB",
                   help="byte budget over the views' private (non-aliased) "
                        "bytes; the pinned base is not counted")
    p.add_argument("--backend", default=None,
                   help="kernel backend for delta application (default: "
                        "host fold on CPU, fused chain_apply on device)")
    p = sub.add_parser("train",
                       help="toy training run with continuous checkpointing "
                            "(DESIGN.md §15): every commit is an MGit "
                            "version node in -C repo")
    p.add_argument("--steps", type=int, default=20,
                   help="number of training steps to run")
    p.add_argument("--commit-every", type=int, default=1, metavar="N",
                   help="commit a checkpoint version every N steps "
                        "(the continuous-checkpointing cadence)")
    p.add_argument("--lossy-tier", action="store_true",
                   help="int8 error-feedback deltas with periodic exact "
                        "keyframes instead of lossless step deltas")
    p.add_argument("--keyframe-every", type=int, default=8, metavar="K",
                   help="lossy tier: every K-th commit is an exact keyframe")
    p.add_argument("--d-model", type=int, default=32,
                   help="toy model width")
    p.add_argument("--n-layers", type=int, default=2,
                   help="toy model depth")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    ap = build_parser()
    if "--dump-docs" in argv:
        # Intercepted pre-parse: the subcommand argument is required, and
        # docs generation must not depend on one.
        print(dump_docs(ap))
        return 0
    args = ap.parse_args(argv)

    if args.cmd == "obs":
        return _cmd_obs(args)
    if args.cmd == "hub":
        return _cmd_hub(args)
    if args.cmd == "serve":
        return _cmd_serve(args)
    if args.cmd == "train":
        return _cmd_train(args)
    if args.cmd == "clone":  # dest is the repo; don't touch args.repo
        from repro import remote as rm
        report = rm.clone(args.url, args.dest, filter=args.filter)
        print(json.dumps(report.to_json(), indent=1))
        return 0 if report.merge is None or not report.merge.conflicts else 1

    g = _graph(args.repo, lzma_preset=args.lzma_preset,
               chunk_threshold=args.chunk_threshold)

    if args.cmd == "log":
        print(g.log() or "(empty lineage graph)")
    elif args.cmd == "show":
        n = g.nodes[args.node]
        info = {"name": n.name, "model_type": n.model_type,
                "parents": n.parents, "children": n.children,
                "version_parents": n.version_parents,
                "version_children": n.version_children,
                "artifact_ref": n.artifact_ref, "metadata": n.metadata}
        if n.artifact_ref and g.store:
            m = g.store.get_manifest(n.artifact_ref)
            kinds = {}
            for e in m["params"].values():
                kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
            info["storage"] = {"depth": m["depth"], "entries": kinds}
        print(json.dumps(info, indent=1))
    elif args.cmd == "diff":
        d = module_diff(g.get_model(args.a), g.get_model(args.b),
                        mode=args.mode)
        print(json.dumps({
            "mode": d.mode, "divergence": d.divergence,
            "matched": len(d.matched_nodes),
            "add_nodes": d.add_nodes, "del_nodes": d.del_nodes,
            "add_edges": len(d.add_edges), "del_edges": len(d.del_edges),
        }, indent=1))
    elif args.cmd == "add-edge":
        g.add_edge(args.x, args.y)
        print(f"provenance edge {args.x} -> {args.y}")
    elif args.cmd == "add-version-edge":
        g.add_version_edge(args.x, args.y)
        print(f"version edge {args.x} -> {args.y}")
    elif args.cmd == "remove-node":
        g.remove_node(args.x)
        print(f"removed {args.x} (+subtree)")
    elif args.cmd == "test":
        it = bfs(g) if args.node is None else bfs(g, start=args.node)
        pattern, match = ((args.glob_pattern, "glob")
                          if args.glob_pattern is not None
                          else (args.pattern, "regex"))
        results = g.run_tests(it, pattern=pattern, match=match)
        print(json.dumps(results, indent=1) if results else
              "(no registered tests matched — register via the Python API)")
    elif args.cmd == "param":
        # Lazy single-parameter checkout: resolves the delta chain for ONE
        # tensor and materializes only that chain — never the full model.
        node = g.nodes[args.node]
        if node.artifact_ref is None or g.store is None:
            print(f"node {args.node!r} has no stored artifact")
            return 1
        try:
            plan = g.store.resolve_chain(node.artifact_ref, args.key)
        except KeyError:
            keys = sorted(g.store.get_manifest(node.artifact_ref)["params"])
            print(f"no param {args.key!r} in {args.node!r}; available: "
                  + ", ".join(keys[:8]) + (" ..." if len(keys) > 8 else ""))
            return 1
        value = g.store.materialize_param(node.artifact_ref, args.key,
                                          plan=plan)
        print(json.dumps({
            "node": args.node, "key": args.key,
            "shape": list(value.shape), "dtype": str(value.dtype),
            "l2_norm": float(np.linalg.norm(np.asarray(value, np.float64))),
            "plan": {"base": plan.base_kind, "chain_depth": plan.depth},
            "bytes_materialized": g.store.io_stats["bytes_materialized"],
        }, indent=1))
    elif args.cmd == "checkout":
        # Batched full-model checkout: chain folding collapses same-eps
        # delta chains into one dequant per parameter; decode fans out
        # across the store's worker pool (DESIGN.md §10.3).
        import time as _time
        node = g.nodes[args.node]
        if node.artifact_ref is None or g.store is None:
            print(f"node {args.node!r} has no stored artifact")
            return 1
        g.store.reset_io_stats()
        t0 = _time.perf_counter()
        artifact = g.store.materialize_artifact(node.artifact_ref,
                                                max_workers=args.jobs)
        dt = _time.perf_counter() - t0
        print(json.dumps({
            "node": args.node, "params": len(artifact.params),
            "bytes": artifact.nbytes(), "seconds": round(dt, 4),
            "io": dict(g.store.io_stats),
            "zero_copy_gets": g.store.cas.stats["zero_copy_gets"],
        }, indent=1))
    elif args.cmd == "stats":
        print(json.dumps(g.store.stats(), indent=1))
    elif args.cmd == "gc":
        print(f"reclaimed {g.store.gc()} bytes")
    elif args.cmd == "remote":
        from repro import remote as rm
        if args.action == "add":
            if not args.name or not args.url:
                print("usage: remote add <name> <url>")
                return 1
            rm.remote_add(args.repo, args.name, args.url)
            print(f"remote {args.name} -> {args.url}")
        elif args.action == "remove":
            rm.remote_remove(args.repo, args.name)
            print(f"removed remote {args.name}")
        else:
            print(json.dumps(rm.remote_list(args.repo), indent=1))
    elif args.cmd in ("push", "pull"):
        from repro import remote as rm
        transport, name = rm.resolve_transport(args.repo, args.remote)
        state = rm.RemoteState(args.repo, name)
        if args.cmd == "push":
            report = rm.push(g, transport, filter=args.filter, state=state,
                             force=args.force,
                             include_quarantined=args.include_quarantined)
        else:
            report = rm.pull(g, transport, filter=args.filter, state=state)
        print(json.dumps(report.to_json(), indent=1))
        if args.cmd == "push" and not report.published:
            return 1
        return 1 if report.merge is not None and report.merge.conflicts else 0
    elif args.cmd == "fsck":
        from repro.remote import LocalJournalStore
        roots = [n.artifact_ref for n in g.nodes.values() if n.artifact_ref]
        report = g.store.fsck(roots)
        report["in_flight_transfers"] = LocalJournalStore(
            args.repo).journal_list()
        print(json.dumps(report, indent=1))
        return 0 if report["ok"] else 1
    elif args.cmd == "diag":
        from repro import diag
        runner = diag.DiagnosticsRunner(g, max_workers=args.jobs,
                                        prefetch=getattr(args, "prefetch",
                                                         False))
        if args.builtin:
            _register_builtin_probes(g)
        if args.action == "run":
            nodes = None if args.node is None else [g.nodes[args.node]]
            if not g.tests:
                print("(no registered tests — register via the Python API "
                      "or pass --builtin)")
                return 1
            report = runner.run(
                nodes=nodes, pattern=args.pattern,
                match="glob" if args.match_glob else "regex",
                force=args.force)
            print(json.dumps(report.to_json(), indent=1))
            return 1 if report.failures() else 0
        elif args.action == "blame":
            if not args.node or not args.test:
                print("usage: diag blame <node> <test>")
                return 1
            report = diag.blame(g, args.node, args.test, runner=runner)
            print(json.dumps(report.to_json(), indent=1))
            return 0 if report.status == diag.PASS else 1
        elif args.action == "history":
            if not args.node:
                print("usage: diag history <node> [test]")
                return 1
            entries = runner.history(args.node, args.test)
            print(json.dumps(entries, indent=1) if entries else
                  f"(no recorded results for {args.node!r})")
        else:  # gate-report
            print(json.dumps(diag.gate_report(g), indent=1) or "[]")
    return 0


def _cmd_obs(args) -> int:
    """`obs metrics` (registry dump) / `obs trace` (traced command run)."""
    from repro.obs import render_prometheus, save_trace, tracing
    if args.action == "metrics":
        _graph(args.repo)  # registers the store's metric families
        print(render_prometheus(), end="")
        return 0
    rest = [a for a in args.rest if a != "--"]
    # REMAINDER swallows options placed after the action; recover --out
    if len(rest) >= 2 and rest[0] == "--out":
        args.out, rest = rest[1], rest[2:]
    elif rest and rest[0].startswith("--out="):
        args.out, rest = rest[0].split("=", 1)[1], rest[1:]
    out = args.out or os.path.join(args.repo, "trace.json")
    with tracing():
        if rest:
            rc = main(["-C", args.repo] + rest)
        else:
            g = _graph(args.repo)
            refs = [(n.name, n.artifact_ref) for n in g.nodes.values()
                    if n.artifact_ref]
            for _, ref in refs:
                g.store.materialize_artifact(ref)
            print(f"traced a checkout sweep over {len(refs)} node(s)")
            rc = 0
    spans = save_trace(out)
    # stderr: the traced command owns stdout (JSON output stays pipeable)
    print(f"wrote {spans} span(s) to {out}", file=sys.stderr)
    return rc


def _cmd_hub(args) -> int:
    """`hub serve|stats|gc|compact|replica` (DESIGN.md §11, §16)."""
    pool_kw = {}
    if args.max_workers is not None:
        pool_kw["max_workers"] = args.max_workers
    if args.queue_depth is not None:
        pool_kw["queue_depth"] = args.queue_depth
    if args.action == "serve":
        from repro.hub import HubService, make_server
        service = HubService(args.repo, token=args.token,
                             allow_quarantined=args.allow_quarantined)
        server = make_server(service, host=args.host, port=args.port,
                             **pool_kw)
        names = ", ".join(service.repo_names())
        print(f"mgit hub: serving {service.root} at {server.url} "
              f"(repos: {names})"
              + (" [token auth]" if service.auth.enabled else ""), flush=True)
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
        return 0
    if args.action == "replica":
        if not args.primary:
            print("usage: hub replica --primary URL [-C replica-dir]")
            return 1
        from repro.hub.replica import serve_replica
        replica, server, _ = serve_replica(
            args.repo, args.primary, token=args.token,
            host=args.host, port=args.port,
            sync_interval_s=args.sync_interval)
        print(f"mgit hub replica: mirroring {args.primary} into "
              f"{replica.service.root} at {server.url}", flush=True)
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
        return 0
    if args.action in ("gc", "compact"):
        if args.url:  # remote: ask a live hub to run its maintenance
            from repro.remote.http import HttpTransport
            tr = HttpTransport(args.url, token=args.token)
            report = (tr.run_gc(confirm_cycles=args.confirm_cycles,
                                grace=args.grace)
                      if args.action == "gc" else tr.run_compact())
        else:  # offline: the hub dir with no live traffic -> no fences
            from repro.hub import HubService
            from repro.hub.gc import run_compaction, run_gc
            service = HubService(args.repo, allow_quarantined=True)
            report = (run_gc(service, confirm_cycles=args.confirm_cycles,
                             grace=args.grace)
                      if args.action == "gc" else run_compaction(service))
        print(json.dumps(report, indent=1))
        return 0
    if not args.url:
        print("usage: hub stats <url>")
        return 1
    from repro.remote.http import HttpTransport
    print(json.dumps(HttpTransport(args.url, token=args.token).server_stats(),
                     indent=1))
    return 0


def _cmd_serve(args) -> int:
    """`serve`: blocking inference daemon over the -C repo's store."""
    from repro.serve import (HubLineageSource, LineageWatcher,
                             LocalLineageSource, ModelPool, Router, ServeApp,
                             make_server)
    store = ArtifactStore(root=args.repo)
    pool = ModelPool(store, max_resident=args.max_resident,
                     budget_bytes=(args.budget_mb * (1 << 20)
                                   if args.budget_mb else None),
                     backend=args.backend)
    router = Router(pool, args.endpoints)
    token = args.token or os.environ.get("MGIT_HUB_TOKEN")
    source = (HubLineageSource(args.hub, token=token) if args.hub
              else LocalLineageSource(args.repo))
    watcher = LineageWatcher(source, router, interval_s=args.poll)
    watcher.poll()  # resolve every endpoint before accepting traffic
    app = ServeApp(router, pool, watcher)
    server = make_server(app, host=args.host, port=args.port)
    watcher.start()
    print(f"mgit serve: {len(router.endpoints)} endpoint(s) over "
          f"{source.describe()} at {server.url}", flush=True)
    for ep in router.endpoints.values():
        st = ep.stats()
        print(f"  {st['name']} -> {st['spec']} "
              f"(node={st['node']}, gate={st['gate']})", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        watcher.stop()
        server.server_close()
    return 0


def _cmd_train(args) -> int:
    """`train`: toy loop exercising the continuous-checkpointing path."""
    from repro.models.config import ModelConfig
    from repro.store.checkpoint import CKPT_STATS
    from repro.train import Trainer
    cfg = ModelConfig(name="cli-train", family="dense",
                      n_layers=args.n_layers, d_model=args.d_model,
                      n_heads=2, n_kv_heads=2, d_ff=args.d_model * 2,
                      vocab_size=64, head_dim=args.d_model // 2,
                      dtype="float32", attn_chunk=16, remat="none")
    trainer = Trainer(cfg, batch=args.batch, seq=args.seq,
                      checkpoint_dir=args.repo, seed=args.seed,
                      commit_every=args.commit_every,
                      lossy_tier=args.lossy_tier,
                      keyframe_every=args.keyframe_every)
    history = trainer.run(args.steps)
    ckpt = trainer.ckpt
    ckpt.close()
    print(json.dumps({
        "steps": args.steps, "start_step": trainer.start_step,
        "final_loss": history["loss"][-1] if history["loss"] else None,
        "tier": ckpt.tier, "commit_every": trainer.checkpoint_every,
        "latest_step": ckpt.latest_step(),
        "ckpt": {k: int(CKPT_STATS[k]) for k in
                 ("saves", "commits", "coalesced", "leaves_skipped")},
    }, indent=1))
    return 0


# ---------------------------------------------------------------------------
# CLI reference generation (docs/cli.md)
# ---------------------------------------------------------------------------


def _action_syntax(action: argparse.Action) -> str:
    """Deterministic syntax cell for one argparse action (no argparse
    formatter involved — their output wraps on terminal width, which would
    make the generated docs drift between environments)."""
    if not action.option_strings:
        name = action.metavar or action.dest
        if action.choices is not None and action.metavar is None:
            name = "{" + ",".join(str(c) for c in action.choices) + "}"
        if action.nargs in ("?", "*", argparse.REMAINDER):
            return f"[{name}]"
        return f"<{name}>"
    opts = ", ".join(action.option_strings)
    if action.nargs == 0:
        return f"`{opts}`"
    metavar = action.metavar or action.dest.replace("-", "_").upper()
    return f"`{opts} {metavar}`"


def _action_desc(action: argparse.Action) -> str:
    desc = " ".join((action.help or "").split())
    extras = []
    if action.choices is not None and action.option_strings:
        extras.append("one of: " + ", ".join(str(c) for c in action.choices))
    if (action.option_strings and action.nargs != 0
            and action.default not in (None, False, argparse.SUPPRESS)):
        extras.append(f"default: {action.default}")
    if extras:
        desc = (desc + " " if desc else "") + "(" + "; ".join(extras) + ")"
    return desc


def dump_docs(ap: argparse.ArgumentParser) -> str:
    """Render the complete CLI reference from the live argparse tree.

    ``docs/cli.md`` is this function's output verbatim; CI regenerates it
    and fails on drift, so the reference can never fall behind the code."""
    sub = next(a for a in ap._actions
               if isinstance(a, argparse._SubParsersAction))
    out = [
        "# mgit — CLI reference",
        "",
        "<!-- GENERATED FILE, do not edit by hand.",
        "     Regenerate: PYTHONPATH=src python -m repro.cli --dump-docs"
        " > docs/cli.md",
        "     CI regenerates and diffs this file, failing on drift. -->",
        "",
        "Invocation: `python -m repro.cli [global options] <command> [...]`",
        "",
        "## Global options",
        "",
        "| option | description |",
        "|---|---|",
    ]
    for action in ap._actions:
        if isinstance(action, (argparse._SubParsersAction,
                               argparse._HelpAction)):
            continue
        out.append(f"| {_action_syntax(action)} | {_action_desc(action)} |")
    out += ["", "## Commands", ""]
    for name, parser in sub.choices.items():
        actions = [a for a in parser._actions
                   if not isinstance(a, argparse._HelpAction)]
        positionals = [a for a in actions if not a.option_strings]
        usage = " ".join(["mgit", name]
                         + [_action_syntax(a) for a in positionals]
                         + (["[options]"]
                            if any(a.option_strings for a in actions)
                            else []))
        out += [f"### `{usage}`", ""]
        help_text = next((a.help for a in sub._choices_actions
                          if a.dest == name and a.help), None)
        if help_text:
            out += [" ".join(help_text.split()), ""]
        if actions:
            out += ["| argument | description |", "|---|---|"]
            for action in actions:
                out.append(f"| {_action_syntax(action)} "
                           f"| {_action_desc(action)} |")
            out.append("")
    out += [
        "## Command overview (from `mgit --help`)",
        "",
        "```text",
        (ap.description or "").strip(),
        "```",
        "",
    ]
    return "\n".join(out)


def _register_builtin_probes(g: LineageGraph) -> None:
    """One param-RMS probe per model type in the graph.

    A named module-level function (stable bytecode), so its ledger entries
    memoize across CLI invocations — the second `diag run --builtin` answers
    entirely from the store."""
    for mt in sorted({n.model_type for n in g.nodes.values()}):
        g.register_test_function(_param_rms, "builtin/param_rms", mt=mt)


def _param_rms(model) -> float:
    total, count = 0.0, 0
    for key in model.params:
        v = np.asarray(model.params[key], dtype=np.float64)
        total += float((v * v).sum())
        count += v.size
    return float(np.sqrt(total / max(count, 1)))


if __name__ == "__main__":
    sys.exit(main())
