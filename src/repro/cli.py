"""mgit — the command-line interface over the lineage graph (paper §3.1).

    python -m repro.cli -C <repo_dir> <command> [...]

Commands (analogous to git's CLI, per the paper):
    log                         render the lineage graph
    show <node>                 node details (parents, versions, storage)
    diff <a> <b> [--mode]       structural/contextual diff between two models
    add-edge <x> <y>            provenance edge
    add-version-edge <x> <y>    versioning edge
    remove-node <x>             remove node + subtree
    test <node|--all> [--re]    run registered tests via a traversal
    param <node> <key>          materialize ONE parameter (lazy checkout):
                                prints its reconstruction plan + summary stats
    stats                       storage statistics (ratio, dedup, objects,
                                packfiles, tensor cache)
    gc                          collect unreferenced objects
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core import LineageGraph, bfs, module_diff
from repro.store import ArtifactStore


def _graph(repo: str) -> LineageGraph:
    return LineageGraph(path=repo, store=ArtifactStore(root=repo))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="mgit", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("-C", dest="repo", default=".", help="lineage repo directory")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("log")
    p = sub.add_parser("show")
    p.add_argument("node")
    p = sub.add_parser("diff")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--mode", default="contextual",
                   choices=["structural", "contextual"])
    p = sub.add_parser("add-edge")
    p.add_argument("x")
    p.add_argument("y")
    p = sub.add_parser("add-version-edge")
    p.add_argument("x")
    p.add_argument("y")
    p = sub.add_parser("remove-node")
    p.add_argument("x")
    p = sub.add_parser("test")
    p.add_argument("node", nargs="?", default=None)
    p.add_argument("--re", dest="pattern", default=None)
    p = sub.add_parser("param")
    p.add_argument("node")
    p.add_argument("key")
    sub.add_parser("stats")
    sub.add_parser("gc")

    args = ap.parse_args(argv)
    g = _graph(args.repo)

    if args.cmd == "log":
        print(g.log() or "(empty lineage graph)")
    elif args.cmd == "show":
        n = g.nodes[args.node]
        info = {"name": n.name, "model_type": n.model_type,
                "parents": n.parents, "children": n.children,
                "version_parents": n.version_parents,
                "version_children": n.version_children,
                "artifact_ref": n.artifact_ref, "metadata": n.metadata}
        if n.artifact_ref and g.store:
            m = g.store.get_manifest(n.artifact_ref)
            kinds = {}
            for e in m["params"].values():
                kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
            info["storage"] = {"depth": m["depth"], "entries": kinds}
        print(json.dumps(info, indent=1))
    elif args.cmd == "diff":
        d = module_diff(g.get_model(args.a), g.get_model(args.b),
                        mode=args.mode)
        print(json.dumps({
            "mode": d.mode, "divergence": d.divergence,
            "matched": len(d.matched_nodes),
            "add_nodes": d.add_nodes, "del_nodes": d.del_nodes,
            "add_edges": len(d.add_edges), "del_edges": len(d.del_edges),
        }, indent=1))
    elif args.cmd == "add-edge":
        g.add_edge(args.x, args.y)
        print(f"provenance edge {args.x} -> {args.y}")
    elif args.cmd == "add-version-edge":
        g.add_version_edge(args.x, args.y)
        print(f"version edge {args.x} -> {args.y}")
    elif args.cmd == "remove-node":
        g.remove_node(args.x)
        print(f"removed {args.x} (+subtree)")
    elif args.cmd == "test":
        it = bfs(g) if args.node is None else bfs(g, start=args.node)
        results = g.run_tests(it, re_pattern=args.pattern)
        print(json.dumps(results, indent=1) if results else
              "(no registered tests matched — register via the Python API)")
    elif args.cmd == "param":
        # Lazy single-parameter checkout: resolves the delta chain for ONE
        # tensor and materializes only that chain — never the full model.
        node = g.nodes[args.node]
        if node.artifact_ref is None or g.store is None:
            print(f"node {args.node!r} has no stored artifact")
            return 1
        try:
            plan = g.store.resolve_chain(node.artifact_ref, args.key)
        except KeyError:
            keys = sorted(g.store.get_manifest(node.artifact_ref)["params"])
            print(f"no param {args.key!r} in {args.node!r}; available: "
                  + ", ".join(keys[:8]) + (" ..." if len(keys) > 8 else ""))
            return 1
        value = g.store.materialize_param(node.artifact_ref, args.key,
                                          plan=plan)
        print(json.dumps({
            "node": args.node, "key": args.key,
            "shape": list(value.shape), "dtype": str(value.dtype),
            "l2_norm": float(np.linalg.norm(np.asarray(value, np.float64))),
            "plan": {"base": plan.base_kind, "chain_depth": plan.depth},
            "bytes_materialized": g.store.io_stats["bytes_materialized"],
        }, indent=1))
    elif args.cmd == "stats":
        print(json.dumps(g.store.stats(), indent=1))
    elif args.cmd == "gc":
        print(f"reclaimed {g.store.gc()} bytes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
