"""The assigned input-shape cells + ShapeDtypeStruct input specs per cell.

Shapes (per assignment):
  train_4k     seq 4096,    global_batch 256   -> lowers train_step
  prefill_32k  seq 32768,   global_batch 32    -> lowers prefill_step
  decode_32k   seq 32768,   global_batch 128   -> lowers serve_step (1 token,
                                                  KV cache of seq_len)
  long_500k    seq 524288,  global_batch 1     -> serve_step; sub-quadratic
                                                  archs only (cfg.subquadratic)

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStruct
stand-ins for every input — no device allocation, so full-size configs lower
on a CPU host with 512 placeholder devices.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import _resolve_entry, param_spec
from repro.models import cache_shapes, param_shapes
from repro.models.config import ModelConfig
from repro.models.model import param_structs


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int
    # microbatches for train cells (activation-memory knob; §Perf)
    microbatches: int = 1


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256, microbatches=16),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

DEC_LEN_CAP = 4096   # enc-dec: decoder stream capped (DESIGN.md §5)
CROSS_LEN = 4096     # enc-dec decode: encoder memory length


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "full quadratic attention — long-context decode skipped (DESIGN.md §5)"
    return True, ""


def _ns(mesh: Optional[Mesh], *entries):
    if mesh is None:
        return None
    axes = set(mesh.axis_names)
    return NamedSharding(mesh, P(*[_resolve_entry(e, axes) for e in entries]))


def _sds(shape, dtype, sharding):
    if sharding is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_structs(cfg: ModelConfig, cell: ShapeCell,
                  mesh: Optional[Mesh]) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for a train/prefill cell."""
    B, S = cell.batch, cell.seq
    bsh2 = _ns(mesh, ("pod", "data"), None)
    bsh3 = _ns(mesh, ("pod", "data"), None, None)
    out: Dict[str, Any] = {}
    if cfg.family in ("encdec", "audio"):
        out["frames"] = _sds((B, S, cfg.d_model), jnp.float32, bsh3)
        out["tokens"] = _sds((B, min(S, DEC_LEN_CAP)), jnp.int32, bsh2)
    elif cfg.family == "vlm":
        out["patches"] = _sds((B, cfg.n_prefix_tokens, cfg.d_model),
                              jnp.float32, bsh3)
        out["tokens"] = _sds((B, S - cfg.n_prefix_tokens), jnp.int32, bsh2)
    else:
        out["tokens"] = _sds((B, S), jnp.int32, bsh2)
    return out


def _cache_part_spec(path: str, shape: Tuple[int, ...]) -> Tuple:
    """Sharding for decode caches: batch over ('pod','data'); for batch=1
    long-context cells the sequence axis takes 'data' instead; head_dim or
    heads over 'model' where divisible."""
    leaf = path.rsplit("/", 1)[-1]
    if leaf in ("k", "v"):
        # (L, B, S, H, hd): batch over data axes, SEQUENCE over model
        # (flash-decode style split-KV). Sharding head_dim instead forces a
        # full per-layer cache all-gather (measured 131GB/step on
        # deepseek decode; §Perf-C) — with S@model only the tiny softmax
        # stats cross the mesh.
        if shape[1] == 1:  # batch 1 (long_500k): sequence takes every axis
            return (None, None, ("pod", "data", "model"), None, None)
        return (None, ("pod", "data"), "model", None, None)
    if "state" in path:   # (L, B, H, N, P) or (L, n, B, H, N, P)
        spec = [None] * len(shape)
        bi = 1 if len(shape) == 5 else 2
        if shape[bi] > 1:
            spec[bi] = ("pod", "data")
        spec[bi + 1] = "model"
        return tuple(spec)
    if "conv" in path:    # (L, B, K-1, Cd) or (L, n, B, K-1, Cd)
        spec = [None] * len(shape)
        bi = 1 if len(shape) == 4 else 2
        if shape[bi] > 1:
            spec[bi] = ("pod", "data")
        spec[-1] = "model"
        return tuple(spec)
    return tuple([None] * len(shape))


def cache_structs_sharded(cfg: ModelConfig, cell: ShapeCell,
                          mesh: Optional[Mesh]):
    from repro.models.model import _nested
    enc_len = CROSS_LEN if cfg.family in ("encdec", "audio") else 0
    flat = {}
    for path, (shape, dtype) in cache_shapes(cfg, cell.batch, cell.seq,
                                             enc_len).items():
        sh = _ns(mesh, *_cache_part_spec(path, shape)) if mesh else None
        flat[path] = _sds(shape, dtype, sh)
    return _nested(flat)


def params_structs_sharded(cfg: ModelConfig, mesh: Optional[Mesh]):
    structs = param_structs(cfg)
    if mesh is None:
        return structs
    from repro.models.model import flat_paths, _nested
    axes = set(mesh.axis_names)

    def _axis_size(e) -> int:
        names = (e,) if isinstance(e, str) else e
        return int(np.prod([mesh.shape[a] for a in names]))

    flat = {}
    for path, s in flat_paths(structs).items():
        spec = param_spec(path, len(s.shape))
        entries = [_resolve_entry(e, axes) for e in spec]
        dropped = []
        for i, e in enumerate(entries):
            if e is not None and s.shape[i] % _axis_size(e) != 0:
                dropped.append(e)   # non-divisible (e.g. E=8 experts on 16-way)
                entries[i] = None
        # re-place dropped mesh axes on the largest divisible unsharded dim so
        # big tensors never silently replicate (mixtral expert weights!)
        for e in dropped:
            for i in sorted(range(len(entries)), key=lambda i: -s.shape[i]):
                if entries[i] is None and s.shape[i] % _axis_size(e) == 0:
                    entries[i] = e
                    break
        flat[path] = _sds(s.shape, s.dtype, NamedSharding(mesh, P(*entries)))
    return _nested(flat)


def state_structs_sharded(cfg: ModelConfig, mesh: Optional[Mesh],
                          compress_grads: bool = False):
    """TrainState ShapeDtypeStructs (params + fp32 moments, ZeRO-sharded)."""
    from repro.optim.adamw import OptState
    params = params_structs_sharded(cfg, mesh)
    f32 = lambda s: _sds(s.shape, jnp.float32, getattr(s, "sharding", None))
    mu = jax.tree_util.tree_map(f32, params)
    nu = jax.tree_util.tree_map(f32, params)
    state = {
        "params": params,
        "opt": OptState(mu=mu, nu=nu, count=_sds((), jnp.int32, _ns(mesh))),
        "step": _sds((), jnp.int32, _ns(mesh)),
    }
    if compress_grads:
        state["err"] = jax.tree_util.tree_map(f32, params)
    return state


def decode_token_structs(cfg: ModelConfig, cell: ShapeCell,
                         mesh: Optional[Mesh]):
    tok = _sds((cell.batch, 1), jnp.int32,
               _ns(mesh, ("pod", "data"), None) if cell.batch > 1 else _ns(mesh))
    pos = _sds((), jnp.int32, _ns(mesh))
    return tok, pos


def input_specs(arch: str, shape: str, mesh: Optional[Mesh] = None,
                cfg: Optional[ModelConfig] = None) -> Dict[str, Any]:
    """All ShapeDtypeStruct inputs for one (arch x shape) dry-run cell."""
    from repro.models.config import get_config
    cfg = cfg or get_config(arch)
    cell = SHAPES[shape]
    ok, why = cell_supported(cfg, cell)
    if not ok:
        raise ValueError(f"{arch} x {shape} unsupported: {why}")
    if cell.kind == "train":
        return {"state": state_structs_sharded(cfg, mesh),
                "batch": batch_structs(cfg, cell, mesh)}
    if cell.kind == "prefill":
        return {"params": params_structs_sharded(cfg, mesh),
                "batch": batch_structs(cfg, cell, mesh)}
    token, pos = decode_token_structs(cfg, cell, mesh)
    return {"params": params_structs_sharded(cfg, mesh),
            "cache": cache_structs_sharded(cfg, cell, mesh),
            "token": token, "pos": pos}
