"""Loop-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which makes
it useless for scan-based programs (layers, microbatches and attention chunks
all live in loops here). The optimized HLO, however, carries
``backend_config={"known_trip_count":{"n":...}}`` on every counted loop, so
this module re-derives the totals exactly:

  flops        2·prod(result)·prod(contracting dims) per dot (+1 flop/element
               for elementwise/reduce ops — softmax/norm traffic), multiplied
               through the loop nest;
  bytes        post-fusion memory traffic: every top-level instruction reads
               its operands and writes its result once (fusions are opaque —
               exactly XLA's own bytes-accessed semantics), times trip counts;
  collectives  operand/result bytes per all-gather / all-reduce /
               reduce-scatter / all-to-all / collective-permute, times trips.

All quantities are PER-DEVICE (the compiled module is the post-SPMD
per-core program). Validated against cost_analysis on loop-free programs in
tests/test_hlo_cost.py.
"""

from __future__ import annotations

import json
import re
from typing import Any, Dict, List, Optional, Tuple


def xla_cost_analysis(compiled: Any) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized across JAX versions.

    Older releases return a one-dict-per-device list; newer ones return the
    dict directly. Always hand back a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0}

# ops that move no data / do no math
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota",
             "optimization-barrier", "custom-call"}
_ELEMENTWISE = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
                "exponential", "tanh", "rsqrt", "sqrt", "log", "log-plus-one",
                "exponential-minus-one", "negate", "abs", "floor", "ceil",
                "power", "compare", "select", "and", "or", "xor", "not",
                "sign", "cosine", "sine", "atan2", "remainder",
                "round-nearest-afz", "round-nearest-even", "clamp",
                "shift-left", "shift-right-logical", "shift-right-arithmetic",
                "logistic", "is-finite", "expm1", "log1p", "cbrt", "erf",
                "reduce-precision", "stochastic-convert"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}

_TYPE_TOKEN = r"(?:\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(" + _TYPE_TOKEN + r")\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(type_str))


def _type_elems(type_str: str) -> int:
    return sum(_shape_elems(dims) for _, dims in _SHAPE_RE.findall(type_str))


class Instr:
    __slots__ = ("name", "type_str", "op", "line")

    def __init__(self, name: str, type_str: str, op: str, line: str):
        self.name = name
        self.type_str = type_str
        self.op = op
        self.line = line


def parse_module(text: str) -> Dict[str, List[Instr]]:
    """computation name -> instruction list."""
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if current is None:
            # header: `%name (args…) -> type {` — args may contain nested parens
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", line)
            if m and not stripped.startswith("//"):
                current = m.group(1)
                comps[current] = []
            continue
        if stripped == "}" or stripped.startswith("} "):
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[current].append(Instr(m.group(1), m.group(2), m.group(3),
                                        stripped))
    return comps


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = self._find_entry(text)
        self._types: Dict[str, str] = {}
        for instrs in self.comps.values():
            for i in instrs:
                self._types[i.name] = i.type_str
        self._memo: Dict[str, Tuple[float, float, Dict]] = {}
        self.unknown_ops: Dict[str, int] = {}

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        if m:
            return m.group(1)
        return next(iter(self.comps))

    # -- per-instruction local costs ---------------------------------------
    def _operands(self, instr: Instr) -> List[str]:
        paren = instr.line.find("(")
        depth = 0
        end = paren
        for idx in range(paren, len(instr.line)):
            ch = instr.line[idx]
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = idx
                    break
        return _OPERAND_RE.findall(instr.line[paren:end + 1])

    def _operand_bytes(self, instr: Instr) -> int:
        return sum(_type_bytes(self._types.get(o, "")) for o in self._operands(instr))

    def _dot_flops(self, instr: Instr) -> float:
        result_elems = _type_elems(instr.type_str)
        ops = self._operands(instr)
        lhs_type = self._types.get(ops[0], "") if ops else ""
        m = _CDIMS_RE.search(instr.line)
        contract = 1
        if m and lhs_type:
            shapes = _SHAPE_RE.findall(lhs_type)
            if shapes:
                dims = [int(d) for d in shapes[0][1].split(",") if d]
                for ci in m.group(1).split(","):
                    if ci:
                        contract *= dims[int(ci)]
        return 2.0 * result_elems * contract

    # -- recursive totals ------------------------------------------------------
    def total(self, comp: Optional[str] = None) -> Tuple[float, float, Dict]:
        """(flops, bytes, collectives) of one execution of ``comp``."""
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = (0.0, 0.0, {})  # cycle guard
        flops = 0.0
        mem = 0.0
        coll: Dict[str, Dict[str, float]] = {}

        def add_coll(kind, ob, rb, n=1.0):
            agg = coll.setdefault(kind, {"count": 0.0, "operand_bytes": 0.0,
                                         "result_bytes": 0.0})
            agg["count"] += n
            agg["operand_bytes"] += ob
            agg["result_bytes"] += rb

        def merge_coll(sub: Dict, mult: float = 1.0):
            for kind, agg in sub.items():
                add_coll(kind, agg["operand_bytes"] * mult,
                         agg["result_bytes"] * mult, agg["count"] * mult)

        for instr in self.comps.get(comp, []):
            op = instr.op
            if op == "while":
                trips = 1
                m = _TRIP_RE.search(instr.line)
                if m:
                    trips = int(m.group(1))
                body = _BODY_RE.search(instr.line)
                cond = _COND_RE.search(instr.line)
                for sub in (body, cond):
                    if sub:
                        f, b, c = self.total(sub.group(1))
                        flops += trips * f
                        mem += trips * b
                        merge_coll(c, trips)
                continue
            if op == "fusion":
                m = _CALLS_RE.search(instr.line)
                if m:
                    f, _, c = self.total(m.group(1))
                    flops += f            # flops inside the fusion body
                    merge_coll(c)
                mem += _type_bytes(instr.type_str) + self._operand_bytes(instr)
                continue
            if op in ("call", "async-start"):
                m = _TO_APPLY_RE.search(instr.line)
                if m:
                    f, b, c = self.total(m.group(1))
                    flops += f
                    mem += b
                    merge_coll(c)
                continue
            if op == "conditional":
                m = _BRANCHES_RE.search(instr.line)
                if m:
                    subs = _OPERAND_RE.findall(m.group(1))
                    totals = [self.total(s) for s in subs]
                    if totals:
                        f = max(t[0] for t in totals)
                        b = max(t[1] for t in totals)
                        flops += f
                        mem += b
                        merge_coll(totals[0][2])
                continue
            if op in _COLLECTIVES or (op.endswith("-start") and
                                      op[:-6] in _COLLECTIVES):
                kind = op[:-6] if op.endswith("-start") else op
                ob = self._operand_bytes(instr)
                rb = _type_bytes(instr.type_str)
                add_coll(kind, ob, rb)
                mem += ob + rb
                continue
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            if op == "dot" or op == "convolution":
                flops += self._dot_flops(instr)
                mem += _type_bytes(instr.type_str) + self._operand_bytes(instr)
                continue
            if op in _ELEMENTWISE or op == "convert":
                flops += _type_elems(instr.type_str)
                mem += _type_bytes(instr.type_str) + self._operand_bytes(instr)
                continue
            if op in ("reduce", "reduce-window"):
                flops += sum(_type_elems(self._types.get(o, ""))
                             for o in self._operands(instr)) / 2
                mem += _type_bytes(instr.type_str) + self._operand_bytes(instr)
                continue
            # data movement ops (copy, transpose, broadcast, slice, pad,
            # dynamic-slice, dynamic-update-slice, gather, scatter, reshape,
            # concatenate, sort, rng, ...) — bytes only
            mem += _type_bytes(instr.type_str) + self._operand_bytes(instr)
            if op not in ("copy", "transpose", "broadcast", "slice", "pad",
                          "reshape", "concatenate", "dynamic-slice",
                          "dynamic-update-slice", "gather", "scatter", "sort",
                          "rng", "rng-bit-generator", "map", "select-and-scatter",
                          "copy-start"):
                self.unknown_ops[op] = self.unknown_ops.get(op, 0) + 1

        self._memo[comp] = (flops, mem, coll)
        return self._memo[comp]


def analyze(text: str) -> Dict:
    """Loop-aware per-device totals for the entry computation."""
    hc = HloCost(text)
    flops, mem, coll = hc.total()
    total_ob = sum(c["operand_bytes"] for c in coll.values())
    total_rb = sum(c["result_bytes"] for c in coll.values())
    # wire-bytes model per collective kind (ring algorithms):
    #   all-gather: each device receives the full result;
    #   reduce-scatter: sends the full operand;
    #   all-reduce: RS + AG = 2x the buffer;
    #   all-to-all / permute: buffer-sized exchange.
    wire = 0.0
    for kind, c in coll.items():
        hi = max(c["operand_bytes"], c["result_bytes"])
        if kind == "all-reduce":
            wire += 2 * hi
        elif kind == "all-gather":
            wire += c["result_bytes"]
        elif kind == "reduce-scatter":
            wire += max(c["operand_bytes"], c["result_bytes"])
        else:
            wire += hi
    return {
        "flops": flops,
        "bytes": mem,
        "collectives": {
            "per_op": coll,
            "operand_bytes": total_ob,
            "result_bytes": total_rb,
            "wire_bytes": wire,
        },
        "unknown_ops": hc.unknown_ops,
    }
