import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Flash-kernel roofline projection for dense prefill cells (§Perf iter. 3).

The validated Pallas flash kernel (kernels/flash_attention.py) keeps score
tiles in VMEM; its HBM traffic is fixed by its BlockSpecs (q+out once, k+v
once per q block). Pallas does not lower on this CPU host outside interpret
mode, so the projection recompiles each cell, classifies HLO bytes by loop
depth (computations with trip multiplier > n_layers are the attention
chunk loops — dense archs have no other nested scan), and substitutes the
kernel's contract traffic:

    projected_bytes = measured_bytes - attention_loop_bytes + flash_bytes

Writes results into experiments/flash_projection.json.
"""

import collections
import json
import re

import jax
import numpy as np

from repro.launch.hlo_cost import (HloCost, _BODY_RE, _TO_APPLY_RE, _TRIP_RE,
                                   _type_bytes)

HBM_BW = 819e9


def comp_multipliers(hc: HloCost):
    mult = collections.defaultdict(float)

    def walk(comp, m):
        mult[comp] += m
        for instr in hc.comps.get(comp, []):
            if instr.op == "while":
                trips = 1
                t = _TRIP_RE.search(instr.line)
                if t:
                    trips = int(t.group(1))
                b = _BODY_RE.search(instr.line)
                if b:
                    walk(b.group(1), m * trips)
            elif instr.op == "call":
                c = _TO_APPLY_RE.search(instr.line)
                if c:
                    walk(c.group(1), m)

    walk(hc.entry, 1.0)
    return mult


def loop_depth_bytes(text: str, threshold: float):
    """(total_bytes, bytes inside computations with multiplier > threshold)."""
    hc = HloCost(text)
    mult = comp_multipliers(hc)
    total = deep = 0.0
    skip_ops = {"parameter", "constant", "get-tuple-element", "tuple",
                "bitcast", "while", "call", "after-all"}
    for comp, m in mult.items():
        for instr in hc.comps.get(comp, []):
            if instr.op in skip_ops:
                continue
            b = (_type_bytes(instr.type_str) + hc._operand_bytes(instr)) * m
            total += b
            if m > threshold:
                deep += b
    return total, deep


def project(arch: str, shape: str = "prefill_32k") -> dict:
    from repro.dist.sharding import use_mesh
    from repro.launch.dryrun import build_step
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, input_specs
    from repro.models.config import get_config
    from repro.kernels.flash_attention import hbm_bytes

    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh()
    chips = int(np.prod(list(mesh.shape.values())))
    specs = input_specs(arch, shape, mesh, cfg=cfg)
    fn, order, donate = build_step(cfg, cell)
    with mesh, use_mesh(mesh):
        compiled = jax.jit(fn, donate_argnums=donate).lower(
            *[specs[k] for k in order]).compile()
    text = compiled.as_text()
    n_loop_threshold = cfg.n_layers * 1.5  # below: layer scan; above: chunks
    total, attn = loop_depth_bytes(text, n_loop_threshold)

    # flash contract traffic (global, bf16), all layers
    L = cfg.n_layers + (cfg.n_encoder_layers if cfg.family in ("audio",) else 0)
    flash = L * hbm_bytes(B=cell.batch, Hq=cfg.n_heads, Hkv=cfg.n_kv_heads,
                          Sq=cell.seq, Skv=cell.seq,
                          hd=cfg.resolved_head_dim, dtype_bytes=2, qc=512)
    flash_per_dev = flash / chips

    projected = total - attn + flash_per_dev
    return {
        "arch": arch, "shape": shape,
        "measured_bytes_per_dev": total,
        "attention_loop_bytes_per_dev": attn,
        "flash_bytes_per_dev": flash_per_dev,
        "projected_bytes_per_dev": projected,
        "memory_term_measured_s": total / HBM_BW,
        "memory_term_projected_s": projected / HBM_BW,
        "speedup": total / projected,
    }


def main():
    out = {}
    for arch in ("deepseek-coder-33b", "starcoder2-15b", "yi-6b"):
        r = project(arch)
        out[arch] = r
        print(f"{arch:22} measured={r['memory_term_measured_s']:8.1f}s "
              f"attn_share={r['attention_loop_bytes_per_dev']/r['measured_bytes_per_dev']:.1%} "
              f"projected={r['memory_term_projected_s']:8.1f}s "
              f"({r['speedup']:.1f}x)")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/flash_projection.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
