import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds ShapeDtypeStruct inputs (launch/shapes.py — no allocation),
  2. ``jax.jit(step).lower(...).compile()`` under the production mesh,
  3. records ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
     (FLOPs / bytes), and the collective schedule parsed from the optimized
     HLO (operand bytes of all-gather/all-reduce/reduce-scatter/all-to-all/
     collective-permute), and
  4. derives the three roofline terms (EXPERIMENTS.md §Roofline).

Results are cached per-cell into a JSON file so reruns are incremental.

NOTE: the XLA_FLAGS line above MUST stay the first statement — jax locks the
device count at first init. Only the dry-run sets it; tests/benches see 1 CPU.
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.dist.sharding import use_mesh
from repro.launch.hlo_cost import xla_cost_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.models.config import get_config, list_archs

# -- TPU v5e hardware model (assignment constants) ---------------------------
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

ARCHS = [
    "starcoder2-15b", "yi-6b", "qwen3-0.6b", "deepseek-coder-33b",
    "seamless-m4t-large-v2", "mamba2-780m", "llama4-scout-17b-16e",
    "mixtral-8x7b", "jamba-1.5-large-398b", "paligemma-3b",
]

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device operand/result bytes of every collective in the HLO."""
    per_op: Dict[str, Dict[str, int]] = {}
    total_operand = total_result = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group(1)
        # result type(s): everything left of the '=' is the result name; the
        # type annotation follows '='. operands: types inside the parens.
        lhs, _, rhs = line.partition("=")
        paren = rhs.find("(")
        result_types = _SHAPE_RE.findall(rhs[:paren])
        # operand section ends at the matching close paren — approximate with
        # the full remainder (attribute strings contain no dtype[shape] tokens)
        operand_types = _SHAPE_RE.findall(rhs[paren:rhs.find(")", paren)])
        ob = sum(_shape_bytes(d, s) for d, s in operand_types)
        rb = sum(_shape_bytes(d, s) for d, s in result_types)
        agg = per_op.setdefault(kind, {"count": 0, "operand_bytes": 0,
                                       "result_bytes": 0})
        agg["count"] += 1
        agg["operand_bytes"] += ob
        agg["result_bytes"] += rb
        total_operand += ob
        total_result += rb
    return {"per_op": per_op, "operand_bytes": total_operand,
            "result_bytes": total_result}


_FLOPS_SEMANTICS: Optional[str] = None


def calibrate_flops_semantics(mesh) -> str:
    """Determine whether cost_analysis() reports per-device or global FLOPs
    by lowering a known sharded matmul."""
    global _FLOPS_SEMANTICS
    if _FLOPS_SEMANTICS is not None:
        return _FLOPS_SEMANTICS
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = k = n = 1024
    a = jax.ShapeDtypeStruct((m, k), np.float32,
                             sharding=NamedSharding(mesh, P("data", None)))
    b = jax.ShapeDtypeStruct((k, n), np.float32,
                             sharding=NamedSharding(mesh, P(None, "model")))
    with mesh:
        compiled = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    flops = xla_cost_analysis(compiled).get("flops", 0.0)
    expected_global = 2.0 * m * k * n
    _FLOPS_SEMANTICS = ("per_device" if flops < expected_global / 2
                        else "global")
    return _FLOPS_SEMANTICS


def count_params(cfg) -> int:
    from repro.models import param_shapes
    return int(sum(int(np.prod(s)) for s in param_shapes(cfg).values()))


def count_active_params(cfg) -> int:
    """Per-token active parameters (MoE: top-k + shared experts only)."""
    from repro.models import param_shapes
    total = 0
    for path, shape in param_shapes(cfg).items():
        n = int(np.prod(shape))
        if "/moe/w_" in path and "shared" not in path:
            n = n * cfg.experts_per_token // max(cfg.n_experts, 1)
        total += n
    return total


def model_flops(cfg, cell) -> float:
    """Analytic MODEL_FLOPS for the cell (6·N·D train, 2·N·D fwd-only)."""
    n_active = count_active_params(cfg)
    if cell.kind == "train":
        tokens = cell.batch * cell.seq
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.batch * cell.seq
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.batch  # decode: one token per sequence


def build_step(cfg, cell):
    """(fn, kwargs-order, donate) for the cell kind."""
    if cell.kind == "train":
        from repro.train.step import make_train_step
        fn = make_train_step(cfg, n_microbatches=cell.microbatches)
        return fn, ("state", "batch"), (0,)
    if cell.kind == "prefill":
        from repro.serve.engine import make_prefill_step
        fn = make_prefill_step(cfg, max_len=cell.seq)
        return fn, ("params", "batch"), ()
    from repro.models.model import decode_step
    import functools
    fn = functools.partial(decode_step, cfg)
    return fn, ("params", "token", "cache", "pos"), (2,)


def run_cell(arch: str, shape: str, multi_pod: bool,
             microbatches: Optional[int] = None,
             remat: Optional[str] = None) -> Dict[str, Any]:
    import dataclasses as dc
    cfg = get_config(arch)
    if remat is not None:
        cfg = dc.replace(cfg, remat=remat)
    cell = SHAPES[shape]
    if microbatches is not None:
        cell = dc.replace(cell, microbatches=microbatches)
    ok, why = cell_supported(cfg, cell)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind,
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    semantics = calibrate_flops_semantics(mesh)
    specs = input_specs(arch, shape, mesh, cfg=cfg)
    fn, order, donate = build_step(cfg, cell)
    args = [specs[k] for k in order]

    t0 = time.time()
    with mesh, use_mesh(mesh):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    hlo_text = compiled.as_text()

    # loop-aware per-device accounting (hlo_cost.py) — XLA's cost_analysis
    # counts while bodies once, so it badly undercounts scanned programs;
    # we keep its raw numbers as side data.
    from repro.launch import hlo_cost
    acc = hlo_cost.analyze(hlo_text)
    coll = acc["collectives"]

    flops = float(acc["flops"])
    bytes_accessed = float(acc["bytes"])
    flops_global = flops * chips
    bytes_global = bytes_accessed * chips
    coll_global_operand = coll["operand_bytes"] * chips
    coll_global_result = coll["result_bytes"] * chips

    # roofline terms (seconds) — spec formulas over GLOBAL quantities
    t_compute = flops_global / (chips * PEAK_FLOPS)
    t_memory = bytes_global / (chips * HBM_BW)
    t_collective = coll_global_operand / (chips * LINK_BW)
    t_coll_wire = coll["wire_bytes"] / LINK_BW  # per-device wire model
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, cell)
    result.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops_per_device=flops,
        bytes_per_device=bytes_accessed,
        collective={**coll, "global_operand_bytes": coll_global_operand,
                    "global_result_bytes": coll_global_result},
        memory_analysis={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes_estimate": (
                (getattr(mem, "argument_size_in_bytes", 0) or 0)
                + (getattr(mem, "output_size_in_bytes", 0) or 0)
                + (getattr(mem, "temp_size_in_bytes", 0) or 0)
                - (getattr(mem, "alias_size_in_bytes", 0) or 0)),
        },
        roofline={
            **{k: float(v) for k, v in terms.items()},
            "collective_wire": float(t_coll_wire),
            "dominant": dominant,
            "bound_s": float(max(terms.values())),
        },
        model_flops=mf,
        hlo_flops_global=flops_global,
        useful_flops_ratio=(mf / flops_global if flops_global else None),
        params=count_params(cfg),
        active_params=count_active_params(cfg),
        flops_semantics=semantics,
        xla_cost_analysis={"flops": float(cost.get("flops", 0.0)),
                           "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                           "note": "loop bodies counted once by XLA"},
        unknown_ops=acc.get("unknown_ops", {}),
    )
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", default=None)
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict[str, Any] = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            for multi in pods:
                key = f"{arch}|{shape}|{'multi' if multi else 'single'}"
                if key in results and results[key].get("status") in ("ok", "skipped") \
                        and args.microbatches is None and args.remat is None:
                    print(f"[cached] {key}", flush=True)
                    continue
                print(f"[run]    {key}", flush=True)
                try:
                    r = run_cell(arch, shape, multi,
                                 microbatches=args.microbatches,
                                 remat=args.remat)
                except Exception as e:  # record the failure, keep sweeping
                    r = {"arch": arch, "shape": shape,
                         "mesh": "2x16x16" if multi else "16x16",
                         "status": "error", "error": f"{type(e).__name__}: {e}",
                         "traceback": traceback.format_exc()[-2000:]}
                results[key] = r
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)
                status = r.get("status")
                extra = ""
                if status == "ok":
                    rt = r["roofline"]
                    extra = (f" dominant={rt['dominant']}"
                             f" bound={rt['bound_s']*1e3:.1f}ms"
                             f" compile={r['compile_s']:.0f}s")
                elif status == "error":
                    extra = " " + r["error"][:120]
                print(f"  -> {status}{extra}", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
