"""Production mesh definitions (multi-pod dry-run spec).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device count locks on first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs through the same code path."""
    return jax.make_mesh((1, 1), ("data", "model"))
