"""Deterministic kill-points for fault-injection tests (DESIGN.md §16.5).

A kill-point is a named seam in production code — ``kill_point("hub.publish.pre_replace")``
— that is a no-op unless a test (or the ``MGIT_KILLPOINTS`` env var) arms it.
Armed points count down a hit budget and then *fire*: raise
:class:`KillPointError` (simulating a crash at exactly that seam), or invoke
a registered callback (letting a test interleave a competing operation at a
precise point instead of hand-rolling thread races).

Design constraints:

* **Near-zero overhead when disarmed.** The hot-path check is one read of a
  module-level flag; the registry lock is only taken once a point is armed.
* **Deterministic.** Points fire on the Nth hit (``after`` hits are skipped
  first), not on a timer or scheduler race.
* **Cross-process.** ``MGIT_KILLPOINTS=name[:after][,name2[:after2]]`` arms
  points in a subprocess (e.g. a hub spawned by a CLI test) without any
  in-process handle. Env-armed points always raise; callbacks are
  in-process only.

Seams currently instrumented (grep for ``kill_point(`` to audit):

* ``hub.publish.pre_replace`` / ``hub.publish.post_replace`` — either side
  of the lineage document's atomic ``os.replace`` commit point;
* ``hub.mget.record`` — between streamed mget pack records;
* ``cas.gc.pre_reclaim`` — after GC picks its dead set, before reclaim;
* ``hub.gc.pre_zero`` — after hub maintenance confirms orphans, before
  zeroing refcounts;
* ``replica.sync.pre_publish`` — between a replica's object fetch and its
  local lineage publish.
"""

from __future__ import annotations

import os
import threading
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["KillPointError", "kill_point", "arm", "disarm", "disarm_all",
           "fired", "armed"]


class KillPointError(RuntimeError):
    """Raised when an armed kill-point fires in raise mode.

    Subclasses RuntimeError so production ``except Exception`` cleanup still
    runs, but tests can catch it precisely."""

    def __init__(self, name: str) -> None:
        super().__init__(f"kill-point fired: {name}")
        self.name = name


# any_armed is the only thing the hot path reads while disarmed; it is a
# plain bool write-protected by _lock (benign race: a point armed
# concurrently with a hit may miss that hit — tests arm before acting).
_any_armed = False
_lock = threading.Lock()
#: name -> [remaining_skips, budget, callback|None]
_points: Dict[str, List] = {}
_fired: Dict[str, int] = {}


def _load_env() -> None:
    spec = os.environ.get("MGIT_KILLPOINTS", "")
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        name, _, after = item.partition(":")
        arm(name, after=int(after) if after else 0)


def arm(name: str, after: int = 0, count: int = 1,
        callback: Optional[Callable[[], None]] = None) -> None:
    """Arm ``name``: skip ``after`` hits, then fire on the next ``count``
    hits. With no ``callback`` a hit raises :class:`KillPointError`;
    with one, the callback runs in the hitting thread instead."""
    global _any_armed
    with _lock:
        _points[name] = [int(after), int(count), callback]
        _any_armed = True


def disarm(name: str) -> None:
    global _any_armed
    with _lock:
        _points.pop(name, None)
        _any_armed = bool(_points)


def disarm_all() -> None:
    global _any_armed
    with _lock:
        _points.clear()
        _fired.clear()
        _any_armed = False


def fired(name: str) -> int:
    """How many times ``name`` has fired since the last :func:`disarm_all`."""
    with _lock:
        return _fired.get(name, 0)


def armed(name: str) -> bool:
    with _lock:
        return name in _points


def kill_point(name: str) -> None:
    """Production-code seam. No-op unless ``name`` is armed."""
    global _any_armed
    if not _any_armed:
        return
    with _lock:
        state = _points.get(name)
        if state is None:
            return
        if state[0] > 0:          # still skipping
            state[0] -= 1
            return
        state[1] -= 1
        if state[1] <= 0:
            _points.pop(name)
            _any_armed = bool(_points)
        _fired[name] = _fired.get(name, 0) + 1
        cb = state[2]
    if cb is None:
        raise KillPointError(name)
    cb()


_load_env()
