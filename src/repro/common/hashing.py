"""Content hashing for parameter tensors.

Durable keys are SHA-256 over (raw bytes, shape, dtype) — exactly the paper's
content-based hashing scheme (§4). The TPU-side fast path (polynomial
fingerprint, see ``repro.kernels.fingerprint``) only *nominates* duplicate
candidates; this module is the source of truth.
"""

from __future__ import annotations

import hashlib

import numpy as np


def tensor_hash(x) -> str:
    """SHA-256 content hash of a tensor (value + shape + dtype)."""
    arr = np.asarray(x)
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


class TensorHasher:
    """Incremental :func:`tensor_hash` over a tensor's raw bytes.

    Feeding the contiguous byte stream chunk-by-chunk yields the SAME digest
    as ``tensor_hash`` over the materialized array — the hash runs over
    ``str(shape) + str(dtype) + raw bytes``, none of which needs the whole
    tensor in memory. This is what lets the chunked commit/checkout engine
    derive and verify content identity of multi-GB tensors under a bounded
    window (DESIGN.md §12)."""

    def __init__(self, shape, dtype) -> None:
        self._h = hashlib.sha256()
        self._h.update(str(tuple(int(d) for d in shape)).encode())
        self._h.update(str(np.dtype(dtype)).encode())

    def update(self, data) -> None:
        self._h.update(data)

    def hexdigest(self) -> str:
        return self._h.hexdigest()


def bytes_hash(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()
