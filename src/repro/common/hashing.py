"""Content hashing for parameter tensors.

Durable keys are SHA-256 over (raw bytes, shape, dtype) — exactly the paper's
content-based hashing scheme (§4). The TPU-side fast path (polynomial
fingerprint, see ``repro.kernels.fingerprint``) only *nominates* duplicate
candidates; this module is the source of truth.
"""

from __future__ import annotations

import hashlib

import numpy as np


def tensor_hash(x) -> str:
    """SHA-256 content hash of a tensor (value + shape + dtype)."""
    arr = np.asarray(x)
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def bytes_hash(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()
