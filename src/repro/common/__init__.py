from repro.common.hashing import bytes_hash, tensor_hash

__all__ = ["bytes_hash", "tensor_hash"]
