"""Synthetic data pipeline: deterministic, shardable, resumable.

Batches are generated per-step from a counter-based RNG (seed ^ step), so the
pipeline is stateless — resuming from checkpoint step N reproduces the exact
stream with no saved iterator state, and every host generates only its own
shard (addressable-shard generation under a mesh). Modality frontends are
stubs per the assignment: audio/vision inputs are precomputed frame/patch
embeddings.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.dist.sharding import batch_spec
from repro.models.config import ModelConfig


class SyntheticPipeline:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 mesh: Optional[Any] = None, seed: int = 1234,
                 start_step: int = 0) -> None:
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.mesh = mesh
        self.seed = seed
        self.step = start_step

    # -- deterministic per-step generation ------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed << 20) ^ step)

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        out: Dict[str, np.ndarray] = {}
        if cfg.family in ("encdec", "audio"):
            out["frames"] = rng.standard_normal(
                (self.batch, self.seq, cfg.d_model), dtype=np.float32)
            dec_len = min(self.seq, 4096)
            out["tokens"] = rng.integers(
                0, cfg.vocab_size, (self.batch, dec_len), dtype=np.int32)
        elif cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (self.batch, cfg.n_prefix_tokens, cfg.d_model), dtype=np.float32)
            out["tokens"] = rng.integers(
                0, cfg.vocab_size, (self.batch, self.seq - cfg.n_prefix_tokens),
                dtype=np.int32)
        else:
            out["tokens"] = rng.integers(
                0, cfg.vocab_size, (self.batch, self.seq), dtype=np.int32)
        return out

    def _place(self, batch: Dict[str, np.ndarray]) -> Dict[str, Any]:
        if self.mesh is None:
            return batch
        placed = {}
        for k, v in batch.items():
            trailing = (None,) * (v.ndim - 1)
            placed[k] = jax.device_put(v, batch_spec(self.mesh, *trailing))
        return placed

    # -- iterator protocol ------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        b = self._place(self.host_batch(self.step))
        self.step += 1
        return b

    # -- resumability -------------------------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: Dict[str, int]) -> None:
        self.step = state["step"]
        self.seed = state["seed"]
