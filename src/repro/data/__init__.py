from repro.data.pipeline import SyntheticPipeline

__all__ = ["SyntheticPipeline"]
