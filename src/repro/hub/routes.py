"""HTTP surface of the hub daemon (DESIGN.md §11.2, §16).

A thin, dependency-free codec over :class:`~repro.hub.app.HubApp` /
:class:`~repro.hub.app.HubService`, built on a bounded worker-pool
subclass of stdlib ``http.server.ThreadingHTTPServer``: up to
``max_workers`` connections are serviced concurrently, ``queue_depth``
more may wait, and anything beyond is shed with ``503 Retry-After``
(§16.4) — saturation is explicit backpressure, never unbounded threads.

Every endpoint also exists repo-scoped as ``/r/<repo>/api/...`` (the
remote-URL form — point a client at ``http://hub/r/<repo>``) or
``/api/r/<repo>/...``; unscoped paths serve the ``default`` repo.

Endpoints (all JSON unless noted; see the §11.2/§16.1 protocol tables):

    GET    /api/ping                 liveness (unauthenticated)
    GET    /api/lineage              document + ``ETag`` header; 404 if none
    PUT    /api/lineage              conditional on ``If-Match`` -> 200/409
    POST   /api/have                 {"keys": [...]} -> {"have": [...]}
    GET    /api/objects/<key>        raw object; honors ``Range`` (206)
    POST   /api/objects/mget         {"keys": [...]} -> pack record stream
    POST   /api/objects/sizes        {"keys": [...]} -> {"sizes", "missing"}
    POST   /api/objects              pack record stream -> {"imported", ...}
    POST   /api/finalize             refcount rebuild (union roots, §16.1)
    GET    /api/journal[/<tid>]      transfer journal list / entry
    PUT    /api/journal/<tid>        persist a journal entry
    DELETE /api/journal/<tid>        retire a journal entry
    GET    /api/stats                live counters + per-route p50/p99
    GET    /api/metrics              Prometheus text exposition (DESIGN §14)
    GET    /api/fsck                 integrity report (service-wide, §16.1)
    GET    /api/repos                tenant list with lineage etags
    DELETE /r/<repo>/api/repo        drop a tenant (objects become orphans)
    POST   /api/gc                   one maintenance GC cycle (§16.3)
    POST   /api/compact              aggressive pack compaction
    POST   /api/replica/sync         pull-from-primary sync (replicas only)

Object payloads stream zero-copy: single-object GETs and mget streams write
``memoryview`` slices of the CAS's pooled mmaps straight to the socket,
with an exact ``Content-Length`` precomputed from O(1) size lookups — the
hub never holds a full transfer in memory. JSON bodies accept and JSON
responses offer gzip content-encoding above a small floor; object bytes are
LZMA/npy payloads already and are never recompressed.
"""

from __future__ import annotations

import concurrent.futures as cf
import gzip
import json
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple, Union
from urllib.parse import unquote, urlsplit

from repro.common.faults import kill_point
from repro.hub.app import HubApp, HubService, ReadOnlyRepo
from repro.obs import span
from repro.remote.http import GZIP_FLOOR, WIRE_REC_HEAD, iter_records
from repro.remote.transport import ETAG_ABSENT, PublishConflict

_RANGE_RE = re.compile(r"bytes=(\d+)-(\d*)$")

# Fixed-path routes the latency histogram may label with — dynamic path
# tails collapse to :key/:tid and anything else to "other", so a scanner
# walking random URLs cannot grow unbounded label cardinality.
_FIXED_ROUTES = frozenset({
    "/api/ping", "/api/lineage", "/api/have", "/api/objects/mget",
    "/api/objects/sizes", "/api/objects", "/api/finalize", "/api/journal",
    "/api/stats", "/api/metrics", "/api/fsck", "/api/repos", "/api/repo",
    "/api/gc", "/api/compact", "/api/replica/sync"})

# (method, route_family) pairs that change hub state — rejected with 403 on
# a read-only replica, and the set the saturation counters key off.
_MUTATING = frozenset({
    ("PUT", "/api/lineage"), ("POST", "/api/objects"),
    ("POST", "/api/finalize"), ("PUT", "/api/journal/:tid"),
    ("DELETE", "/api/journal/:tid"), ("DELETE", "/api/repo"),
    ("POST", "/api/gc"), ("POST", "/api/compact")})


def split_repo(path: str) -> Tuple[str, Optional[str]]:
    """``(api_path, repo_name)`` for a possibly repo-scoped path (§16.1).

    Two equivalent spellings route to the same tenant:

    * ``/r/<repo>/api/...`` — the remote-URL form: a client configured
      with ``http://hub/r/<repo>`` needs zero transport changes, its URL
      prefix lands every request here;
    * ``/api/r/<repo>/...`` — the API-first form from the protocol table.

    Unscoped paths return ``(path, None)`` and route to the default repo."""
    if path.startswith("/api/r/"):
        name, _, tail = path[len("/api/r/"):].partition("/")
        return ("/api/" + tail if tail else "/api"), name
    if path.startswith("/r/"):
        name, _, tail = path[len("/r/"):].partition("/")
        return ("/" + tail if tail else "/"), name
    return path, None


def route_family(path: str) -> str:
    """Collapse a request path to its bounded-cardinality route label.

    Repo-scoped paths collapse to the same family as their unscoped form —
    the repo name is unbounded and must not become a label."""
    path, _ = split_repo(path)
    if (path.startswith("/api/objects/")
            and path not in ("/api/objects/mget", "/api/objects/sizes")):
        return "/api/objects/:key"
    if path.startswith("/api/journal/"):
        return "/api/journal/:tid"
    return path if path in _FIXED_ROUTES else "other"

# CAS keys and journal ids are hash-derived tokens; anything else in the
# path tail is hostile (os.path.join would resolve '../' segments OUTSIDE
# the served repository — remote file read/write). Dot-only names are
# excluded too ('.'/'..' are directories even without a separator).
_SAFE_ID_RE = re.compile(r"^[A-Za-z0-9._-]+$")


def _safe_id(s: str) -> bool:
    return bool(_SAFE_ID_RE.match(s)) and set(s) != {"."}


class _RangeNotSatisfiable(Exception):
    """Range start at/after EOF — HTTP 416, not a malformed request."""


class HubRequestHandler(BaseHTTPRequestHandler):
    server_version = "mgit-hub/1.0"
    protocol_version = "HTTP/1.1"

    # -- plumbing ------------------------------------------------------------
    @property
    def app(self) -> HubApp:
        # set per-request by _route once the repo scope is resolved; error
        # paths that fire earlier (auth, bad repo name) count against the
        # default repo's stats
        resolved = getattr(self, "_app", None)
        return resolved or self.server.app  # type: ignore[attr-defined]

    def log_message(self, format: str, *args: Any) -> None:
        pass  # request metrics live in app.stats, not stderr

    def _gzip_ok(self) -> bool:
        return "gzip" in (self.headers.get("Accept-Encoding") or "")

    def _read_body(self) -> bytes:
        length = int(self.headers.get("Content-Length") or 0)
        data = self.rfile.read(length) if length else b""
        self.app.count(bytes_in=len(data))
        if self.headers.get("Content-Encoding") == "gzip":
            data = gzip.decompress(data)
        return data

    def _read_json(self) -> Dict:
        body = self._read_body()
        return json.loads(body) if body else {}

    def _send_json(self, obj: Any, status: int = 200,
                   headers: Optional[Dict[str, str]] = None) -> None:
        body = json.dumps(obj).encode()
        hdrs = dict(headers or {})
        if self._gzip_ok() and len(body) > GZIP_FLOOR:
            body = gzip.compress(body, 5)
            hdrs["Content-Encoding"] = "gzip"
        if status >= 400:
            # error paths may not have drained the request body (401 fires
            # before _read_body); leftover bytes on a keep-alive socket
            # would be parsed as the next request line — close instead
            self.close_connection = True
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        for k, v in hdrs.items():
            self.send_header(k, v)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        self.app.count(bytes_out=len(body))

    # -- auth ----------------------------------------------------------------
    def _authorized(self, path: str) -> bool:
        if path == "/api/ping":
            return True  # health probes run without credentials
        if self.app.auth.check(self.headers.get("Authorization")):
            return True
        self.app.count(auth_failures=1)
        self._send_json({"error": "unauthorized"}, status=401,
                        headers={"WWW-Authenticate": "Bearer"})
        return False

    # -- dispatch ------------------------------------------------------------
    def _write_body(self, view) -> None:
        """Body write with an optional per-connection bandwidth cap.

        ``HubServer.throttle_bps`` (benchmarks/tests only) emulates the
        per-TCP-stream throughput limit of a real network path — the
        property that makes parallel ranged connections aggregate
        bandwidth. Zero (the default) writes straight through.
        """
        bps = self.server.throttle_bps  # type: ignore[attr-defined]
        if not bps:
            self.wfile.write(view)
            return
        step = 256 * 1024
        mv = memoryview(view)
        for i in range(0, len(mv), step):
            piece = mv[i:i + step]
            self.wfile.write(piece)
            time.sleep(len(piece) / bps)

    def _route(self, method: str) -> None:
        raw = unquote(urlsplit(self.path).path).rstrip("/") or "/"
        path, repo = split_repo(raw)
        self._app = None  # default repo until the scope resolves
        self.app.count(requests=1)
        if self.server.delay_s:  # type: ignore[attr-defined]
            # simulated per-request RTT (benchmarks/tests only): loopback
            # has none, so this is how WAN behavior is exercised locally
            time.sleep(self.server.delay_s)  # type: ignore[attr-defined]
        if not self._authorized(path):
            return
        if repo is not None:
            # resolution AFTER auth: tenant dirs are only ever created by
            # authorized requests, never by an unauthenticated scanner
            service = self.server.service  # type: ignore[attr-defined]
            if service is None:
                self._send_json({"error": "not a multi-tenant hub"},
                                status=404)
                return
            if not _safe_id(repo):
                self._send_json({"error": "bad repo name"}, status=404)
                return
            app = service.repo(repo, create=not service.read_only)
            if app is None:
                self._send_json({"error": f"no repo {repo!r}"}, status=404)
                return
            self._app = app
        route = route_family(path)
        if self.app.read_only and (method, route) in _MUTATING:
            self._send_json({"error": "read-only replica"}, status=403)
            return
        t0 = time.perf_counter()
        try:
            with span("hub.request", cat="hub", method=method, route=route):
                handler = self._resolve(method, path)
                if handler is None:
                    self._send_json({"error": f"no route {method} {path}"},
                                    status=404)
                    return
                handler()
        except PublishConflict as exc:
            self._send_json({"error": "lineage moved",
                             "etag": exc.current_etag}, status=409)
        except ReadOnlyRepo as exc:
            self._send_json({"error": str(exc)}, status=403)
        except (ValueError, KeyError, json.JSONDecodeError) as exc:
            self._send_json({"error": str(exc)}, status=400)
        except ConnectionError:
            raise  # client went away mid-response; nothing to send
        except Exception as exc:  # noqa: BLE001 — daemon must not die
            self.app.count(errors_500=1)
            self._send_json({"error": f"internal: {exc}"}, status=500)
        finally:
            self.app.observe_request(method, route,
                                     time.perf_counter() - t0)

    def _resolve(self, method: str, path: str):
        if (path.startswith("/api/objects/")
                and path not in ("/api/objects/mget", "/api/objects/sizes")):
            key = path[len("/api/objects/"):]
            if not _safe_id(key):
                return None  # 404s — never reaches a filesystem join
            if method == "GET":
                return lambda: self._get_object(key)
            return None
        if path.startswith("/api/journal/"):
            tid = path[len("/api/journal/"):]
            if not _safe_id(tid):
                return None
            return {"GET": lambda: self._journal_get(tid),
                    "PUT": lambda: self._journal_put(tid),
                    "DELETE": lambda: self._journal_delete(tid),
                    }.get(method)
        table = {
            ("GET", "/api/ping"): self._ping,
            ("GET", "/api/lineage"): self._get_lineage,
            ("PUT", "/api/lineage"): self._put_lineage,
            ("POST", "/api/have"): self._have,
            ("POST", "/api/objects/mget"): self._mget,
            ("POST", "/api/objects/sizes"): self._sizes,
            ("POST", "/api/objects"): self._put_objects,
            ("POST", "/api/finalize"): self._finalize,
            ("GET", "/api/journal"): self._journal_list,
            ("GET", "/api/stats"): self._stats,
            ("GET", "/api/metrics"): self._metrics,
            ("GET", "/api/fsck"): self._fsck,
            ("GET", "/api/repos"): self._list_repos,
            ("DELETE", "/api/repo"): self._delete_repo,
            ("POST", "/api/gc"): self._run_gc,
            ("POST", "/api/compact"): self._run_compact,
            ("POST", "/api/replica/sync"): self._replica_sync,
        }
        return table.get((method, path))

    def do_GET(self) -> None:
        self._route("GET")

    def do_POST(self) -> None:
        self._route("POST")

    def do_PUT(self) -> None:
        self._route("PUT")

    def do_DELETE(self) -> None:
        self._route("DELETE")

    # -- routes --------------------------------------------------------------
    def _ping(self) -> None:
        self._send_json({"ok": True, "service": "mgit-hub",
                         "auth": self.app.auth.enabled})

    def _get_lineage(self) -> None:
        payload, etag = self.app.lineage()
        if payload is None:
            self._send_json({"error": "no lineage published"}, status=404,
                            headers={"ETag": etag})
            return
        self._send_json(payload, headers={"ETag": etag})

    def _put_lineage(self) -> None:
        expected = self.headers.get("If-Match")
        payload = self._read_json()
        result = self.app.publish(payload, expected=expected)
        self._send_json(result, headers={"ETag": result["etag"]})

    def _have(self) -> None:
        keys = self._read_json().get("keys", [])
        self._send_json({"have": self.app.have(keys)})

    def _parse_range(self, size: int) -> Optional[Tuple[int, int]]:
        """``(start, length)`` from a single-range header, or None."""
        header = self.headers.get("Range")
        if not header:
            return None
        m = _RANGE_RE.match(header.strip())
        if not m:
            raise ValueError(f"unsupported Range {header!r}")
        start = int(m.group(1))
        end = int(m.group(2)) if m.group(2) else size - 1
        if start >= size or end < start:
            # 416, not 400: a resume positioned exactly at EOF is a healthy
            # "nothing left to fetch", not a malformed request
            raise _RangeNotSatisfiable(size)
        return start, min(end, size - 1) - start + 1

    def _get_object(self, key: str) -> None:
        # reader lease (§16.2): a concurrent gc defers physical reclaim
        # until this response is fully written, so the view below can never
        # dangle even if the key dies mid-transfer
        with self.app.store.cas.pin():
            try:
                view = self.app.store.cas.get_view(key)
            except KeyError:
                self._send_json({"error": f"no object {key!r}"}, status=404)
                return
            size = len(view)
            try:
                rng = self._parse_range(size)
            except _RangeNotSatisfiable:
                self._send_json({"error": "range not satisfiable",
                                 "size": size}, status=416,
                                headers={"Content-Range": f"bytes */{size}"})
                return
            if rng is None:
                start, length, status = 0, size, 200
            else:
                (start, length), status = rng, 206
            self.send_response(status)
            self.send_header("Content-Type", "application/octet-stream")
            self.send_header("Accept-Ranges", "bytes")
            if status == 206:
                self.send_header("Content-Range",
                                 f"bytes {start}-{start + length - 1}/{size}")
            self.send_header("Content-Length", str(length))
            self.end_headers()
            self._write_body(view[start:start + length])  # zero-copy off mmap
        self.app.count(bytes_out=length, objects_served=1)

    def _mget(self) -> None:
        keys = self._read_json().get("keys", [])
        # the lease covers preflight THROUGH stream end: sizes resolved here
        # stay valid against concurrent gc/compaction for the whole response
        with self.app.store.cas.pin():
            sizes, missing = self.app.object_sizes(keys)
            if missing:
                self._send_json({"error": "missing objects",
                                 "missing": missing[:32]}, status=404)
                return
            total = sum(WIRE_REC_HEAD.size + len(k.encode()) + n
                        for k, n in sizes.items())
            self.send_response(200)
            self.send_header("Content-Type", "application/x-mgit-pack")
            self.send_header("Content-Length", str(total))
            self.end_headers()
            try:
                for key, view in self.app.iter_object_views(list(sizes)):
                    kill_point("hub.mget.record")
                    if len(view) != sizes[key]:
                        raise ValueError(f"object {key!r} changed size "
                                         "mid-stream")
                    kb = key.encode()
                    self.wfile.write(WIRE_REC_HEAD.pack(len(kb), len(view)))
                    self.wfile.write(kb)
                    self._write_body(view)  # zero-copy off the pooled mmap
            except ConnectionError:
                raise
            except Exception:
                # Headers + a Content-Length already went out: a concurrent
                # ledger overwrite (or an injected fault) invalidated the
                # preflight. Splicing a JSON error into the declared body
                # would corrupt the stream — abort the connection instead;
                # the client sees a short read and retries through its
                # backoff path.
                self.close_connection = True
                return
        self.app.count(bytes_out=total, objects_served=len(sizes))

    def _sizes(self) -> None:
        # Size preflight for the pull planner: objects above the ranged-read
        # floor (chunked tensors' ``c_`` payloads) get segmented parallel
        # GETs instead of riding the single mget stream. Missing keys are
        # reported, not an error — the planner mgets whatever remains.
        keys = self._read_json().get("keys", [])
        sizes, missing = self.app.object_sizes(keys)
        self._send_json({"sizes": sizes, "missing": missing})

    def _put_objects(self) -> None:
        body = self._read_body()
        objects = dict(iter_records(body))
        written = self.app.import_objects(objects)
        self._send_json({"imported": len(objects), "bytes_written": written})

    def _finalize(self) -> None:
        self._read_body()  # client-side roots are advisory; drain + ignore
        self._send_json({"refcounts": self.app.finalize()})

    def _journal_get(self, tid: str) -> None:
        payload = self.app.journal.journal_load(tid)
        if payload is None:
            self._send_json({"error": f"no journal {tid}"}, status=404)
        else:
            self._send_json(payload)

    def _journal_put(self, tid: str) -> None:
        self.app.journal.journal_write(tid, self._read_json())
        self._send_json({"ok": True})

    def _journal_delete(self, tid: str) -> None:
        self.app.journal.journal_clear(tid)
        self._send_json({"ok": True})

    def _journal_list(self) -> None:
        self._send_json({"transfers": list(self.app.journal.journal_list())})

    def _stats(self) -> None:
        self._send_json(self.app.stats_json())

    def _metrics(self) -> None:
        # Prometheus text, NOT json — scrapers parse the exposition format
        body = self.app.metrics_text().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        self.app.count(bytes_out=len(body))

    def _fsck(self) -> None:
        self._send_json(self.app.fsck())

    # -- multi-tenant / maintenance routes (§16) ------------------------------
    def _service(self) -> Optional[HubService]:
        return self.server.service  # type: ignore[attr-defined]

    def _list_repos(self) -> None:
        service = self._service()
        if service is None:
            _, etag = self.app.lineage()
            self._send_json({"repos": [{"name": "default", "etag": etag}]})
            return
        out = []
        for name in service.repo_names():
            app = service.repo(name, create=False)
            if app is None:
                continue
            _, etag = app.lineage()
            out.append({"name": name, "etag": etag})
        self._send_json({"repos": out})

    def _delete_repo(self) -> None:
        service = self._service()
        if service is None:
            self._send_json({"error": "not a multi-tenant hub"}, status=404)
            return
        name = self.app.name
        if not service.delete_repo(name):
            self._send_json({"error": f"cannot delete repo {name!r}"},
                            status=400)
            return
        self._send_json({"deleted": name})

    def _run_gc(self) -> None:
        service = self._service()
        if service is None:
            self._send_json({"error": "not a multi-tenant hub"}, status=404)
            return
        body = self._read_json()
        confirm = int(body.get("confirm_cycles", 2))
        grace = int(body.get("grace", 1))
        self._send_json(service.run_gc(confirm_cycles=confirm, grace=grace))

    def _run_compact(self) -> None:
        service = self._service()
        if service is None:
            self._send_json({"error": "not a multi-tenant hub"}, status=404)
            return
        self._read_body()
        self._send_json(service.compact())

    def _replica_sync(self) -> None:
        replica = getattr(self.server, "replica", None)
        if replica is None:
            self._send_json({"error": "not a replica"}, status=404)
            return
        self._send_json(replica.sync_once())


#: default bounded-pool size; 0 restores the unbounded thread-per-request
#: behavior of the PR-5 server
DEFAULT_MAX_WORKERS = 32
#: connections allowed to queue for a worker beyond the pool size before
#: the acceptor sheds with 503
DEFAULT_QUEUE_DEPTH = 64
_SHED_BODY = b'{"error": "saturated", "retry": true}'
_SHED_RESPONSE = (b"HTTP/1.1 503 Service Unavailable\r\n"
                  b"Content-Type: application/json\r\n"
                  b"Retry-After: 1\r\n"
                  b"Connection: close\r\n"
                  b"Content-Length: " + str(len(_SHED_BODY)).encode()
                  + b"\r\n\r\n" + _SHED_BODY)


class HubServer(ThreadingHTTPServer):
    """Bounded worker-pool HTTP server for one :class:`HubApp` or a whole
    :class:`HubService` (§16.4).

    Connections are handled on a fixed-size pool instead of one OS thread
    each; up to ``queue_depth`` connections may wait for a worker, and
    beyond that the acceptor writes a minimal ``503 Retry-After: 1``
    straight to the socket and closes — saturation degrades into explicit,
    retryable backpressure instead of unbounded thread growth. The
    transport's existing retry/backoff path treats the 503 like any other
    server-side retryable failure."""

    daemon_threads = True
    allow_reuse_address = True
    delay_s = 0.0        # per-request simulated RTT; see _route
    throttle_bps = 0     # per-connection bandwidth cap; see _write_body

    def __init__(self, app: Union[HubApp, HubService],
                 host: str = "127.0.0.1", port: int = 0,
                 max_workers: int = DEFAULT_MAX_WORKERS,
                 queue_depth: int = DEFAULT_QUEUE_DEPTH) -> None:
        if isinstance(app, HubService):
            self.service: Optional[HubService] = app
            self.app = app.default
        else:
            self.service = None
            self.app = app
        self.replica = None  # set by repro.hub.replica.serve_replica
        self.max_workers = int(max_workers)
        self.queue_depth = int(queue_depth)
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        self._slots: Optional[threading.Semaphore] = None
        if self.max_workers > 0:
            self._pool = cf.ThreadPoolExecutor(
                max_workers=self.max_workers,
                thread_name_prefix="mgit-hub-worker")
            self._slots = threading.Semaphore(
                self.max_workers + self.queue_depth)
        super().__init__((host, port), HubRequestHandler)

    # -- bounded-pool connection handling ------------------------------------
    def process_request(self, request, client_address) -> None:
        if self._pool is None:  # unbounded compat mode
            super().process_request(request, client_address)
            return
        if not self._slots.acquire(blocking=False):
            self._shed(request)
            return
        try:
            self._pool.submit(self._work, request, client_address)
        except RuntimeError:  # pool shut down while accepting
            self._slots.release()
            self.shutdown_request(request)

    def _work(self, request, client_address) -> None:
        try:
            self.finish_request(request, client_address)
        except Exception:  # noqa: BLE001 — worker must return to the pool
            self.handle_error(request, client_address)
        finally:
            self.shutdown_request(request)
            self._slots.release()

    def _shed(self, request) -> None:
        """Overload response written by the acceptor thread: cheap enough
        that a saturated hub still answers every connection, with close
        semantics so no shed socket lingers in keep-alive."""
        try:
            request.sendall(_SHED_RESPONSE)
        except OSError:
            pass
        finally:
            self.shutdown_request(request)
        self.app.count(sheds_503=1)

    def server_close(self) -> None:
        super().server_close()
        if self._pool is not None:
            self._pool.shutdown(wait=False)

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"


def make_server(app: Union[HubApp, HubService], host: str = "127.0.0.1",
                port: int = 0, max_workers: int = DEFAULT_MAX_WORKERS,
                queue_depth: int = DEFAULT_QUEUE_DEPTH) -> HubServer:
    """Bind (port 0 picks an ephemeral one) without starting the loop —
    tests and the CLI both drive ``serve_forever`` themselves."""
    return HubServer(app, host=host, port=port, max_workers=max_workers,
                     queue_depth=queue_depth)


def start_in_thread(app: Union[HubApp, HubService], host: str = "127.0.0.1",
                    port: int = 0, max_workers: int = DEFAULT_MAX_WORKERS,
                    queue_depth: int = DEFAULT_QUEUE_DEPTH
                    ) -> Tuple[HubServer, threading.Thread]:
    """Serve on a daemon thread; returns the bound server (``server.url``)."""
    server = make_server(app, host=host, port=port, max_workers=max_workers,
                         queue_depth=queue_depth)
    thread = threading.Thread(target=server.serve_forever,
                              name="mgit-hub", daemon=True)
    thread.start()
    return server, thread
