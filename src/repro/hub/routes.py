"""HTTP surface of the hub daemon (DESIGN.md §11.2).

A thin, dependency-free codec over :class:`~repro.hub.app.HubApp` built on
stdlib ``http.server.ThreadingHTTPServer`` — one OS thread per in-flight
request, which is exactly the shape the app's locking was designed for
(parallel object I/O, serialized lineage swap).

Endpoints (all JSON unless noted; see the §11.2 protocol table):

    GET    /api/ping                 liveness (unauthenticated)
    GET    /api/lineage              document + ``ETag`` header; 404 if none
    PUT    /api/lineage              conditional on ``If-Match`` -> 200/409
    POST   /api/have                 {"keys": [...]} -> {"have": [...]}
    GET    /api/objects/<key>        raw object; honors ``Range`` (206)
    POST   /api/objects/mget         {"keys": [...]} -> pack record stream
    POST   /api/objects/sizes        {"keys": [...]} -> {"sizes", "missing"}
    POST   /api/objects              pack record stream -> {"imported", ...}
    POST   /api/finalize             refcount rebuild from current document
    GET    /api/journal[/<tid>]      transfer journal list / entry
    PUT    /api/journal/<tid>        persist a journal entry
    DELETE /api/journal/<tid>        retire a journal entry
    GET    /api/stats                live counters + per-route p50/p99
    GET    /api/metrics              Prometheus text exposition (DESIGN §14)
    GET    /api/fsck                 integrity report of the served repo

Object payloads stream zero-copy: single-object GETs and mget streams write
``memoryview`` slices of the CAS's pooled mmaps straight to the socket,
with an exact ``Content-Length`` precomputed from O(1) size lookups — the
hub never holds a full transfer in memory. JSON bodies accept and JSON
responses offer gzip content-encoding above a small floor; object bytes are
LZMA/npy payloads already and are never recompressed.
"""

from __future__ import annotations

import gzip
import json
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple
from urllib.parse import unquote, urlsplit

from repro.hub.app import HubApp
from repro.obs import span
from repro.remote.http import GZIP_FLOOR, WIRE_REC_HEAD, iter_records
from repro.remote.transport import ETAG_ABSENT, PublishConflict

_RANGE_RE = re.compile(r"bytes=(\d+)-(\d*)$")

# Fixed-path routes the latency histogram may label with — dynamic path
# tails collapse to :key/:tid and anything else to "other", so a scanner
# walking random URLs cannot grow unbounded label cardinality.
_FIXED_ROUTES = frozenset({
    "/api/ping", "/api/lineage", "/api/have", "/api/objects/mget",
    "/api/objects/sizes", "/api/objects", "/api/finalize", "/api/journal",
    "/api/stats", "/api/metrics", "/api/fsck"})


def route_family(path: str) -> str:
    """Collapse a request path to its bounded-cardinality route label."""
    if (path.startswith("/api/objects/")
            and path not in ("/api/objects/mget", "/api/objects/sizes")):
        return "/api/objects/:key"
    if path.startswith("/api/journal/"):
        return "/api/journal/:tid"
    return path if path in _FIXED_ROUTES else "other"

# CAS keys and journal ids are hash-derived tokens; anything else in the
# path tail is hostile (os.path.join would resolve '../' segments OUTSIDE
# the served repository — remote file read/write). Dot-only names are
# excluded too ('.'/'..' are directories even without a separator).
_SAFE_ID_RE = re.compile(r"^[A-Za-z0-9._-]+$")


def _safe_id(s: str) -> bool:
    return bool(_SAFE_ID_RE.match(s)) and set(s) != {"."}


class _RangeNotSatisfiable(Exception):
    """Range start at/after EOF — HTTP 416, not a malformed request."""


class HubRequestHandler(BaseHTTPRequestHandler):
    server_version = "mgit-hub/1.0"
    protocol_version = "HTTP/1.1"

    # -- plumbing ------------------------------------------------------------
    @property
    def app(self) -> HubApp:
        return self.server.app  # type: ignore[attr-defined]

    def log_message(self, format: str, *args: Any) -> None:
        pass  # request metrics live in app.stats, not stderr

    def _gzip_ok(self) -> bool:
        return "gzip" in (self.headers.get("Accept-Encoding") or "")

    def _read_body(self) -> bytes:
        length = int(self.headers.get("Content-Length") or 0)
        data = self.rfile.read(length) if length else b""
        self.app.count(bytes_in=len(data))
        if self.headers.get("Content-Encoding") == "gzip":
            data = gzip.decompress(data)
        return data

    def _read_json(self) -> Dict:
        body = self._read_body()
        return json.loads(body) if body else {}

    def _send_json(self, obj: Any, status: int = 200,
                   headers: Optional[Dict[str, str]] = None) -> None:
        body = json.dumps(obj).encode()
        hdrs = dict(headers or {})
        if self._gzip_ok() and len(body) > GZIP_FLOOR:
            body = gzip.compress(body, 5)
            hdrs["Content-Encoding"] = "gzip"
        if status >= 400:
            # error paths may not have drained the request body (401 fires
            # before _read_body); leftover bytes on a keep-alive socket
            # would be parsed as the next request line — close instead
            self.close_connection = True
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        for k, v in hdrs.items():
            self.send_header(k, v)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        self.app.count(bytes_out=len(body))

    # -- auth ----------------------------------------------------------------
    def _authorized(self, path: str) -> bool:
        if path == "/api/ping":
            return True  # health probes run without credentials
        if self.app.auth.check(self.headers.get("Authorization")):
            return True
        self.app.count(auth_failures=1)
        self._send_json({"error": "unauthorized"}, status=401,
                        headers={"WWW-Authenticate": "Bearer"})
        return False

    # -- dispatch ------------------------------------------------------------
    def _write_body(self, view) -> None:
        """Body write with an optional per-connection bandwidth cap.

        ``HubServer.throttle_bps`` (benchmarks/tests only) emulates the
        per-TCP-stream throughput limit of a real network path — the
        property that makes parallel ranged connections aggregate
        bandwidth. Zero (the default) writes straight through.
        """
        bps = self.server.throttle_bps  # type: ignore[attr-defined]
        if not bps:
            self.wfile.write(view)
            return
        step = 256 * 1024
        mv = memoryview(view)
        for i in range(0, len(mv), step):
            piece = mv[i:i + step]
            self.wfile.write(piece)
            time.sleep(len(piece) / bps)

    def _route(self, method: str) -> None:
        path = unquote(urlsplit(self.path).path).rstrip("/") or "/"
        self.app.count(requests=1)
        if self.server.delay_s:  # type: ignore[attr-defined]
            # simulated per-request RTT (benchmarks/tests only): loopback
            # has none, so this is how WAN behavior is exercised locally
            time.sleep(self.server.delay_s)  # type: ignore[attr-defined]
        if not self._authorized(path):
            return
        route = route_family(path)
        t0 = time.perf_counter()
        try:
            with span("hub.request", cat="hub", method=method, route=route):
                handler = self._resolve(method, path)
                if handler is None:
                    self._send_json({"error": f"no route {method} {path}"},
                                    status=404)
                    return
                handler()
        except PublishConflict as exc:
            self._send_json({"error": "lineage moved",
                             "etag": exc.current_etag}, status=409)
        except (ValueError, KeyError, json.JSONDecodeError) as exc:
            self._send_json({"error": str(exc)}, status=400)
        except ConnectionError:
            raise  # client went away mid-response; nothing to send
        except Exception as exc:  # noqa: BLE001 — daemon must not die
            self._send_json({"error": f"internal: {exc}"}, status=500)
        finally:
            self.app.observe_request(method, route,
                                     time.perf_counter() - t0)

    def _resolve(self, method: str, path: str):
        if (path.startswith("/api/objects/")
                and path not in ("/api/objects/mget", "/api/objects/sizes")):
            key = path[len("/api/objects/"):]
            if not _safe_id(key):
                return None  # 404s — never reaches a filesystem join
            if method == "GET":
                return lambda: self._get_object(key)
            return None
        if path.startswith("/api/journal/"):
            tid = path[len("/api/journal/"):]
            if not _safe_id(tid):
                return None
            return {"GET": lambda: self._journal_get(tid),
                    "PUT": lambda: self._journal_put(tid),
                    "DELETE": lambda: self._journal_delete(tid),
                    }.get(method)
        table = {
            ("GET", "/api/ping"): self._ping,
            ("GET", "/api/lineage"): self._get_lineage,
            ("PUT", "/api/lineage"): self._put_lineage,
            ("POST", "/api/have"): self._have,
            ("POST", "/api/objects/mget"): self._mget,
            ("POST", "/api/objects/sizes"): self._sizes,
            ("POST", "/api/objects"): self._put_objects,
            ("POST", "/api/finalize"): self._finalize,
            ("GET", "/api/journal"): self._journal_list,
            ("GET", "/api/stats"): self._stats,
            ("GET", "/api/metrics"): self._metrics,
            ("GET", "/api/fsck"): self._fsck,
        }
        return table.get((method, path))

    def do_GET(self) -> None:
        self._route("GET")

    def do_POST(self) -> None:
        self._route("POST")

    def do_PUT(self) -> None:
        self._route("PUT")

    def do_DELETE(self) -> None:
        self._route("DELETE")

    # -- routes --------------------------------------------------------------
    def _ping(self) -> None:
        self._send_json({"ok": True, "service": "mgit-hub",
                         "auth": self.app.auth.enabled})

    def _get_lineage(self) -> None:
        payload, etag = self.app.lineage()
        if payload is None:
            self._send_json({"error": "no lineage published"}, status=404,
                            headers={"ETag": etag})
            return
        self._send_json(payload, headers={"ETag": etag})

    def _put_lineage(self) -> None:
        expected = self.headers.get("If-Match")
        payload = self._read_json()
        result = self.app.publish(payload, expected=expected)
        self._send_json(result, headers={"ETag": result["etag"]})

    def _have(self) -> None:
        keys = self._read_json().get("keys", [])
        self._send_json({"have": self.app.have(keys)})

    def _parse_range(self, size: int) -> Optional[Tuple[int, int]]:
        """``(start, length)`` from a single-range header, or None."""
        header = self.headers.get("Range")
        if not header:
            return None
        m = _RANGE_RE.match(header.strip())
        if not m:
            raise ValueError(f"unsupported Range {header!r}")
        start = int(m.group(1))
        end = int(m.group(2)) if m.group(2) else size - 1
        if start >= size or end < start:
            # 416, not 400: a resume positioned exactly at EOF is a healthy
            # "nothing left to fetch", not a malformed request
            raise _RangeNotSatisfiable(size)
        return start, min(end, size - 1) - start + 1

    def _get_object(self, key: str) -> None:
        try:
            view = self.app.store.cas.get_view(key)
        except KeyError:
            self._send_json({"error": f"no object {key!r}"}, status=404)
            return
        size = len(view)
        try:
            rng = self._parse_range(size)
        except _RangeNotSatisfiable:
            self._send_json({"error": "range not satisfiable", "size": size},
                            status=416,
                            headers={"Content-Range": f"bytes */{size}"})
            return
        if rng is None:
            start, length, status = 0, size, 200
        else:
            (start, length), status = rng, 206
        self.send_response(status)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Accept-Ranges", "bytes")
        if status == 206:
            self.send_header("Content-Range",
                             f"bytes {start}-{start + length - 1}/{size}")
        self.send_header("Content-Length", str(length))
        self.end_headers()
        self._write_body(view[start:start + length])  # zero-copy off mmap
        self.app.count(bytes_out=length, objects_served=1)

    def _mget(self) -> None:
        keys = self._read_json().get("keys", [])
        sizes, missing = self.app.object_sizes(keys)
        if missing:
            self._send_json({"error": "missing objects",
                             "missing": missing[:32]}, status=404)
            return
        total = sum(WIRE_REC_HEAD.size + len(k.encode()) + n
                    for k, n in sizes.items())
        self.send_response(200)
        self.send_header("Content-Type", "application/x-mgit-pack")
        self.send_header("Content-Length", str(total))
        self.end_headers()
        try:
            for key, view in self.app.iter_object_views(list(sizes)):
                if len(view) != sizes[key]:
                    raise ValueError(f"object {key!r} changed size "
                                     "mid-stream")
                kb = key.encode()
                self.wfile.write(WIRE_REC_HEAD.pack(len(kb), len(view)))
                self.wfile.write(kb)
                self._write_body(view)  # zero-copy off the pooled mmap
        except ConnectionError:
            raise
        except Exception:
            # Headers + a Content-Length already went out: a concurrent gc
            # or ledger overwrite invalidated the preflight. Splicing a JSON
            # error into the declared body would corrupt the stream — abort
            # the connection instead; the client sees a short read and
            # retries through its backoff path.
            self.close_connection = True
            return
        self.app.count(bytes_out=total, objects_served=len(sizes))

    def _sizes(self) -> None:
        # Size preflight for the pull planner: objects above the ranged-read
        # floor (chunked tensors' ``c_`` payloads) get segmented parallel
        # GETs instead of riding the single mget stream. Missing keys are
        # reported, not an error — the planner mgets whatever remains.
        keys = self._read_json().get("keys", [])
        sizes, missing = self.app.object_sizes(keys)
        self._send_json({"sizes": sizes, "missing": missing})

    def _put_objects(self) -> None:
        body = self._read_body()
        objects = dict(iter_records(body))
        written = self.app.import_objects(objects)
        self._send_json({"imported": len(objects), "bytes_written": written})

    def _finalize(self) -> None:
        self._read_body()  # client-side roots are advisory; drain + ignore
        self._send_json({"refcounts": self.app.finalize()})

    def _journal_get(self, tid: str) -> None:
        payload = self.app.journal.journal_load(tid)
        if payload is None:
            self._send_json({"error": f"no journal {tid}"}, status=404)
        else:
            self._send_json(payload)

    def _journal_put(self, tid: str) -> None:
        self.app.journal.journal_write(tid, self._read_json())
        self._send_json({"ok": True})

    def _journal_delete(self, tid: str) -> None:
        self.app.journal.journal_clear(tid)
        self._send_json({"ok": True})

    def _journal_list(self) -> None:
        self._send_json({"transfers": list(self.app.journal.journal_list())})

    def _stats(self) -> None:
        self._send_json(self.app.stats_json())

    def _metrics(self) -> None:
        # Prometheus text, NOT json — scrapers parse the exposition format
        body = self.app.metrics_text().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)
        self.app.count(bytes_out=len(body))

    def _fsck(self) -> None:
        self._send_json(self.app.fsck())


class HubServer(ThreadingHTTPServer):
    """ThreadingHTTPServer bound to one :class:`HubApp`."""

    daemon_threads = True
    allow_reuse_address = True
    delay_s = 0.0        # per-request simulated RTT; see _route
    throttle_bps = 0     # per-connection bandwidth cap; see _write_body

    def __init__(self, app: HubApp, host: str = "127.0.0.1",
                 port: int = 0) -> None:
        self.app = app
        super().__init__((host, port), HubRequestHandler)

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"


def make_server(app: HubApp, host: str = "127.0.0.1",
                port: int = 0) -> HubServer:
    """Bind (port 0 picks an ephemeral one) without starting the loop —
    tests and the CLI both drive ``serve_forever`` themselves."""
    return HubServer(app, host=host, port=port)


def start_in_thread(app: HubApp, host: str = "127.0.0.1", port: int = 0
                    ) -> Tuple[HubServer, threading.Thread]:
    """Serve on a daemon thread; returns the bound server (``server.url``)."""
    server = make_server(app, host=host, port=port)
    thread = threading.Thread(target=server.serve_forever,
                              name="mgit-hub", daemon=True)
    thread.start()
    return server, thread
