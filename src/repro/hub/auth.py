"""Bearer-token auth stub for the hub daemon (DESIGN.md §11.5).

Deliberately minimal: one shared secret per daemon, compared in constant
time. The seam a real deployment swaps for per-user tokens/OAuth is the
single :meth:`TokenAuth.check` call in the request handler — routes never
see credentials, only an allow/deny.
"""

from __future__ import annotations

import hmac
from typing import Optional


class TokenAuth:
    """``TokenAuth(None)`` allows everything (open hub, loopback dev use);
    with a token set, requests must carry ``Authorization: Bearer <token>``.
    """

    def __init__(self, token: Optional[str] = None) -> None:
        self.token = token or None

    @property
    def enabled(self) -> bool:
        return self.token is not None

    def check(self, authorization_header: Optional[str]) -> bool:
        """True when the request may proceed."""
        if self.token is None:
            return True
        if not authorization_header:
            return False
        scheme, _, presented = authorization_header.partition(" ")
        if scheme.lower() != "bearer":
            return False
        return hmac.compare_digest(presented.strip(), self.token)
