"""MGit model hub: a threaded HTTP daemon serving one repository.

The multi-user face of the system (paper §5 collaboration; DESIGN.md §11):
:class:`HubApp` wraps a repo directory's :class:`ArtifactStore` + lineage
document with concurrent-push safety (optimistic lineage swap -> HTTP 409),
server-side quarantine policy and live stats; :mod:`repro.hub.routes`
exposes it over a small REST surface that
:class:`repro.remote.http.HttpTransport` speaks from the client side, so
``push``/``pull``/``clone`` work unchanged against ``http://`` remotes.

Start one with ``mgit hub serve`` or embed via :func:`start_in_thread`.
"""

from repro.hub.app import HubApp
from repro.hub.auth import TokenAuth
from repro.hub.routes import (HubRequestHandler, HubServer, make_server,
                              start_in_thread)

__all__ = ["HubApp", "TokenAuth", "HubRequestHandler", "HubServer",
           "make_server", "start_in_thread"]
