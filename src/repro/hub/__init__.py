"""MGit model hub: a worker-pool HTTP daemon serving one or many repositories.

The multi-user face of the system (paper §5 collaboration; DESIGN.md §11,
§16): :class:`HubApp` wraps a repo's lineage document + transfer journal
with concurrent-push safety (optimistic lineage swap -> HTTP 409) and
server-side quarantine policy; :class:`HubService` scales that to many
repos over one shared CAS (cross-repo dedup, union-root refcounts,
orphan GC via :mod:`repro.hub.gc`); :mod:`repro.hub.replica` adds
read-replica hubs and a replica-aware client transport.
:mod:`repro.hub.routes` exposes it all over a small REST surface that
:class:`repro.remote.http.HttpTransport` speaks from the client side, so
``push``/``pull``/``clone`` work unchanged against ``http://`` remotes —
including repo-scoped ``http://hub/r/<name>`` URLs.

Start one with ``mgit hub serve`` or embed via :func:`start_in_thread`.
"""

from repro.hub.app import HubApp, HubService, ReadOnlyRepo
from repro.hub.auth import TokenAuth
from repro.hub.routes import (HubRequestHandler, HubServer, make_server,
                              start_in_thread)

__all__ = ["HubApp", "HubService", "ReadOnlyRepo", "TokenAuth",
           "HubRequestHandler", "HubServer", "make_server",
           "start_in_thread"]
