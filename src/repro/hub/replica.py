"""Read replicas: horizontally fanned-out hub reads (DESIGN.md §16.5).

Two halves, both built on the existing transport stack rather than a new
protocol:

* :class:`ReplicaHub` — the server side. A read-only :class:`HubService`
  that periodically mirrors a primary hub over plain
  :class:`~repro.remote.http.HttpTransport` calls: list the primary's
  repos, compare lineage etags, fetch the missing object closure in
  journalled-size batches, then *mirror-publish* the primary's document
  byte-faithfully (same etag — that is what the client's staleness check
  keys on). All client-facing mutations are rejected with 403; the only
  write path is the sync itself.

* :class:`ReplicaSetTransport` — the client side. Wraps a primary
  transport plus N replica transports behind the ordinary
  :class:`~repro.remote.transport.Transport` interface so ``pull``/
  ``clone`` work unchanged: every write, journal and publish goes to the
  primary; ``have``/object reads fan out over the replicas round-robin.
  Before trusting a replica for a read batch, its lineage etag is compared
  against the last etag seen from the primary — a stale or unreachable
  replica falls back to the primary for that batch (counted, §14). Object
  payloads are content-addressed, so a *fresh-etag* replica can still miss
  an object only in pathological windows; those surface as KeyError and
  fall back the same way.

Sync is pull-based and periodic (or on-demand via ``POST
/api/replica/sync``): replicas are eventually consistent by design, and
the staleness fallback is what makes that safe for clients.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.common.faults import kill_point
from repro.hub.app import HubService
from repro.hub.routes import HubServer, start_in_thread
from repro.obs import span
from repro.remote.http import HttpTransport
from repro.remote.negotiate import chunked
from repro.remote.transport import Transport, lineage_etag
from repro.store.manifest_walk import walk_manifests

#: objects fetched per mget batch during replica sync
SYNC_CHUNK_OBJECTS = 64


class ReplicaHub:
    """Mirrors a primary hub into a local read-only :class:`HubService`."""

    def __init__(self, root: str, primary_url: str,
                 token: Optional[str] = None) -> None:
        self.primary_url = primary_url.rstrip("/")
        self.token = token
        self.service = HubService(root, token=token, read_only=True,
                                  allow_quarantined=True)
        self._sync_lock = threading.Lock()

    def _transport(self, repo: Optional[str] = None) -> HttpTransport:
        url = self.primary_url
        if repo and repo != "default":
            url = f"{url}/r/{repo}"
        return HttpTransport(url, token=self.token)

    def _sync_repo(self, name: str) -> Dict[str, Any]:
        """Mirror one repo; returns a per-repo report."""
        tr = self._transport(name)
        payload, etag = tr.fetch_lineage_versioned()
        app = self.service.repo(name)  # internal create; clients cannot
        _, local_etag = app.lineage()
        if etag == local_etag:
            return {"repo": name, "synced": False, "etag": etag}
        store = self.service.store
        roots = [n["artifact_ref"] for n in (payload or {}).get("nodes", [])
                 if n.get("artifact_ref")]

        def fetch(keys: Sequence[str]) -> Dict[str, bytes]:
            # serve manifests we already hold locally; fetch + import the
            # rest so the walk doubles as the manifest transfer
            out: Dict[str, bytes] = {}
            miss: List[str] = []
            for k in keys:
                if store.cas.has(k):
                    try:
                        out[k] = store.cas.get_bytes(k)
                        continue
                    except KeyError:
                        pass
                miss.append(k)
            if miss:
                got = tr.read_objects(miss)
                store.import_objects(got)
                out.update(got)
            return out

        missing_refs: List[str] = []
        closure = walk_manifests(fetch, roots, missing=missing_refs)
        want: List[str] = []
        seen: Set[str] = set()
        for info in closure.values():
            for k in info.objects:
                if k not in seen and not store.cas.has(k):
                    seen.add(k)
                    want.append(k)
        fetched_bytes = 0
        for batch in chunked(want, SYNC_CHUNK_OBJECTS):
            got = tr.read_objects(batch)
            fetched_bytes += store.import_objects(got)
        kill_point("replica.sync.pre_publish")
        if payload is not None:
            app.publish(payload, mirror=True)
        self.service.finalize()
        self.service.default.count(replica_syncs=1)
        return {"repo": name, "synced": True, "etag": etag,
                "objects_fetched": len(want) + len(closure),
                "bytes_fetched": fetched_bytes,
                "missing_refs": missing_refs}

    def sync_once(self) -> Dict[str, Any]:
        """One full mirror pass over every repo the primary lists."""
        with self._sync_lock, span("replica.sync", cat="hub"):
            repos = self._transport().list_repos()
            reports = [self._sync_repo(r["name"]) for r in repos]
            return {"repos": reports,
                    "synced": sum(1 for r in reports if r["synced"])}

    def sync_forever(self, interval_s: float = 5.0,
                     stop: Optional[threading.Event] = None) -> None:
        """Periodic sync loop (daemon-thread body for ``hub replica``)."""
        stop = stop or threading.Event()
        while not stop.is_set():
            try:
                self.sync_once()
            except Exception:  # noqa: BLE001 — a flaky primary must not kill the loop
                pass
            stop.wait(interval_s)


def serve_replica(root: str, primary_url: str, token: Optional[str] = None,
                  host: str = "127.0.0.1", port: int = 0,
                  sync_interval_s: float = 5.0,
                  ) -> Tuple[ReplicaHub, HubServer, threading.Thread]:
    """Start a read-replica hub: HTTP server + periodic sync thread.

    Returns ``(replica, server, sync_thread)``; the server runs on its own
    daemon thread (``server.url``), the sync thread mirrors every
    ``sync_interval_s`` (0 disables the loop — call ``sync_once`` or POST
    ``/api/replica/sync`` to sync on demand)."""
    replica = ReplicaHub(root, primary_url, token=token)
    server, _ = start_in_thread(replica.service, host=host, port=port)
    server.replica = replica
    if sync_interval_s > 0:
        sync_thread = threading.Thread(
            target=replica.sync_forever, args=(sync_interval_s,),
            name="mgit-replica-sync", daemon=True)
        sync_thread.start()
    else:
        sync_thread = threading.Thread(target=lambda: None)
    return replica, server, sync_thread


class ReplicaSetTransport(Transport):
    """Primary + N read replicas behind the standard Transport interface.

    Reads rotate over the replicas; each batch first validates the chosen
    replica's lineage etag against the last etag observed from the primary
    (refreshed by ``fetch_lineage_versioned``, which every pull/clone calls
    before reading objects). Stale or failing replicas fall back to the
    primary — correctness never depends on replica freshness, only read
    *capacity* does."""

    def __init__(self, primary: Transport,
                 replicas: Sequence[Transport]) -> None:
        self.primary = primary
        self.replicas = list(replicas)
        self.url = primary.url
        self._rr = 0
        self._rr_lock = threading.Lock()
        self._primary_etag: Optional[str] = None
        self.fallbacks = 0
        self.replica_reads = 0

    # -- replica selection ----------------------------------------------------
    def _next_replica(self) -> Optional[Transport]:
        if not self.replicas:
            return None
        with self._rr_lock:
            tr = self.replicas[self._rr % len(self.replicas)]
            self._rr += 1
            return tr

    def _fresh_replica(self) -> Optional[Transport]:
        """A replica whose document matches the primary's last-seen etag."""
        tr = self._next_replica()
        if tr is None:
            return None
        try:
            if self._primary_etag is None:
                # no primary fetch yet this session: establish the baseline
                self._primary_etag = self.primary.fetch_lineage_versioned()[1]
            _, replica_etag = tr.fetch_lineage_versioned()
            if replica_etag == self._primary_etag:
                return tr
        except Exception:  # noqa: BLE001 — unreachable replica == stale replica
            pass
        self.fallbacks += 1
        return None

    def _read_via(self, op, *args, **kwargs):
        tr = self._fresh_replica()
        if tr is not None:
            try:
                result = op(tr)(*args, **kwargs)
                self.replica_reads += 1
                return result
            except Exception:  # noqa: BLE001 — any replica failure -> primary
                self.fallbacks += 1
        return op(self.primary)(*args, **kwargs)

    # -- reads (fanned) -------------------------------------------------------
    def have(self, keys: Sequence[str]) -> Set[str]:
        return self._read_via(lambda t: t.have, keys)

    def read_objects(self, keys: Sequence[str]) -> Dict[str, bytes]:
        return self._read_via(lambda t: t.read_objects, keys)

    def object_sizes(self, keys: Sequence[str]) -> Optional[Dict[str, int]]:
        return self._read_via(lambda t: t.object_sizes, keys)

    def read_object_range(self, key: str, start: int,
                          length: Optional[int] = None) -> bytes:
        return self._read_via(lambda t: t.read_object_range,
                              key, start, length)

    def read_object_parallel(self, key: str, size: int, **kwargs) -> bytes:
        return self._read_via(lambda t: t.read_object_parallel,
                              key, size, **kwargs)

    # -- lineage (primary-authoritative) --------------------------------------
    def fetch_lineage(self) -> Optional[Dict]:
        return self.fetch_lineage_versioned()[0]

    def fetch_lineage_versioned(self) -> Tuple[Optional[Dict], str]:
        payload, etag = self.primary.fetch_lineage_versioned()
        self._primary_etag = etag
        return payload, etag

    # -- writes (primary only) ------------------------------------------------
    def ensure_repo(self) -> None:
        self.primary.ensure_repo()

    def publish_lineage(self, payload: Dict,
                        expected: Optional[str] = None) -> Optional[Dict]:
        result = self.primary.publish_lineage(payload, expected=expected)
        self._primary_etag = lineage_etag(payload)
        return result

    def write_objects(self, objects: Mapping[str, bytes]) -> None:
        self.primary.write_objects(objects)

    def finalize(self, roots: Sequence[str]) -> None:
        self.primary.finalize(roots)

    # -- journal (primary only) -----------------------------------------------
    def journal_load(self, transfer_id: str) -> Optional[Dict]:
        return self.primary.journal_load(transfer_id)

    def journal_write(self, transfer_id: str, payload: Dict) -> None:
        self.primary.journal_write(transfer_id, payload)

    def journal_clear(self, transfer_id: str) -> None:
        self.primary.journal_clear(transfer_id)

    def journal_list(self) -> Sequence[str]:
        return self.primary.journal_list()
