"""HubApp — the model-hub daemon's repository state machine (DESIGN.md §11).

One app instance serves one repository directory through the same
:class:`ArtifactStore` a local client would open — the hub is "just another
peer" whose transport happens to be HTTP. The HTTP layer
(:mod:`repro.hub.routes`) stays a thin codec: every semantic decision lives
here so it is unit-testable without sockets.

Concurrency model (§11.3): object ingestion and reads are fully parallel —
the CAS is internally locked, writes are content-addressed and idempotent,
and reads come off the pooled mmap views. Only the *lineage publish* takes
the per-repo write lock, and only for the duration of one compare-and-swap:
the client sends the etag of the document its merge was based on, and a
mismatch raises :class:`PublishConflict` (HTTP 409) instead of clobbering a
concurrent pusher's work. Refcount finalization re-derives its root set
from the *current* published document under the same lock, so interleaved
``publish``/``finalize`` pairs from racing clients always converge on exact
counts (fsck-clean).

Quarantine is honored server-side (§9.4 meets §11.3): a pushed document may
not introduce or modify nodes flagged quarantined — the hub keeps its own
copy (or drops a new quarantined node) and reports the rejected names,
unless the operator started it with ``allow_quarantined``. Client-side
filtering already does this by default; the server check makes the policy
hold against old or adversarial clients too.

As everywhere in the remote stack, the hub only ever handles *stored*
artifact bytes: manifests, tensors and delta blobs under their CAS keys.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Iterable, Iterator, List, Mapping, Optional
from typing import Sequence, Tuple

from repro.hub.auth import TokenAuth
from repro.obs import REGISTRY, Histogram, render_prometheus
from repro.remote.journal import LocalJournalStore
from repro.remote.transport import (ETAG_ABSENT, PublishConflict,
                                    lineage_etag)
from repro.store.artifact_store import ArtifactStore


class HubApp:
    """Serves one repo directory; thread-safe for a ThreadingHTTPServer."""

    def __init__(self, root: str, token: Optional[str] = None,
                 allow_quarantined: bool = False) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.store = ArtifactStore(root=self.root)
        self.journal = LocalJournalStore(self.root)
        self.auth = TokenAuth(token)
        self.allow_quarantined = allow_quarantined
        self._publish_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.started_at = time.time()
        # registry-backed compat view: same count()/stats_json() surface,
        # scrapeable as mgit_hub_* through GET /api/metrics (§14)
        self.stats = REGISTRY.group(
            "mgit_hub",
            keys=("requests", "bytes_in", "bytes_out", "objects_served",
                  "objects_received", "publishes", "conflicts_409",
                  "quarantine_rejected", "auth_failures", "finalizes"),
            help="hub request/transfer counters")
        self._latency: Dict[Tuple[str, str], Histogram] = {}

    # -- stats ---------------------------------------------------------------
    def count(self, **deltas: int) -> None:
        with self._stats_lock:
            for key, d in deltas.items():
                self.stats[key] = self.stats.get(key, 0) + d

    def observe_request(self, method: str, route: str,
                        seconds: float) -> None:
        """Record one request into the per-route latency histogram."""
        h = self._latency.get((method, route))
        if h is None:
            h = REGISTRY.histogram(
                "mgit_http_request_seconds",
                help="request latency by service/method/route",
                service="hub", instance=self.stats.instance,
                method=method, route=route)
            self._latency[(method, route)] = h
        h.observe(seconds)

    def latency_json(self) -> Dict[str, Any]:
        """Per-route p50/p99 estimated from the histogram buckets —
        the same math a `histogram_quantile()` PromQL query would do."""
        out: Dict[str, Any] = {}
        for (method, route), h in sorted(self._latency.items()):
            out[f"{method} {route}"] = {
                "count": h.count,
                "p50_ms": round((h.quantile(0.5) or 0.0) * 1e3, 3),
                "p99_ms": round((h.quantile(0.99) or 0.0) * 1e3, 3)}
        return out

    def metrics_text(self) -> str:
        """Prometheus text exposition of the whole process registry."""
        return render_prometheus()

    def stats_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = self.stats.snapshot()
        out["uptime_seconds"] = round(time.time() - self.started_at, 3)
        out["objects"] = self.store.cas.object_count()
        out["physical_bytes"] = self.store.cas.physical_bytes()
        out["in_flight_transfers"] = list(self.journal.journal_list())
        out["request_latency"] = self.latency_json()
        return out

    # -- lineage document ----------------------------------------------------
    def _lineage_path(self) -> str:
        return os.path.join(self.root, "lineage.json")

    def lineage(self) -> Tuple[Optional[Dict], str]:
        """Current document + etag (``ETAG_ABSENT`` when none published)."""
        if not os.path.exists(self._lineage_path()):
            return None, ETAG_ABSENT
        with open(self._lineage_path()) as f:
            payload = json.load(f)
        return payload, lineage_etag(payload)

    def _filter_quarantined(self, payload: Dict, current: Optional[Dict]
                            ) -> Tuple[Dict, List[str]]:
        """Enforce the quarantine policy on an incoming document.

        A quarantined node identical to the hub's copy passes (it is not
        being *propagated*, just echoed back by the client's merge); one
        that is new or modified is replaced by the hub's copy or dropped.
        Adjacency lists are pruned to the surviving node set afterwards so
        a drop never leaves dangling edges."""
        from repro.core.quarantine import is_quarantined
        cur = {n["name"]: n for n in (current or {}).get("nodes", [])}
        kept: List[Dict] = []
        rejected: List[str] = []
        for node in payload.get("nodes", []):
            if is_quarantined(node) and node != cur.get(node["name"]):
                rejected.append(node["name"])
                if node["name"] in cur:
                    kept.append(cur[node["name"]])
                continue
            kept.append(node)
        if not rejected:
            return payload, []
        names = {n["name"] for n in kept}
        for node in kept:
            for field in ("parents", "children", "version_parents",
                          "version_children"):
                node[field] = [x for x in node.get(field, []) if x in names]
        return {"nodes": kept}, sorted(rejected)

    def publish(self, payload: Dict, expected: Optional[str] = None
                ) -> Dict[str, Any]:
        """Compare-and-swap the lineage document (the push commit point).

        Raises :class:`PublishConflict` when ``expected`` no longer matches
        the current etag. Returns ``{"etag", "quarantined_rejected"}``."""
        with self._publish_lock:
            current, current_etag = self.lineage()
            if expected is not None and expected != current_etag:
                self.count(conflicts_409=1)
                raise PublishConflict(current_etag)
            if not self.allow_quarantined:
                payload, rejected = self._filter_quarantined(payload, current)
            else:
                rejected = []
            tmp = self._lineage_path() + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._lineage_path())
            self.count(publishes=1, quarantine_rejected=len(rejected))
            return {"etag": lineage_etag(payload),
                    "quarantined_rejected": rejected}

    def finalize(self) -> int:
        """Rebuild exact refcounts from the *current* document's roots.

        Root derivation is server-side on purpose: a racing client's view
        of the merged roots may be stale by the time its finalize arrives;
        the published document is the single source of truth. Runs under
        the publish lock so a rebuild never interleaves with a swap."""
        with self._publish_lock:
            payload, _ = self.lineage()
            roots = [n["artifact_ref"] for n in (payload or {}).get("nodes", [])
                     if n.get("artifact_ref")]
            counts = self.store.rebuild_refcounts(roots)
            self.count(finalizes=1)
            return len(counts)

    # -- objects -------------------------------------------------------------
    def have(self, keys: Sequence[str]) -> List[str]:
        cas = self.store.cas
        return [k for k in keys if cas.has(k)]

    def object_sizes(self, keys: Sequence[str]
                     ) -> Tuple[Dict[str, int], List[str]]:
        """(sizes of present keys, missing keys) — the mget preflight that
        lets routes send an exact Content-Length before streaming."""
        cas = self.store.cas
        sizes: Dict[str, int] = {}
        missing: List[str] = []
        for k in keys:
            if cas.has(k):
                sizes[k] = cas.size(k)
            else:
                missing.append(k)
        return sizes, missing

    def iter_object_views(self, keys: Sequence[str]
                          ) -> Iterator[Tuple[str, memoryview]]:
        """Zero-copy streaming multi-get straight off the CAS mmap pool."""
        return self.store.cas.iter_views(keys)

    def import_objects(self, objects: Mapping[str, bytes]) -> int:
        written = self.store.import_objects(objects)
        self.count(objects_received=len(objects))
        return written

    def fsck(self) -> Dict[str, Any]:
        payload, _ = self.lineage()
        roots = [n["artifact_ref"] for n in (payload or {}).get("nodes", [])
                 if n.get("artifact_ref")]
        report = self.store.fsck(roots)
        report["in_flight_transfers"] = list(self.journal.journal_list())
        return report
