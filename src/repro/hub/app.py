"""HubApp / HubService — the model-hub daemon's state machines (DESIGN.md §11, §16).

One :class:`HubApp` instance serves one repository through the same
:class:`ArtifactStore` a local client would open — the hub is "just another
peer" whose transport happens to be HTTP. The HTTP layer
(:mod:`repro.hub.routes`) stays a thin codec: every semantic decision lives
here so it is unit-testable without sockets.

:class:`HubService` (§16.1) scales that to many repositories over ONE shared
CAS: tenants live at ``<root>/repos/<name>/`` (per-repo lineage document,
transfer journal and publish lock), while objects/packs/refcounts are
service-wide — a derived model pushed to repo B dedups byte-for-byte against
its base in repo A. The hub root itself doubles as the ``default`` tenant,
so a PR-5 single-repo hub directory is a valid (one-tenant) service and the
unscoped ``/api/...`` surface keeps working unchanged.

Sharing the refcount table changes two derivations: ``finalize`` and
``fsck`` must take the *union* of all tenants' roots (one tenant's roots
would clobber counts on objects another tenant shares), and deleting a repo
cannot decrement anything synchronously — its objects become *orphans*
(positive refcount, unreachable from every tenant) that the maintenance
pass in :mod:`repro.hub.gc` confirms across two cycles before reclaiming.
``HubService`` tracks recently-imported keys for the same reason: a push's
objects are refcounted but unreachable until its publish lands, and must
never be mistaken for garbage in between.

Concurrency model (§11.3): object ingestion and reads are fully parallel —
the CAS is internally locked, writes are content-addressed and idempotent,
and reads come off the pooled mmap views. Only the *lineage publish* takes
the per-repo write lock, and only for the duration of one compare-and-swap:
the client sends the etag of the document its merge was based on, and a
mismatch raises :class:`PublishConflict` (HTTP 409) instead of clobbering a
concurrent pusher's work. Refcount finalization re-derives its root set
from the *current* published document under the same lock, so interleaved
``publish``/``finalize`` pairs from racing clients always converge on exact
counts (fsck-clean).

Quarantine is honored server-side (§9.4 meets §11.3): a pushed document may
not introduce or modify nodes flagged quarantined — the hub keeps its own
copy (or drops a new quarantined node) and reports the rejected names,
unless the operator started it with ``allow_quarantined``. Client-side
filtering already does this by default; the server check makes the policy
hold against old or adversarial clients too.

As everywhere in the remote stack, the hub only ever handles *stored*
artifact bytes: manifests, tensors and delta blobs under their CAS keys.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Iterable, Iterator, List, Mapping, Optional
from typing import Sequence, Tuple

from repro.common.faults import kill_point
from repro.hub.auth import TokenAuth
from repro.obs import REGISTRY, Histogram, render_prometheus
from repro.remote.journal import LocalJournalStore
from repro.remote.transport import (ETAG_ABSENT, PublishConflict,
                                    lineage_etag)
from repro.store.artifact_store import ArtifactStore

#: counters every HubApp/HubService exposes as ``mgit_hub_*`` (§14, §16)
HUB_STAT_KEYS = ("requests", "bytes_in", "bytes_out", "objects_served",
                 "objects_received", "publishes", "conflicts_409",
                 "quarantine_rejected", "auth_failures", "finalizes",
                 "sheds_503", "errors_500", "gc_runs", "gc_bytes_reclaimed",
                 "compactions", "replica_syncs", "replica_fallbacks")


class ReadOnlyRepo(RuntimeError):
    """Raised when a mutating operation hits a read-only (replica) hub."""


class HubApp:
    """Serves one repo directory; thread-safe for a ThreadingHTTPServer."""

    def __init__(self, root: str, token: Optional[str] = None,
                 allow_quarantined: bool = False,
                 store: Optional[ArtifactStore] = None,
                 service: Optional["HubService"] = None,
                 name: str = "default", read_only: bool = False) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        # shared-store mode (§16.1): the service owns ONE ArtifactStore for
        # every tenant; this repo dir then holds only lineage.json and its
        # transfer journal. Standalone mode keeps the PR-5 shape.
        self.store = store if store is not None else ArtifactStore(root=self.root)
        self.service = service
        self.name = name
        self.read_only = read_only
        self.journal = LocalJournalStore(self.root)
        self.auth = TokenAuth(token)
        self.allow_quarantined = allow_quarantined
        self._publish_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.started_at = time.time()
        # registry-backed compat view: same count()/stats_json() surface,
        # scrapeable as mgit_hub_* through GET /api/metrics (§14)
        self.stats = REGISTRY.group(
            "mgit_hub", keys=HUB_STAT_KEYS,
            help="hub request/transfer counters")
        self._latency: Dict[Tuple[str, str], Histogram] = {}

    # -- stats ---------------------------------------------------------------
    def count(self, **deltas: int) -> None:
        with self._stats_lock:
            for key, d in deltas.items():
                self.stats[key] = self.stats.get(key, 0) + d

    def observe_request(self, method: str, route: str,
                        seconds: float) -> None:
        """Record one request into the per-route latency histogram."""
        h = self._latency.get((method, route))
        if h is None:
            h = REGISTRY.histogram(
                "mgit_http_request_seconds",
                help="request latency by service/method/route",
                service="hub", instance=self.stats.instance,
                method=method, route=route)
            self._latency[(method, route)] = h
        h.observe(seconds)

    def latency_json(self) -> Dict[str, Any]:
        """Per-route p50/p99 estimated from the histogram buckets —
        the same math a `histogram_quantile()` PromQL query would do."""
        out: Dict[str, Any] = {}
        for (method, route), h in sorted(self._latency.items()):
            out[f"{method} {route}"] = {
                "count": h.count,
                "p50_ms": round((h.quantile(0.5) or 0.0) * 1e3, 3),
                "p99_ms": round((h.quantile(0.99) or 0.0) * 1e3, 3)}
        return out

    def metrics_text(self) -> str:
        """Prometheus text exposition of the whole process registry."""
        return render_prometheus()

    def stats_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = self.stats.snapshot()
        out["uptime_seconds"] = round(time.time() - self.started_at, 3)
        out["objects"] = self.store.cas.object_count()
        out["physical_bytes"] = self.store.cas.physical_bytes()
        out["in_flight_transfers"] = list(self.journal.journal_list())
        out["request_latency"] = self.latency_json()
        return out

    # -- lineage document ----------------------------------------------------
    def _lineage_path(self) -> str:
        return os.path.join(self.root, "lineage.json")

    def lineage(self) -> Tuple[Optional[Dict], str]:
        """Current document + etag (``ETAG_ABSENT`` when none published)."""
        if not os.path.exists(self._lineage_path()):
            return None, ETAG_ABSENT
        with open(self._lineage_path()) as f:
            payload = json.load(f)
        return payload, lineage_etag(payload)

    def _filter_quarantined(self, payload: Dict, current: Optional[Dict]
                            ) -> Tuple[Dict, List[str]]:
        """Enforce the quarantine policy on an incoming document.

        A quarantined node identical to the hub's copy passes (it is not
        being *propagated*, just echoed back by the client's merge); one
        that is new or modified is replaced by the hub's copy or dropped.
        Adjacency lists are pruned to the surviving node set afterwards so
        a drop never leaves dangling edges."""
        from repro.core.quarantine import is_quarantined
        cur = {n["name"]: n for n in (current or {}).get("nodes", [])}
        kept: List[Dict] = []
        rejected: List[str] = []
        for node in payload.get("nodes", []):
            if is_quarantined(node) and node != cur.get(node["name"]):
                rejected.append(node["name"])
                if node["name"] in cur:
                    kept.append(cur[node["name"]])
                continue
            kept.append(node)
        if not rejected:
            return payload, []
        names = {n["name"] for n in kept}
        for node in kept:
            for field in ("parents", "children", "version_parents",
                          "version_children"):
                node[field] = [x for x in node.get(field, []) if x in names]
        return {"nodes": kept}, sorted(rejected)

    def publish(self, payload: Dict, expected: Optional[str] = None,
                mirror: bool = False) -> Dict[str, Any]:
        """Compare-and-swap the lineage document (the push commit point).

        Raises :class:`PublishConflict` when ``expected`` no longer matches
        the current etag. Returns ``{"etag", "quarantined_rejected"}``.

        ``mirror=True`` is the replica-sync path (§16.5): an unconditional
        byte-faithful replace that bypasses the read-only guard and the
        quarantine filter — the primary already applied policy, and a
        replica re-filtering would drift its etag from the primary's,
        permanently failing the client's staleness check."""
        if self.read_only and not mirror:
            raise ReadOnlyRepo(f"repo {self.name!r} is a read-only replica")
        with self._publish_lock:
            current, current_etag = self.lineage()
            if expected is not None and expected != current_etag:
                self.count(conflicts_409=1)
                raise PublishConflict(current_etag)
            if not self.allow_quarantined and not mirror:
                payload, rejected = self._filter_quarantined(payload, current)
            else:
                rejected = []
            tmp = self._lineage_path() + ".tmp"
            kill_point("hub.publish.pre_replace")
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._lineage_path())
            kill_point("hub.publish.post_replace")
            self.count(publishes=1, quarantine_rejected=len(rejected))
            return {"etag": lineage_etag(payload),
                    "quarantined_rejected": rejected}

    def roots(self) -> List[str]:
        """``artifact_ref`` roots of this repo's current document."""
        payload, _ = self.lineage()
        return [n["artifact_ref"] for n in (payload or {}).get("nodes", [])
                if n.get("artifact_ref")]

    def finalize(self) -> int:
        """Rebuild exact refcounts from the *current* document's roots.

        Root derivation is server-side on purpose: a racing client's view
        of the merged roots may be stale by the time its finalize arrives;
        the published document is the single source of truth. Runs under
        the publish lock so a rebuild never interleaves with a swap.

        In shared-store mode the rebuild must span the union of every
        tenant's roots — rebuilding from one tenant's view would install
        that tenant's counts on objects other tenants also reference — so
        it delegates to :meth:`HubService.finalize`."""
        if self.service is not None:
            self.count(finalizes=1)
            return self.service.finalize()
        with self._publish_lock:
            counts = self.store.rebuild_refcounts(self.roots())
            self.count(finalizes=1)
            return len(counts)

    # -- objects -------------------------------------------------------------
    def have(self, keys: Sequence[str]) -> List[str]:
        cas = self.store.cas
        return [k for k in keys if cas.has(k)]

    def object_sizes(self, keys: Sequence[str]
                     ) -> Tuple[Dict[str, int], List[str]]:
        """(sizes of present keys, missing keys) — the mget preflight that
        lets routes send an exact Content-Length before streaming."""
        cas = self.store.cas
        sizes: Dict[str, int] = {}
        missing: List[str] = []
        for k in keys:
            if cas.has(k):
                sizes[k] = cas.size(k)
            else:
                missing.append(k)
        return sizes, missing

    def iter_object_views(self, keys: Sequence[str]
                          ) -> Iterator[Tuple[str, memoryview]]:
        """Zero-copy streaming multi-get straight off the CAS mmap pool."""
        return self.store.cas.iter_views(keys)

    def import_objects(self, objects: Mapping[str, bytes]) -> int:
        if self.read_only:
            raise ReadOnlyRepo(f"repo {self.name!r} is a read-only replica")
        written = self.store.import_objects(objects)
        if self.service is not None:
            # grace-list the keys so maintenance GC cannot mistake a push's
            # not-yet-published objects for orphans (§16.3)
            self.service.note_imports(objects.keys())
        self.count(objects_received=len(objects))
        return written

    def fsck(self) -> Dict[str, Any]:
        # shared-store mode: integrity is a service-wide question (the
        # refcount table spans tenants), answered against the union roots
        if self.service is not None:
            report = self.service.fsck()
        else:
            report = self.store.fsck(self.roots())
        report["in_flight_transfers"] = list(self.journal.journal_list())
        return report


class HubService:
    """Many repos, one CAS (§16.1): the multi-tenant hub state machine.

    The service root holds the shared ``ArtifactStore``; the root directory
    itself is the ``default`` tenant (backward compatible with a PR-5 hub
    dir) and named tenants live under ``repos/<name>/``. Tenant apps share
    the service's token, quarantine policy and read-only flag; each keeps
    its own publish lock, lineage etag and transfer journal.
    """

    def __init__(self, root: str, token: Optional[str] = None,
                 allow_quarantined: bool = False,
                 read_only: bool = False) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.store = ArtifactStore(root=self.root)
        self.token = token
        self.auth = TokenAuth(token)
        self.allow_quarantined = allow_quarantined
        self.read_only = read_only
        self.started_at = time.time()
        self._repos: Dict[str, HubApp] = {}
        self._repos_lock = threading.RLock()
        # one finalize at a time service-wide: rebuilds write the SHARED
        # refcount table, and interleaved rebuilds from different root
        # snapshots could leave a mix of both (§16.1)
        self._finalize_lock = threading.RLock()
        # maintenance state (§16.3) — owned by repro.hub.gc
        self.gc_lock = threading.Lock()
        self.gc_cycle = 0
        self.prev_orphans: set = set()
        self._imports_lock = threading.Lock()
        #: key -> (gc_cycle at import, monotonic time at import)
        self._recent_imports: Dict[str, Tuple[int, float]] = {}
        #: wall-clock backstop for the import grace list — an abandoned
        #: transfer's debris lingers at most this long past its last chunk
        self.import_grace_s = 900.0
        self.default = self._make_app("default", self.root)
        for name in self._scan_repos():
            self.repo(name)

    # -- tenants -------------------------------------------------------------
    def _repo_dir(self, name: str) -> str:
        return os.path.join(self.root, "repos", name)

    def _make_app(self, name: str, root: str) -> HubApp:
        app = HubApp(root, token=self.token,
                     allow_quarantined=self.allow_quarantined,
                     store=self.store, service=self, name=name,
                     read_only=self.read_only)
        self._repos[name] = app
        return app

    def _scan_repos(self) -> List[str]:
        repos_dir = os.path.join(self.root, "repos")
        if not os.path.isdir(repos_dir):
            return []
        return sorted(d for d in os.listdir(repos_dir)
                      if os.path.isdir(os.path.join(repos_dir, d)))

    def repo(self, name: str, create: bool = True) -> Optional[HubApp]:
        """Tenant app for ``name``, created on first touch when allowed.

        Callers (the HTTP layer) validate the name shape before this point;
        creation is an authorized-request-only path there."""
        with self._repos_lock:
            app = self._repos.get(name)
            if app is None and create:
                app = self._make_app(name, self._repo_dir(name))
            return app

    def repo_names(self) -> List[str]:
        with self._repos_lock:
            return sorted(self._repos)

    def delete_repo(self, name: str) -> bool:
        """Drop a tenant: its lineage document and journal are removed;
        its *private* objects stay in the shared CAS as orphans until the
        two-cycle maintenance GC (§16.3) confirms and reclaims them. Keys
        it shared with surviving tenants lose its contribution immediately:
        the closing finalize rebuilds every still-reachable count from the
        surviving union roots (orphans are untouched — rebuilds only write
        reachable keys). The ``default`` tenant is the service root and
        cannot be deleted."""
        if name == "default":
            return False
        with self._repos_lock:
            app = self._repos.pop(name, None)
        if app is None:
            return False
        with app._publish_lock:
            import shutil
            shutil.rmtree(app.root, ignore_errors=True)
        self.finalize()
        return True

    # -- service-wide derivations --------------------------------------------
    def all_roots(self) -> List[str]:
        """Union of every tenant's lineage roots (deterministic order)."""
        roots: set = set()
        with self._repos_lock:
            apps = list(self._repos.values())
        for app in apps:
            roots.update(app.roots())
        return sorted(roots)

    def finalize(self) -> int:
        with self._finalize_lock:
            counts = self.store.rebuild_refcounts(self.all_roots())
            # published keys graduate out of the import grace list: they are
            # reachability-protected now, and must not enjoy time-based
            # grace later should they become orphans (e.g. repo deletion)
            with self._imports_lock:
                for k in counts:
                    self._recent_imports.pop(k, None)
            return len(counts)

    def fsck(self) -> Dict[str, Any]:
        report = self.store.fsck(self.all_roots())
        report["repos"] = {}
        with self._repos_lock:
            apps = list(self._repos.items())
        for name, app in apps:
            _, etag = app.lineage()
            report["repos"][name] = {
                "etag": etag,
                "in_flight_transfers": list(app.journal.journal_list())}
        return report

    # -- import grace list (§16.3) -------------------------------------------
    def note_imports(self, keys: Iterable[str]) -> None:
        with self._imports_lock:
            cycle = self.gc_cycle
            now = time.monotonic()
            for k in keys:
                self._recent_imports[k] = (cycle, now)

    def recent_import_keys(self, grace: int = 2) -> set:
        """Keys imported within ``grace`` maintenance cycles *or* the last
        ``import_grace_s`` seconds — never GC candidates: they may belong
        to a transfer whose publish is still in flight. Cycle count alone
        is not a safe clock: an aggressive maintenance loop can burn
        through ``grace`` cycles in milliseconds while a large push is
        still streaming chunks, so wall time backstops it. Publishing
        graduates keys out of this list (see :meth:`finalize`), so the
        time window only ever delays reclaim of *abandoned* transfers.
        ``grace=0`` disables both protections (offline CLI use)."""
        with self._imports_lock:
            floor = self.gc_cycle - grace
            now = time.monotonic()
            stale = [k for k, (c, t) in self._recent_imports.items()
                     if c < floor and (grace <= 0
                                       or now - t >= self.import_grace_s)]
            for k in stale:
                del self._recent_imports[k]
            if grace <= 0:
                return set()
            return set(self._recent_imports)

    # -- maintenance (delegates to repro.hub.gc) ------------------------------
    def run_gc(self, confirm_cycles: int = 2,
               grace: int = 1) -> Dict[str, Any]:
        from repro.hub import gc as hubgc
        return hubgc.run_gc(self, confirm_cycles=confirm_cycles, grace=grace)

    def compact(self) -> Dict[str, Any]:
        from repro.hub import gc as hubgc
        return hubgc.run_compaction(self)

    def stats_json(self) -> Dict[str, Any]:
        out = self.default.stats_json()
        out["repos"] = self.repo_names()
        out["read_only"] = self.read_only
        out["gc_cycle"] = self.gc_cycle
        out["deferred_dead_bytes"] = self.store.cas.deferred_dead_bytes()
        return out
