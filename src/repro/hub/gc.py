"""Hub maintenance: orphan GC and pack compaction under live traffic (§16.3).

A shared-CAS hub accumulates two kinds of garbage the per-push ``finalize``
can never touch (``rebuild_refcounts`` only writes keys reachable from the
given roots, by design):

* **orphans** — keys with a positive refcount that no tenant's lineage
  reaches: the residue of deleted repos, superseded publishes, and crashed
  pushes whose transfer landed but whose publish never did;
* **dead pack payload** — bytes in packfiles owned by already-collected
  records, reclaimed by rewriting the pack.

Correctness under concurrency rests on three fences:

1. **Import grace list.** A push's objects are refcounted-but-unreachable
   between its transfer and its publish — exactly an orphan's signature.
   :meth:`HubService.note_imports` stamps every imported key with the
   current maintenance cycle; keys stamped within ``grace`` cycles are
   never candidates. A push therefore only risks collection if it idles
   for more than two full maintenance intervals between transfer end and
   publish — and even then fence 2 must also miss it.
2. **Two-cycle confirmation.** A candidate is only reclaimed if it was
   *already* a candidate in the previous cycle AND is one again now, with
   both root snapshots taken under every tenant's publish lock plus the
   service finalize lock — so a publish that resurrects a candidate
   between cycles is always observed.
3. **Reader leases.** The zero-and-sweep runs ``CAS.gc()`` which, under
   active :meth:`CAS.pin` leases (held by in-flight object GETs and mget
   streams), defers physical reclaim to the last lease release. A reader
   that resolved offsets before the sweep finishes its stream against
   intact bytes; the mget abort-and-retry seam remains as the last-ditch
   defense.

Writers (publish/finalize) stall for the duration of the zero-and-sweep —
refcount surgery and index bookkeeping, no object I/O — which is the
advertised saturation behavior (§16.4): GC pauses writes briefly, never
readers.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict

from repro.common.faults import kill_point
from repro.obs import span


@contextlib.contextmanager
def _all_publish_locks(service):
    """Every tenant's publish lock + the finalize lock, in one canonical
    order (finalize first, then tenants sorted by name) so maintenance can
    never deadlock against a publish/finalize pair."""
    with service._repos_lock:
        apps = [service._repos[n] for n in sorted(service._repos)]
    with contextlib.ExitStack() as stack:
        stack.enter_context(service._finalize_lock)
        for app in apps:
            stack.enter_context(app._publish_lock)
        yield


def orphan_candidates(service, grace: int = 1) -> set:
    """Refcounted keys unreachable from every tenant's roots, minus the
    import grace list. Caller must hold the publish/finalize locks for the
    snapshot to be race-free against concurrent publishes."""
    store = service.store
    reachable = set(store.expected_refcounts(service.all_roots()))
    recent = service.recent_import_keys(grace=grace)
    with store.cas._lock:
        counted = [k for k, c in store.cas.refcounts.items() if c > 0]
    return {k for k in counted if k not in reachable and k not in recent}


def run_gc(service, confirm_cycles: int = 2,
           grace: int = 1) -> Dict[str, Any]:
    """One maintenance cycle: confirm + reclaim orphans, sweep rc==0 keys.

    Returns a report with candidate/confirmed counts and bytes reclaimed
    (bytes deferred to an active reader lease count as reclaimed — they are
    committed and unlinked at the last pin release). ``confirm_cycles=1``
    skips the two-cycle fence and ``grace=0`` the import grace list —
    offline use only (``mgit hub gc`` on a dir with no live traffic).
    With the defaults, garbage created at cycle N is reclaimed at cycle
    N+3 at the latest: protected through N+1 (grace), candidate at N+2,
    confirmed at N+3."""
    store = service.store
    with service.gc_lock, span("hub.gc", cat="hub"):
        service.gc_cycle += 1
        cycle = service.gc_cycle
        with _all_publish_locks(service):
            cands = orphan_candidates(service, grace=grace)
            if confirm_cycles <= 1:
                confirmed = set(cands)
            else:
                confirmed = cands & service.prev_orphans
            kill_point("hub.gc.pre_zero")
            if confirmed:
                with store.cas.batched_refcounts():
                    for k in confirmed:
                        store.cas.refcounts[k] = 0
            # sweep inside the lock scope: a publish racing the sweep could
            # otherwise re-reference a key between our zeroing and the CAS
            # removing its bytes
            reclaimed = store.cas.gc()
            # the confirmation ledger only advances once the sweep commits —
            # a crash anywhere above leaves the previous cycle's candidate
            # set intact instead of resetting the two-cycle clock
            service.prev_orphans = cands - confirmed
        deferred = store.cas.deferred_dead_bytes()
        report = {
            "cycle": cycle,
            "candidates": len(cands),
            "confirmed_orphans": len(confirmed),
            "reclaimed_bytes": reclaimed,
            "deferred_bytes": deferred,
            "gc_epoch": store.cas.gc_epoch,
        }
        service.default.count(gc_runs=1, gc_bytes_reclaimed=reclaimed)
        return report


def run_compaction(service) -> Dict[str, Any]:
    """Rewrite packs carrying dead payload (aggressive: any dead bytes).

    Skipped while reader leases are active — compaction relocates live
    records between packs, and although POSIX keeps unlinked pack files
    readable through existing mmaps, an in-flight mget's size preflight
    must not see index entries move under it. The caller (maintenance
    loop / CLI) simply retries next cycle."""
    store = service.store
    with service.gc_lock, span("hub.compact", cat="hub"):
        before = store.cas.pack_stats()
        did = store.cas.compact(aggressive=True)
        after = store.cas.pack_stats()
        report = {
            "ran": did,
            "packs_before": before["packs"],
            "packs_after": after["packs"],
            "dead_bytes_before": before["pack_dead_bytes"],
            "dead_bytes_after": after["pack_dead_bytes"],
        }
        if did:
            service.default.count(compactions=1)
        return report
