from repro.ft.straggler import StepTimer, StragglerEvent, StragglerPolicy, Watchdog

__all__ = ["StepTimer", "StragglerEvent", "StragglerPolicy", "Watchdog"]
