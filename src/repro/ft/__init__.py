from repro.ft.straggler import (ElasticRestart, StepTimer, StragglerEvent,
                                StragglerPolicy, Watchdog)

__all__ = ["ElasticRestart", "StepTimer", "StragglerEvent", "StragglerPolicy",
           "Watchdog"]
