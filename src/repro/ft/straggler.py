"""Straggler detection & mitigation hooks + heartbeat watchdog.

At 1000+ nodes, tail-latency hosts dominate step time (synchronous SPMD waits
for the slowest participant). The framework-side pieces we can build and test
without hardware:

* :class:`StepTimer` — per-step EWMA + variance; flags steps slower than
  ``threshold`` x the running mean (the standard detection signal).
* :class:`StragglerPolicy` — pluggable responses, in escalating order:
  log -> shrink the offender's data shard (rebalance callback) -> evict +
  elastic restart from the last MGit checkpoint (the CheckpointManager's
  ``restore_sharded`` re-lays the state out on the surviving mesh).
* :class:`Watchdog` — heartbeat file per host + stale-peer detection; drives
  the same policy on hang (vs slow) failures.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    mean: float
    ratio: float


class StepTimer:
    """EWMA step-time tracker; emits an event when a step is anomalously slow."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.1,
                 warmup: int = 5) -> None:
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.mean: Optional[float] = None
        self.n = 0
        self.events: List[StragglerEvent] = []

    def record(self, step: int, duration: float) -> Optional[StragglerEvent]:
        self.n += 1
        if self.mean is None:
            self.mean = duration
            return None
        event = None
        ratio = duration / max(self.mean, 1e-9)
        if self.n > self.warmup and ratio > self.threshold:
            event = StragglerEvent(step=step, duration=duration,
                                   mean=self.mean, ratio=ratio)
            self.events.append(event)
            # don't pollute the EWMA with the anomaly
            return event
        self.mean = (1 - self.alpha) * self.mean + self.alpha * duration
        return event


class StragglerPolicy:
    """Escalating mitigation: log -> rebalance -> evict/elastic-restart."""

    def __init__(self,
                 rebalance_fn: Optional[Callable[[StragglerEvent], None]] = None,
                 evict_fn: Optional[Callable[[StragglerEvent], None]] = None,
                 rebalance_after: int = 2, evict_after: int = 5) -> None:
        self.rebalance_fn = rebalance_fn
        self.evict_fn = evict_fn
        self.rebalance_after = rebalance_after
        self.evict_after = evict_after
        self.count = 0
        self.actions: List[str] = []

    def on_event(self, event: StragglerEvent) -> str:
        self.count += 1
        if self.count >= self.evict_after and self.evict_fn is not None:
            self.evict_fn(event)
            action = "evict"
        elif self.count >= self.rebalance_after and self.rebalance_fn is not None:
            self.rebalance_fn(event)
            action = "rebalance"
        else:
            action = "log"
        self.actions.append(action)
        return action


class ElasticRestart:
    """Evict-stage policy action: resume the trainer from its lineage.

    Wired as :class:`StragglerPolicy`'s ``evict_fn``, this closes the
    evict -> elastic-restart loop described in the module docstring: when a
    host is slow enough to evict, the surviving workers re-lay the last
    committed MGit checkpoint out on the current mesh and continue from
    there. With continuous checkpointing (DESIGN.md §15) the rollback
    window is the commit cadence — steps, not epochs — and the exact tier
    makes the resumed state bit-identical to what was committed.

    ``trainer`` is duck-typed: it needs ``.ckpt`` (a CheckpointManager or
    None), ``.state``, ``.mesh``, ``.pipeline`` and ``.start_step``."""

    def __init__(self, trainer) -> None:
        self.trainer = trainer
        self.restarts: List[Dict[str, int]] = []

    def __call__(self, event: StragglerEvent) -> None:
        tr = self.trainer
        ckpt = getattr(tr, "ckpt", None)
        if ckpt is None:
            return
        ckpt.wait()  # drain in-flight commits; surface async failures
        if ckpt.latest_step() is None:
            return  # nothing committed yet: keep the live state
        if tr.mesh is not None:
            import jax

            def tmpl(x):
                return jax.ShapeDtypeStruct(
                    x.shape, x.dtype, sharding=getattr(x, "sharding", None))

            template = jax.tree_util.tree_map(tmpl, tr.state)
            state, step = ckpt.restore_sharded(template)
        else:
            state, step = ckpt.restore(template=tr.state)
        tr.state = state
        tr.pipeline.step = step
        tr.start_step = step
        self.restarts.append({"event_step": event.step,
                              "restored_step": step})


class Watchdog:
    """File-based heartbeats: each host touches its file; stale peers flagged."""

    def __init__(self, directory: str, host_id: str, interval: float = 1.0,
                 stale_after: float = 5.0) -> None:
        self.directory = directory
        self.host_id = host_id
        self.interval = interval
        self.stale_after = stale_after
        os.makedirs(directory, exist_ok=True)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _path(self, host: str) -> str:
        return os.path.join(self.directory, f"hb_{host}")

    def beat(self) -> None:
        with open(self._path(self.host_id), "w") as f:
            f.write(str(time.time()))

    def start(self) -> None:
        def loop():
            while not self._stop.wait(self.interval):
                self.beat()
        self.beat()
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)

    def stale_peers(self) -> Dict[str, float]:
        """host -> seconds since last heartbeat, for peers past stale_after."""
        now = time.time()
        stale = {}
        for f in os.listdir(self.directory):
            if not f.startswith("hb_"):
                continue
            host = f[3:]
            if host == self.host_id:
                continue
            age = now - os.path.getmtime(os.path.join(self.directory, f))
            if age > self.stale_after:
                stale[host] = age
        return stale
