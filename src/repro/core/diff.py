"""MGit's ``diff`` primitive (paper Algorithm 3).

Hash-table based graph matching between two LayerGraphs. Produces the node/edge
add/delete sets needed to turn model A into model B, plus the matched pairs.
Runs in either *structural* mode (hashes ignore parameter values) or
*contextual* mode (hashes include parameter content). The divergence scores

    d = |edges_diff| / (|edges_A| + |edges_B|)

computed from the diff output drive automated lineage-graph construction (§3.2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.artifact import ModelArtifact
from repro.core.graphir import LayerGraph


Edge = Tuple[str, str]
Match = Tuple[str, str]


@dataclasses.dataclass
class DiffResult:
    """Output of ``module_diff``: edit script A -> B plus the match maps."""

    mode: str
    matched_nodes: List[Match]     # (name_in_A, name_in_B)
    matched_edges: List[Tuple[Edge, Edge]]
    add_nodes: List[str]           # names in B to add
    del_nodes: List[str]           # names in A to delete
    add_edges: List[Edge]          # edges in B to add
    del_edges: List[Edge]          # edges in A to delete
    n_edges_a: int
    n_edges_b: int
    n_nodes_a: int
    n_nodes_b: int

    @property
    def divergence(self) -> float:
        """Paper's divergence score: |edges_diff| / (|E_A| + |E_B|)."""
        denom = self.n_edges_a + self.n_edges_b
        if denom == 0:
            # Degenerate single-layer graphs: fall back to node-level score.
            denom = self.n_nodes_a + self.n_nodes_b
            return (len(self.add_nodes) + len(self.del_nodes)) / max(denom, 1)
        return (len(self.add_edges) + len(self.del_edges)) / denom

    @property
    def identical(self) -> bool:
        return not (self.add_nodes or self.del_nodes or self.add_edges or self.del_edges)

    def match_map(self) -> Dict[str, str]:
        """name_in_A -> name_in_B for matched layers."""
        return dict(self.matched_nodes)


def _node_hash(graph: LayerGraph, name: str, mode: str) -> str:
    node = graph.nodes[name]
    return node.contextual_hash() if mode == "contextual" else node.structural_hash()


def _build_tables(graph: LayerGraph, mode: str):
    """Hash tables of nodes and edges; values are lists in topological order."""
    topo = graph.topo_order()
    topo_idx = {n: i for i, n in enumerate(topo)}
    nh = {n: _node_hash(graph, n, mode) for n in graph.nodes}
    node_table: Dict[str, List[str]] = {}
    for n in topo:
        node_table.setdefault(nh[n], []).append(n)
    edge_table: Dict[Tuple[str, str], List[Edge]] = {}
    for (src, dst) in sorted(graph.edges, key=lambda e: (topo_idx[e[0]], topo_idx[e[1]])):
        edge_table.setdefault((nh[src], nh[dst]), []).append((src, dst))
    return node_table, edge_table, topo_idx


def module_diff(a, b, mode: str = "contextual") -> DiffResult:
    """Algorithm 3: diff between two models (LayerGraphs or ModelArtifacts)."""
    if isinstance(a, ModelArtifact):
        if mode == "contextual":
            a.param_hashes()  # ensure hashes are attached to the graph
        a = a.graph
    if isinstance(b, ModelArtifact):
        if mode == "contextual":
            b.param_hashes()
        b = b.graph

    n1_table, e1_table, topo1 = _build_tables(a, mode)
    n2_table, e2_table, topo2 = _build_tables(b, mode)

    match1: Dict[str, str] = {}  # node in A -> node in B
    match2: Dict[str, str] = {}  # node in B -> node in A
    matched_edges: List[Tuple[Edge, Edge]] = []

    def _consistent(x: str, y: str) -> bool:
        """x (in A) may be matched to y (in B) without violating 1-1 matching."""
        if x in match1:
            return match1[x] == y
        return y not in match2

    def _commit(x: str, y: str) -> None:
        match1[x] = y
        match2[y] = x

    # Pass 1: greedily match edges whose (src-hash, dst-hash) agree, committing a
    # matching only when both endpoint pairs are consistent with matches so far.
    for ehash, es1 in e1_table.items():
        es2 = list(e2_table.get(ehash, []))
        for e1 in es1:
            for e2 in es2:
                if _consistent(e1[0], e2[0]) and _consistent(e1[1], e2[1]):
                    # A self-consistency corner: matching (x->y) for both
                    # endpoints of the same edge must not collide.
                    if e1[0] == e1[1] and e2[0] != e2[1]:
                        continue
                    _commit(e1[0], e2[0])
                    _commit(e1[1], e2[1])
                    matched_edges.append((e1, e2))
                    es2.remove(e2)
                    break

    # Pass 2: match remaining nodes that share a hash but sit on no common edge.
    for nhash, ns1 in n1_table.items():
        ns1u = [n for n in ns1 if n not in match1]
        ns2u = [n for n in n2_table.get(nhash, []) if n not in match2]
        for x, y in zip(ns1u, ns2u):
            _commit(x, y)

    # Pass 3: drop inverse (order-crossing) matches. Sort node matches by topo
    # order in A and require strictly increasing topo order in B.
    node_matches = sorted(match1.items(), key=lambda kv: topo1[kv[0]])
    kept: List[Match] = []
    max_b = -1
    for x, y in node_matches:
        if topo2[y] > max_b:
            kept.append((x, y))
            max_b = topo2[y]
    kept_1 = {x: y for x, y in kept}
    kept_2 = {y: x for x, y in kept}
    matched_edges = [
        (e1, e2)
        for (e1, e2) in matched_edges
        if kept_1.get(e1[0]) == e2[0] and kept_1.get(e1[1]) == e2[1]
    ]
    matched_edge_set_a = {e1 for e1, _ in matched_edges}
    matched_edge_set_b = {e2 for _, e2 in matched_edges}

    # Also: an edge present in both graphs between *matched* endpoints counts as
    # matched even if pass 1 missed it (endpoints matched in pass 2).
    b_edges = set(b.edges)
    for (src, dst) in a.edges:
        if (src, dst) in matched_edge_set_a:
            continue
        mapped = (kept_1.get(src), kept_1.get(dst))
        if mapped[0] is not None and mapped[1] is not None and mapped in b_edges:
            if mapped not in matched_edge_set_b:
                matched_edges.append(((src, dst), mapped))
                matched_edge_set_a.add((src, dst))
                matched_edge_set_b.add(mapped)

    add_nodes = [n for n in b.nodes if n not in kept_2]
    del_nodes = [n for n in a.nodes if n not in kept_1]
    add_edges = [e for e in b.edges if e not in matched_edge_set_b]
    del_edges = [e for e in a.edges if e not in matched_edge_set_a]

    return DiffResult(
        mode=mode,
        matched_nodes=kept,
        matched_edges=matched_edges,
        add_nodes=add_nodes,
        del_nodes=del_nodes,
        add_edges=add_edges,
        del_edges=del_edges,
        n_edges_a=len(a.edges),
        n_edges_b=len(b.edges),
        n_nodes_a=len(a.nodes),
        n_nodes_b=len(b.nodes),
    )


def divergence_scores(a, b) -> Tuple[float, float]:
    """(d_structural, d_contextual) between two models (paper §3.2)."""
    ds = module_diff(a, b, mode="structural").divergence
    dc = module_diff(a, b, mode="contextual").divergence
    return ds, dc
