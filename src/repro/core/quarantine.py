"""The quarantine flag — one reader for every enforcement seam.

A node a test gate failed carries ``metadata["quarantined"] = True`` plus a
``metadata["quarantine"]`` record (DESIGN.md §9.4). Three subsystems make
policy off that flag: push selection (``repro.remote.sync`` excludes
quarantined nodes from the shipped subgraph), the hub's publish filter
(``repro.hub.app`` refuses to introduce them), and the serving gate
(``repro.serve.router`` refuses them traffic). Each used to read the
metadata ad hoc through ``repro.diag.gate``, which drags in the whole
diagnostics runner; this module is the dependency-light home both the flag
names and the predicate live in. ``repro.diag.gate`` re-exports everything
here, so existing imports keep working.
"""

from __future__ import annotations

from typing import Any, Dict, Union

QUARANTINE_FLAG = "quarantined"
QUARANTINE_RECORD = "quarantine"


def is_quarantined(node: Union["LineageNode", Dict[str, Any]]) -> bool:
    """Works on live nodes AND serialized node documents (sync payloads)."""
    metadata = node.get("metadata", {}) if isinstance(node, dict) \
        else node.metadata
    return bool(metadata.get(QUARANTINE_FLAG))
