"""LayerGraph IR — the DAG representation of a model that MGit's ``diff`` operates on.

The paper uses torch.fx DAGs (Reed et al., 2022); in JAX there is no module graph,
so models in this framework *emit* a LayerGraph alongside their parameter pytree:
nodes are layers (op type + parameter metadata), edges are dataflow. ``diff``
(Algorithm 3) runs hash-table graph matching over two LayerGraphs.

The IR is deliberately framework-agnostic metadata: shapes/dtypes/content-hashes,
never live arrays, so it serializes to JSON and scales to thousands of layers.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple


def _stable_hash(*parts: Any) -> str:
    """Deterministic hash of JSON-serializable parts (order-sensitive)."""
    payload = json.dumps(parts, sort_keys=True, default=str).encode()
    return hashlib.sha256(payload).hexdigest()[:16]


@dataclasses.dataclass
class LayerNode:
    """One layer (op) in the model DAG.

    Attributes:
      name: unique name within the graph (e.g. ``"block3/attn/wq"``).
      op_type: layer kind (e.g. ``"linear"``, ``"rmsnorm"``, ``"ssd"``).
      params: mapping param-name -> (shape tuple, dtype str). Metadata only.
      param_hashes: optional mapping param-name -> content hash (filled in when the
        artifact's parameters are known; used for *contextual* diff).
      attrs: static attributes that change structure (e.g. n_heads, window).
    """

    name: str
    op_type: str
    params: Dict[str, Tuple[Tuple[int, ...], str]] = dataclasses.field(default_factory=dict)
    param_hashes: Dict[str, str] = dataclasses.field(default_factory=dict)
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def structural_hash(self) -> str:
        """Hash of everything *except* parameter values."""
        return _stable_hash(self.op_type, sorted(self.params.items()), sorted(self.attrs.items()))

    def contextual_hash(self) -> str:
        """Hash including parameter content (falls back to structural if unknown)."""
        if not self.param_hashes:
            return self.structural_hash()
        return _stable_hash(self.structural_hash(), sorted(self.param_hashes.items()))

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "op_type": self.op_type,
            "params": {k: [list(s), d] for k, (s, d) in self.params.items()},
            "param_hashes": dict(self.param_hashes),
            "attrs": dict(self.attrs),
        }

    @staticmethod
    def from_json(obj: Mapping[str, Any]) -> "LayerNode":
        return LayerNode(
            name=obj["name"],
            op_type=obj["op_type"],
            params={k: (tuple(v[0]), v[1]) for k, v in obj["params"].items()},
            param_hashes=dict(obj.get("param_hashes", {})),
            attrs=dict(obj.get("attrs", {})),
        )


class LayerGraph:
    """A DAG of :class:`LayerNode` with dataflow edges.

    Insertion order of nodes is preserved and used as a topological-order
    tiebreak (model builders emit layers in execution order).
    """

    def __init__(self) -> None:
        self.nodes: Dict[str, LayerNode] = {}
        self.edges: List[Tuple[str, str]] = []
        self._succ: Dict[str, List[str]] = {}
        self._pred: Dict[str, List[str]] = {}

    # -- construction ------------------------------------------------------
    def add_node(self, node: LayerNode) -> LayerNode:
        if node.name in self.nodes:
            raise ValueError(f"duplicate layer name {node.name!r}")
        self.nodes[node.name] = node
        self._succ.setdefault(node.name, [])
        self._pred.setdefault(node.name, [])
        return node

    def add_edge(self, src: str, dst: str) -> None:
        if src not in self.nodes or dst not in self.nodes:
            raise KeyError(f"edge endpoints must exist: {src!r} -> {dst!r}")
        self.edges.append((src, dst))
        self._succ[src].append(dst)
        self._pred[dst].append(src)

    # -- queries -----------------------------------------------------------
    def successors(self, name: str) -> Sequence[str]:
        return self._succ.get(name, [])

    def predecessors(self, name: str) -> Sequence[str]:
        return self._pred.get(name, [])

    def topo_order(self) -> List[str]:
        """Kahn topological order; insertion order breaks ties."""
        indeg = {n: len(self._pred[n]) for n in self.nodes}
        order: List[str] = []
        ready = [n for n in self.nodes if indeg[n] == 0]  # insertion-ordered
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m in self._succ[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(self.nodes):
            raise ValueError("LayerGraph has a cycle")
        return order

    def reachable_from(self, starts: Iterable[str]) -> set:
        """All nodes reachable (downstream) from ``starts`` via DFS."""
        seen: set = set()
        stack = list(starts)
        while stack:
            n = stack.pop()
            for m in self._succ.get(n, []):
                if m not in seen:
                    seen.add(m)
                    stack.append(m)
        return seen

    def set_param_hashes(self, hashes: Mapping[str, Mapping[str, str]]) -> None:
        """Attach content hashes: {layer_name: {param_name: hash}}."""
        for lname, phashes in hashes.items():
            if lname in self.nodes:
                self.nodes[lname].param_hashes.update(phashes)

    def param_names(self) -> List[Tuple[str, str]]:
        """All (layer_name, param_name) pairs in topological order."""
        out = []
        for lname in self.topo_order():
            for pname in self.nodes[lname].params:
                out.append((lname, pname))
        return out

    # -- serialization -----------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {
            "nodes": [self.nodes[n].to_json() for n in self.nodes],
            "edges": [list(e) for e in self.edges],
        }

    @staticmethod
    def from_json(obj: Mapping[str, Any]) -> "LayerGraph":
        g = LayerGraph()
        for n in obj["nodes"]:
            g.add_node(LayerNode.from_json(n))
        for src, dst in obj["edges"]:
            g.add_edge(src, dst)
        return g

    # -- convenience builders ----------------------------------------------
    @staticmethod
    def chain(layers: Sequence[LayerNode]) -> "LayerGraph":
        """Linear chain graph (common case: sequential model)."""
        g = LayerGraph()
        prev: Optional[str] = None
        for node in layers:
            g.add_node(node)
            if prev is not None:
                g.add_edge(prev, node.name)
            prev = node.name
        return g

    def __len__(self) -> int:
        return len(self.nodes)

    def __repr__(self) -> str:
        return f"LayerGraph(nodes={len(self.nodes)}, edges={len(self.edges)})"
