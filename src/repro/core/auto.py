"""Automated lineage-graph construction (paper §3.2).

Inserting a model ``x`` runs a pairwise ``diff`` against every model already in
the graph and picks as parent the node with the smallest *contextual* then
*structural* divergence score. If nothing is sufficiently similar, ``x``
becomes a root. Only provenance edges are inferred — versioning edges require
user annotation, exactly as in the paper.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.artifact import ModelArtifact
from repro.core.diff import divergence_scores, module_diff
from repro.core.lineage import LineageGraph

# A divergence of 1.0 means "no overlap at all"; anything >= the threshold is
# treated as unrelated and the model becomes a root.
DEFAULT_ROOT_THRESHOLD = 0.999

_SAMPLE = 4096  # elements sampled per tensor for value divergence


def value_divergence(a: ModelArtifact, b: ModelArtifact) -> float:
    """Beyond-paper refinement: CONTINUOUS divergence over structurally
    matched parameters (mean relative |delta| on a sample).

    The paper's contextual score is exact-hash based, so once every tensor
    changed even slightly (finetune version chains) all candidates tie at
    1.0 and parent choice degrades to name order. A magnitude-aware score
    recovers the ordering (a model is closest to the version it was
    finetuned FROM). Used only as a tiebreak below ``root_threshold``.
    """
    d = module_diff(a, b, mode="structural")
    if not d.matched_nodes:
        return float("inf")
    num = den = 0.0
    for a_name, b_name in d.matched_nodes:
        for pname in a.graph.nodes[a_name].params:
            ka, kb = f"{a_name}/{pname}", f"{b_name}/{pname}"
            if ka not in a.params or kb not in b.params:
                continue
            pa = np.asarray(a.params[ka]).ravel()[:_SAMPLE]
            pb = np.asarray(b.params[kb]).ravel()[:_SAMPLE]
            if pa.shape != pb.shape:
                continue
            num += float(np.mean(np.abs(pa - pb)))
            den += float(np.mean(np.abs(pa))) + 1e-12
    return num / max(den, 1e-12)


def choose_parent(graph: LineageGraph, artifact: ModelArtifact,
                  root_threshold: float = DEFAULT_ROOT_THRESHOLD,
                  use_value_similarity: bool = True,
                  ) -> Tuple[Optional[str], Dict[str, Tuple[float, float]]]:
    """Return (best_parent_name or None, all pairwise scores).

    Paper order: smallest contextual, then structural divergence.
    ``use_value_similarity`` adds the continuous value divergence as a final
    tiebreak (set False for the paper-faithful algorithm)."""
    scores: Dict[str, Tuple] = {}
    for name, node in graph.nodes.items():
        try:
            other = node.get_model()
        except ValueError:
            continue
        ds, dc = divergence_scores(other, artifact)
        scores[name] = (ds, dc)
    if not scores:
        return None, scores
    if use_value_similarity:
        # only pay the value-divergence cost for the tied leaders
        leader = min((scores[n][1], scores[n][0]) for n in scores)
        tied = [n for n in scores
                if (scores[n][1], scores[n][0]) == leader]
        dv = {n: (value_divergence(graph.nodes[n].get_model(), artifact)
                  if len(tied) > 1 else 0.0)
              for n in tied}
        best = min(tied, key=lambda n: (dv[n], n))
    else:
        best = min(scores, key=lambda n: (scores[n][1], scores[n][0], n))
    ds, dc = scores[best]
    if dc >= root_threshold and ds >= root_threshold:
        return None, scores
    return best, scores


def auto_insert(graph: LineageGraph, artifact: ModelArtifact, name: str,
                root_threshold: float = DEFAULT_ROOT_THRESHOLD,
                use_value_similarity: bool = True) -> Optional[str]:
    """Insert ``artifact`` with automatically inferred provenance.

    Returns the chosen parent name (None if inserted as a root).
    """
    parent, _ = choose_parent(graph, artifact, root_threshold,
                              use_value_similarity=use_value_similarity)
    graph.add_node(artifact, name)
    if parent is not None:
        graph.add_edge(parent, name)
    return parent


def auto_construct(graph: LineageGraph, pool: List[Tuple[str, ModelArtifact]],
                   root_threshold: float = DEFAULT_ROOT_THRESHOLD,
                   use_value_similarity: bool = True,
                   ) -> Dict[str, Optional[str]]:
    """Build a lineage graph from a pool of (name, artifact) pairs.

    Models are inserted in pool order (the paper bootstraps from an unordered
    pool; insertion order only affects which of two equally-similar models is
    the parent). Returns {model: inferred parent}.
    """
    chosen: Dict[str, Optional[str]] = {}
    for name, artifact in pool:
        chosen[name] = auto_insert(graph, artifact, name, root_threshold,
                                   use_value_similarity=use_value_similarity)
    return chosen


def insertion_benchmark(graph: LineageGraph, pool: List[Tuple[str, ModelArtifact]],
                        ) -> List[float]:
    """Per-model auto-insertion wall times (paper Figure 3)."""
    times: List[float] = []
    for name, artifact in pool:
        t0 = time.perf_counter()
        auto_insert(graph, artifact, name)
        times.append(time.perf_counter() - t0)
    return times
